"""Render dryrun_all.json as the EXPERIMENTS.md §Roofline markdown table.

No jax import — pure JSON formatting.

  PYTHONPATH=src python -m benchmarks.roofline_table [dryrun_all.json]
"""
from __future__ import annotations

import json
import os
import sys


def _fmt(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 100:
        return f"{x:.0f}"
    if x >= 0.01:
        return f"{x:.2f}"
    return f"{x:.1e}"


def _gb(x) -> str:
    return f"{x / 1e9:.2f}" if x else "?"


def render(cells, mesh="16x16") -> str:
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound |"
        " useful | roofline | HBM GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    skips = []
    for c in cells:
        if c["mesh"] != mesh:
            continue
        if c.get("status") == "skipped":
            skips.append(f"{c['arch']} × {c['shape']}")
            continue
        if c.get("status") != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | FAILED | | | | "
                         f"| | |")
            continue
        r = c["roofline"]
        m = c.get("memory_analysis", {})
        peak = (m.get("argument_bytes") or 0) + (m.get("temp_bytes") or 0)
        lines.append(
            f"| {c['arch']} | {c['shape']} | {_fmt(r['t_compute_s'])} "
            f"| {_fmt(r['t_memory_s'])} | {_fmt(r['t_collective_s'])} "
            f"| {r['bottleneck'][:4]} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {_gb(peak)} |")
    out = "\n".join(lines)
    if skips:
        out += ("\n\nSkipped (full-attention at 524k context, DESIGN.md "
                "§5): " + ", ".join(skips))
    return out


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "dryrun_all.json")
    with open(path) as f:
        cells = json.load(f)
    for mesh in ("16x16", "2x16x16"):
        print(f"### mesh {mesh}\n")
        print(render(cells, mesh))
        print()


if __name__ == "__main__":
    main()
