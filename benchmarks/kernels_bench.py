"""Kernel-level benchmarks: structural metrics + CPU timings.

On CPU the Pallas kernels run in interpret mode (orders of magnitude slower
than compiled TPU), so kernel rows report STRUCTURAL metrics (panel
traffic, FLOP counts) as the derived value, plus jnp-path wall times for
regression tracking.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.morton_matmul.ops import panel_traffic
from repro.models.layers import blockwise_attention


def _time(f, *args, n=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n


def curve_panel_traffic() -> List[Dict]:
    rows = []
    for nm in (8, 16, 32):
        for order in ("rowmajor", "morton", "hilbert"):
            for cap in (1, 4):
                t = panel_traffic(nm, nm, order, capacity=cap)
                rows.append({"name": f"curve/{order}/grid{nm}/cap{cap}",
                             "us_per_call": 0.0,
                             "derived": f"{t}_panel_fetches"})
    return rows


def attention_paths() -> List[Dict]:
    rng = np.random.default_rng(0)
    B, S, H, K, D = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    rows = []
    for label, kwargs in [
            ("masked_scan", dict(skip_masked_blocks=False)),
            ("cond_skip", dict(skip_masked_blocks=True)),
            ("unrolled_static_skip", dict(unroll=True))]:
        fn = jax.jit(lambda q, k, v, kw=kwargs: blockwise_attention(
            q, k, v, causal=True, scale=D ** -0.5, block_q=128,
            block_kv=128, **kw))
        dt = _time(fn, q, k, v)
        rows.append({"name": f"attn/causal_{label}/S{S}",
                     "us_per_call": dt * 1e6,
                     "derived": f"{4 * S * S * D * H / 2 / dt / 1e9:.1f}GFLOPs"})
    return rows


def ssd_duality() -> List[Dict]:
    """Mamba-2 SSD duality (arXiv:2405.21060 Fig 10 analogue): the chunked
    algorithm's cost is linear in S while the fully-quadratic dual form is
    O(S^2) — the crossover justifies the chunked kernel for training."""
    from repro.kernels.ssd_scan.ref import ssd_ref
    from repro.models.ssm import _ssd_chunked
    rng = np.random.default_rng(5)
    B, H, P, N, chunk = 1, 4, 32, 64, 64
    rows = []
    for S in (256, 512, 1024):
        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
        dt = jax.nn.softplus(
            jnp.asarray(rng.normal(size=(B, S, H)), jnp.float32))
        A = -jnp.exp(jnp.asarray(rng.normal(size=(H,)), jnp.float32) * 0.5)
        Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        chunked = jax.jit(lambda *a: _ssd_chunked(*a, chunk)[0])
        quad = jax.jit(lambda *a: ssd_ref(*a)[0])
        t_c = _time(chunked, x, dt, A, Bm, Cm)
        t_q = _time(quad, x, dt, A, Bm, Cm)
        rows.append({"name": f"ssd/chunked/S{S}",
                     "us_per_call": t_c * 1e6,
                     "derived": f"quad_over_chunked={t_q / t_c:.1f}x"})
    return rows


def moe_padding_elision() -> List[Dict]:
    """Megablocks-style capacity skip (kernels/moe_gemm): fraction of MXU
    row-tiles elided for Zipf-imbalanced routing at several capacity
    factors — the structural win for §Perf Cell C."""
    rng = np.random.default_rng(6)
    E, T, k = 32, 8192, 8
    # Zipf-ish expert popularity, as routers actually produce
    pop = 1.0 / np.arange(1, E + 1)
    pop /= pop.sum()
    assignments = rng.choice(E, size=T * k, p=pop)
    counts = np.bincount(assignments, minlength=E)
    rows = []
    for cf in (1.0, 1.25, 2.0):
        C = int(cf * k * T / E)
        block = 128
        ntiles = -(-C // block) * E
        live = sum(-(-min(c, C) // block) for c in counts)
        rows.append({
            "name": f"moe_gemm/skip/cap{cf}",
            "us_per_call": 0.0,
            "derived": f"{1 - live / ntiles:.0%}_tiles_elided",
        })
    return rows
