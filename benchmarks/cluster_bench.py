"""Cluster + planned-cutout benchmarks (the PR's speed acceptance).

Two stories, paper-shaped:

  * ``planned vs loop``: the planned batch cutout (one `get_many` per run,
    one decompression per blob, vectorized assembly) against the seed
    per-cuboid Python loop (`cutout_loop`) on a >=256^3 volume — the
    speedup row is the BENCH_* trajectory the issue asks for.
  * ``shards``: the same cutout load over a `ClusterStore` with 1/2/4
    nodes (paper Fig 11: throughput from parallel spatially-partitioned
    nodes), plus the write->migrate path.

``BENCH_PRESET=tiny`` (or ``run.py --preset tiny``) shrinks the volume so
the CI smoke job finishes in seconds; the full preset keeps the 256^3
acceptance volume.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from repro.cluster import ClusterStore
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, cutout_loop, ingest, write_cutout
from repro.core.store import CuboidStore


def preset() -> str:
    return os.environ.get("BENCH_PRESET", "full")


def _shape():
    # acceptance: planned-vs-loop speedup measured on a >=256^3 volume
    return (64, 64, 64) if preset() == "tiny" else (256, 256, 256)


def _spec(shape):
    return DatasetSpec(name="cluster_bench", volume_shape=shape,
                       dtype="uint8", base_cuboid=(64, 64, 16))


def _boxes(shape, n, seed=11):
    """Unaligned cutout boxes covering ~1/8 of the volume each."""
    rng = np.random.default_rng(seed)
    size = tuple(max(8, s // 2) for s in shape)
    out = []
    for _ in range(n):
        lo = tuple(int(rng.integers(1, s - sz)) for s, sz in zip(shape, size))
        out.append((lo, tuple(l + sz for l, sz in zip(lo, size))))
    return out


def _timed(fn, boxes, repeats=1):
    t0 = time.perf_counter()
    for _ in range(repeats):
        for lo, hi in boxes:
            fn(lo, hi)
    return (time.perf_counter() - t0) / (repeats * len(boxes))


def planned_vs_loop() -> List[Dict]:
    shape = _shape()
    store = CuboidStore(_spec(shape))
    vol = np.random.default_rng(5).integers(0, 255, size=shape,
                                            dtype=np.uint8)
    ingest(store, 0, vol)
    boxes = _boxes(shape, n=4)
    t_loop = _timed(lambda lo, hi: cutout_loop(store, 0, lo, hi), boxes)
    t_plan = _timed(lambda lo, hi: cutout(store, 0, lo, hi), boxes)
    mb = float(np.prod([s // 2 for s in shape])) / 1e6
    return [
        {"name": f"cluster/loop/{shape[0]}", "us_per_call": t_loop * 1e6,
         "derived": f"{mb / t_loop:.1f}MBps"},
        {"name": f"cluster/planned/{shape[0]}", "us_per_call": t_plan * 1e6,
         "derived": f"{mb / t_plan:.1f}MBps"},
        {"name": f"cluster/planned_speedup/{shape[0]}", "us_per_call": 0.0,
         "derived": f"{t_loop / t_plan:.2f}x_vs_loop"},
    ]


def shard_scaling() -> List[Dict]:
    shape = _shape()
    vol = np.random.default_rng(6).integers(0, 255, size=shape,
                                            dtype=np.uint8)
    boxes = _boxes(shape, n=4, seed=12)
    rows = []
    for n_nodes in (1, 2, 4):
        cluster = ClusterStore(_spec(shape), n_nodes=n_nodes)
        t0 = time.perf_counter()
        write_cutout(cluster, 0, (0, 0, 0), vol)
        t_write = time.perf_counter() - t0
        t_read = _timed(lambda lo, hi: cutout(cluster, 0, lo, hi), boxes)
        t0 = time.perf_counter()
        n_migrated = cluster.migrate()
        t_migrate = time.perf_counter() - t0
        mb = vol.nbytes / 1e6
        rows.append({"name": f"cluster/shards{n_nodes}/read",
                     "us_per_call": t_read * 1e6,
                     "derived": f"{(mb / 8) / t_read:.1f}MBps"})
        rows.append({"name": f"cluster/shards{n_nodes}/write_migrate",
                     "us_per_call": (t_write + t_migrate) * 1e6,
                     "derived": f"migrated{n_migrated}"})
        cluster.close()
    return rows


def rows() -> List[Dict]:
    return planned_vs_loop() + shard_scaling()
