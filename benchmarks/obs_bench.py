"""Observability overhead benchmarks (the tracing tier's own cost).

Tracing must be *always-on-cheap*: every request pays the sampling
decision and every instrumented stage pays one no-op span when the
request is untraced.  Three rows quantify that:

  * ``null_span``: the untraced instrumentation primitive itself — one
    ``with span(...)`` on an inactive trace (a single ContextVar read).
  * ``warm_cutout_untraced``: the warm-cutout path with instrumentation
    compiled in but no trace active; derived carries p50/p99 from a
    latency histogram plus the *estimated* untraced overhead — spans
    per request (counted from a traced run) x the null-span cost, as a
    fraction of the request p50.  The acceptance bar is <= 5%.
  * ``warm_cutout_traced``: the same loop with every request sampled,
    so the full cost of recording spans is visible as a ratio.

``BENCH_PRESET=tiny`` shrinks volumes for the CI smoke job.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from repro.cluster import ClusterStore
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest
from repro.obs import trace
from repro.obs.hist import Histogram, describe


def preset() -> str:
    return os.environ.get("BENCH_PRESET", "full")


def _shape():
    return (64, 64, 32) if preset() == "tiny" else (128, 128, 64)


def _boxes(shape, n, size, seed=29):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        lo = [int(rng.integers(0, s - size)) for s in shape]
        out.append((lo, [a + size for a in lo]))
    return out


def rows() -> List[Dict]:
    shape = _shape()
    vol = np.random.default_rng(11).integers(1, 255, size=shape,
                                             dtype=np.uint8)
    spec = DatasetSpec(name="obs_bench", volume_shape=shape, dtype="uint8",
                       base_cuboid=(16, 16, 8))
    store = ClusterStore(spec, n_nodes=2, cache_bytes=64 << 20)
    ingest(store, 0, vol)
    boxes = _boxes(shape, 8, size=16)
    reps = 20 if preset() == "tiny" else 60

    # warm the cache so both timed loops ride the hit path
    for lo, hi in boxes:
        cutout(store, 0, lo, hi)

    # null-span microbench: the entire untraced cost of one instrumented
    # stage (no trace is active here, whatever REPRO_TRACE_SAMPLE says)
    n_null = 200_000
    t0 = time.perf_counter()
    for _ in range(n_null):
        with trace.span("bench"):
            pass
    t_null = (time.perf_counter() - t0) / n_null

    h_untraced = Histogram()
    for i in range(reps):
        lo, hi = boxes[i % len(boxes)]
        with h_untraced.time():
            cutout(store, 0, lo, hi)

    h_traced = Histogram()
    last_id = ""
    for i in range(reps):
        lo, hi = boxes[i % len(boxes)]
        last_id = f"obsbench{i:08x}"  # explicit id -> always sampled
        ctx = trace.maybe_start(last_id)
        with trace.activate(ctx), h_traced.time(), trace.span("request"):
            cutout(store, 0, lo, hi)
    spans_per_req = len(trace.trace_spans(last_id))
    store.close()

    p50 = h_untraced.percentile(0.5)
    est_pct = 100.0 * spans_per_req * t_null / p50 if p50 else 0.0
    ratio = (h_traced.percentile(0.5) / p50) if p50 else 0.0
    return [
        {"name": "obs/null_span",
         "us_per_call": t_null * 1e6,
         "derived": f"untraced_with_span;{n_null}iters"},
        {"name": f"obs/warm_cutout_untraced/{shape[0]}",
         "us_per_call": h_untraced.mean * 1e6,
         "derived": (f"{describe(h_untraced)}"
                     f";spans_per_req={spans_per_req}"
                     f";est_untraced_overhead={est_pct:.2f}%")},
        {"name": f"obs/warm_cutout_traced/{shape[0]}",
         "us_per_call": h_traced.mean * 1e6,
         "derived": (f"{describe(h_traced)}"
                     f";p50_x_vs_untraced={ratio:.3f}")},
    ]
