"""Cache-tier benchmarks (the paper §6 memcached/SSD vision, measured).

Two stories:

  * ``repeated``: the dominant vision-pipeline access pattern — the same
    region cut out over and over (model training sweeps, proofreading
    views).  A disk-backed store is measured cold (cacheless) and warm
    (hot-cuboid cache): the warm path serves decoded cuboids from memory,
    skipping file I/O *and* decompression.  The speedup row is the PR's
    acceptance number (>= 3x), and every cached cutout is verified
    bit-identical against the cacheless result across 1/2/4 shards.
  * ``burst``: bursty small writes through the write-behind ingest queue —
    the submit-side latency the client sees (queue absorbs the burst)
    vs. the synchronous write path, plus the explicit ``flush()`` barrier
    cost that makes the burst durable.

``BENCH_PRESET=tiny`` shrinks volumes for the CI smoke job.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.cluster import ClusterStore
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest, write_cutout
from repro.core.store import CuboidStore, DirectoryBackend


def preset() -> str:
    return os.environ.get("BENCH_PRESET", "full")


def _shape():
    return (64, 64, 64) if preset() == "tiny" else (256, 256, 256)


def _spec(shape):
    return DatasetSpec(name="cache_bench", volume_shape=shape,
                       dtype="uint8", base_cuboid=(32, 32, 16))


def _boxes(shape, n, seed=21):
    rng = np.random.default_rng(seed)
    size = tuple(max(8, s // 2) for s in shape)
    out = []
    for _ in range(n):
        lo = tuple(int(rng.integers(1, s - sz)) for s, sz in zip(shape, size))
        out.append((lo, tuple(l + sz for l, sz in zip(lo, size))))
    return out


def _timed(fn, boxes, repeats):
    t0 = time.perf_counter()
    for _ in range(repeats):
        for lo, hi in boxes:
            fn(lo, hi)
    return (time.perf_counter() - t0) / (repeats * len(boxes))


def repeated_cutout() -> List[Dict]:
    """Warm-cache repeated cutouts vs. the cacheless disk path."""
    shape = _shape()
    vol = np.random.default_rng(7).integers(0, 255, size=shape,
                                            dtype=np.uint8)
    boxes = _boxes(shape, n=4)
    repeats = 3
    rows = []
    with tempfile.TemporaryDirectory(prefix="ocp-cache-bench-") as root:
        cold = CuboidStore(_spec(shape), backend=DirectoryBackend(root))
        ingest(cold, 0, vol)
        t_cold = _timed(lambda lo, hi: cutout(cold, 0, lo, hi), boxes,
                        repeats)
        # warm path: same directory tree, hot-cuboid cache in front
        warm = CuboidStore(_spec(shape), backend=DirectoryBackend(root))
        from repro.cluster import attach_cache
        attach_cache(warm, max(64 << 20, 4 * vol.nbytes))
        for lo, hi in boxes:  # warm the working set
            cutout(warm, 0, lo, hi)
        t_warm = _timed(lambda lo, hi: cutout(warm, 0, lo, hi), boxes,
                        repeats)
        # acceptance: cached results bit-identical to the cacheless path
        identical = all(
            np.array_equal(cutout(warm, 0, lo, hi), cutout(cold, 0, lo, hi))
            for lo, hi in boxes)
        hits = warm.read_stats.cache_hits
        misses = warm.read_stats.cache_misses
    mb = float(np.prod([max(8, s // 2) for s in shape])) / 1e6
    rows.append({"name": f"cache/cold/{shape[0]}",
                 "us_per_call": t_cold * 1e6,
                 "derived": f"{mb / t_cold:.1f}MBps"})
    rows.append({"name": f"cache/warm/{shape[0]}",
                 "us_per_call": t_warm * 1e6,
                 "derived": f"{mb / t_warm:.1f}MBps"})
    rows.append({"name": f"cache/warm_speedup/{shape[0]}",
                 "us_per_call": 0.0,
                 "derived": (f"{t_cold / t_warm:.2f}x_vs_cold"
                             f";identical={identical}"
                             f";hits={hits};misses={misses}")})
    return rows


def shard_identity() -> List[Dict]:
    """Cached vs. uncached cutouts bit-identical across 1/2/4 shards."""
    shape = tuple(min(s, 64) for s in _shape())
    vol = np.random.default_rng(8).integers(0, 255, size=shape,
                                            dtype=np.uint8)
    boxes = _boxes(shape, n=3, seed=22)
    rows = []
    for n_nodes in (1, 2, 4):
        plain = ClusterStore(_spec(shape), n_nodes=n_nodes,
                             cache_bytes=0, write_behind=False)
        cached = ClusterStore(_spec(shape), n_nodes=n_nodes,
                              cache_bytes=64 << 20, write_behind=True)
        ingest(plain, 0, vol)
        ingest(cached, 0, vol)
        identical = all(
            np.array_equal(cutout(cached, 0, lo, hi),
                           cutout(plain, 0, lo, hi))
            for lo, hi in boxes for _ in range(2))  # cold + warm pass
        rows.append({"name": f"cache/identity/shards{n_nodes}",
                     "us_per_call": 0.0,
                     "derived": f"identical={identical}"})
        plain.close()
        cached.close()
    return rows


def burst_ingest() -> List[Dict]:
    """Small-write bursts: write-behind submit latency vs. sync writes."""
    shape = tuple(min(s, 128) for s in _shape())
    spec = _spec(shape)
    patch_shape = (32, 32, 16)
    n_patches = 16 if preset() == "tiny" else 64
    rng = np.random.default_rng(9)
    patches = []
    for _ in range(n_patches):
        lo = tuple(int(rng.integers(0, s - p))
                   for s, p in zip(shape, patch_shape))
        patches.append((lo, rng.integers(1, 255, size=patch_shape,
                                         dtype=np.uint8)))
    rows = []
    with tempfile.TemporaryDirectory(prefix="ocp-burst-bench-") as root:
        def disk_factory(i, s):
            return CuboidStore(
                s, backend=DirectoryBackend(os.path.join(root, f"sync{i}")))

        sync = ClusterStore(spec, n_nodes=2, node_factory=disk_factory,
                            cache_bytes=0, write_behind=False)
        t0 = time.perf_counter()
        for lo, data in patches:
            write_cutout(sync, 0, lo, data)
        t_sync = (time.perf_counter() - t0) / n_patches
        sync.close()

        def disk_factory2(i, s):
            return CuboidStore(
                s, backend=DirectoryBackend(os.path.join(root, f"wb{i}")))

        wb = ClusterStore(spec, n_nodes=2, node_factory=disk_factory2,
                          cache_bytes=64 << 20, write_behind=True,
                          write_behind_items=4 * n_patches)
        t0 = time.perf_counter()
        for lo, data in patches:
            write_cutout(wb, 0, lo, data)
        t_submit = (time.perf_counter() - t0) / n_patches
        t0 = time.perf_counter()
        drained = wb.flush()
        t_flush = time.perf_counter() - t0
        q = wb.queue_counters()
        wb.close()
    rows.append({"name": f"cache/burst_sync/{shape[0]}",
                 "us_per_call": t_sync * 1e6,
                 "derived": f"{n_patches}patches"})
    rows.append({"name": f"cache/burst_submit/{shape[0]}",
                 "us_per_call": t_submit * 1e6,
                 "derived": f"{t_sync / t_submit:.2f}x_vs_sync"
                            f";peak_depth={q['depth_peak']}"})
    rows.append({"name": f"cache/burst_flush/{shape[0]}",
                 "us_per_call": t_flush * 1e6,
                 "derived": f"drained{drained}"})
    return rows


def rows() -> List[Dict]:
    return repeated_cutout() + shard_identity() + burst_ingest()
