"""Fault-tolerance benchmarks (ISSUE 10: the always-on service story).

One story, three phases: p99 cutout-read latency and client-visible error
rate on a 3-node replication-2 cluster — **healthy**, **during a node
kill** (a live owner crashed mid-traffic, reads failing over on the
degraded path while the supervisor detects and promotes), and **after
automatic failover** (topology shrunk, replication healed back to target
with no operator call).  A ``heal`` row reports the closed-loop recovery
time from kill to healed replication.

Every sampled cutout is verified bit-identical against the ingested
volume — a fast wrong answer is not a data point.

``BENCH_PRESET=tiny`` shrinks volumes for the CI smoke job.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.cluster import ClusterStore
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest
from repro.ft import ClusterWatch, FaultPlan, StorageSupervisor, faulty_factory


def preset() -> str:
    return os.environ.get("BENCH_PRESET", "full")


def _shape():
    return (64, 64, 64) if preset() == "tiny" else (128, 128, 128)


def _spec(shape):
    return DatasetSpec(name="faults_bench", volume_shape=shape,
                       dtype="uint8", base_cuboid=(32, 32, 16))


def _boxes(shape, n, seed=23):
    rng = np.random.default_rng(seed)
    size = tuple(max(8, s // 4) for s in shape)
    out = []
    for _ in range(n):
        lo = tuple(int(rng.integers(0, s - sz)) for s, sz in zip(shape, size))
        out.append((lo, tuple(l + sz for l, sz in zip(lo, size))))
    return out


def _p99(samples: List[float]) -> float:
    return float(np.percentile(samples, 99)) if samples else 0.0


def _sample(cluster, vol, boxes, n, seed) -> Tuple[List[float], int]:
    """n verified cutout reads: (latencies of the successes, error count)."""
    rng = np.random.default_rng(seed)
    lats: List[float] = []
    errors = 0
    for _ in range(n):
        lo, hi = boxes[int(rng.integers(0, len(boxes)))]
        t0 = time.perf_counter()
        try:
            got = cutout(cluster, 0, lo, hi)
        except Exception:
            errors += 1
            continue
        lats.append(time.perf_counter() - t0)
        sl = tuple(slice(a, b) for a, b in zip(lo, hi))
        if not np.array_equal(got, vol[sl]):
            raise AssertionError(f"stale read at {lo}..{hi}")
    return lats, errors


def kill_and_heal() -> List[Dict]:
    shape = _shape()
    n = 20 if preset() == "tiny" else 60
    vol = np.random.default_rng(11).integers(0, 255, size=shape,
                                             dtype=np.uint8)
    plans = {i: FaultPlan(seed=i) for i in range(3)}
    fac = faulty_factory(plans=plans)
    cluster = ClusterStore(_spec(shape), n_nodes=3, replication=2,
                           node_factory=fac)
    ingest(cluster, 0, vol)
    boxes = _boxes(shape, n=6)

    # phase 1: healthy baseline
    lats_healthy, errs_healthy = _sample(cluster, vol, boxes, n, seed=3)

    # phase 2: kill a live owner; reads fail over on the degraded path
    # while the supervisor detects, declares dead, and promotes replicas
    sup = StorageSupervisor(cluster, watch=ClusterWatch(cluster, dead_ticks=2),
                            interval=0.02)
    fac.built[1].crash()
    t_kill = time.perf_counter()
    sup.start()
    lats_kill, errs_kill = _sample(cluster, vol, boxes, n, seed=5)
    # wait for the closed loop: topology shrunk AND replication healed
    deadline = time.monotonic() + 120.0
    healed = False
    while time.monotonic() < deadline:
        topo = cluster.topology()
        if (topo["n_nodes"] == 2 and not topo["rebalancing"]
                and topo.get("replication") == topo.get("replication_target")):
            healed = True
            break
        time.sleep(0.02)
    t_heal = time.perf_counter() - t_kill
    sup.stop()

    # phase 3: after automatic failover
    lats_after, errs_after = _sample(cluster, vol, boxes, n, seed=7)
    cluster.close()

    def rate(errs, total):
        return errs / max(1, total)

    return [
        {"name": f"faults/read_healthy_p99/{shape[0]}",
         "us_per_call": _p99(lats_healthy) * 1e6,
         "derived": f"{len(lats_healthy)}samples"
                    f";err_rate={rate(errs_healthy, n):.3f}"},
        {"name": f"faults/read_during_kill_p99/{shape[0]}",
         "us_per_call": _p99(lats_kill) * 1e6,
         "derived": f"{len(lats_kill)}samples"
                    f";err_rate={rate(errs_kill, n):.3f}"},
        {"name": f"faults/read_after_failover_p99/{shape[0]}",
         "us_per_call": _p99(lats_after) * 1e6,
         "derived": f"{len(lats_after)}samples"
                    f";err_rate={rate(errs_after, n):.3f}"},
        {"name": f"faults/failover_heal/{shape[0]}",
         "us_per_call": t_heal * 1e6,
         "derived": f"healed={healed};kill_to_replication_target"},
    ]


def rows() -> List[Dict]:
    return kill_and_heal()
