"""Laptop-scale analogues of the paper's Figures 10-13.

The absolute numbers differ from the 2013 Dell cluster, but each experiment
preserves the paper's *shape*: what is varied, what is measured, and which
effect must appear (alignment gap, concurrency scaling, write collapse,
write-path offload).
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import tempfile
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.annotations import Annotation, AnnotationProject
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest
from repro.core.store import CuboidStore, DirectoryBackend, MemoryBackend

CUBOID = (64, 64, 16)


def _tiny() -> bool:
    """CI smoke preset (run.py --preset tiny): fewer/smaller requests."""
    return os.environ.get("BENCH_PRESET") == "tiny"


def _sizes():
    return (32, 64) if _tiny() else (32, 64, 128)


def _make_volume(shape=(256, 256, 64), seed=0, entropy="high"):
    rng = np.random.default_rng(seed)
    if entropy == "high":     # EM-like: compresses <10% (paper §5)
        return rng.integers(0, 255, size=shape, dtype=np.uint8)
    vol = np.zeros(shape, dtype=np.uint8)   # annotation-like: low entropy
    vol[::4, ::4] = rng.integers(0, 8, size=(shape[0] // 4,
                                             shape[1] // 4, shape[2]))
    return vol


def _store(backend=None, shape=(256, 256, 64), dtype="uint8"):
    spec = DatasetSpec(name="bench", volume_shape=shape, dtype=dtype,
                       base_cuboid=CUBOID)
    return CuboidStore(spec, backend=backend)


def _timed_cutouts(store, boxes, n_workers=1) -> Tuple[float, float]:
    """Returns (seconds, MB moved)."""
    total = sum(float(np.prod([h - l for l, h in zip(lo, hi)]))
                for lo, hi in boxes)
    t0 = time.perf_counter()
    if n_workers == 1:
        for lo, hi in boxes:
            cutout(store, 0, lo, hi)
    else:
        with cf.ThreadPoolExecutor(max_workers=n_workers) as ex:
            list(ex.map(lambda b: cutout(store, 0, *b), boxes))
    return time.perf_counter() - t0, total / 1e6


def fig10_cutout_throughput() -> List[Dict]:
    """Throughput vs cutout size x {memory-aligned, disk-aligned,
    unaligned}. Expected shape (paper): aligned-in-memory > disk-aligned >
    unaligned; throughput grows with size as fixed costs amortize."""
    vol = _make_volume()
    mem_store = _store()
    ingest(mem_store, 0, vol)
    tmp = tempfile.mkdtemp(prefix="ocp_bench_")
    disk_store = _store(DirectoryBackend(tmp))
    ingest(disk_store, 0, vol)
    rng = np.random.default_rng(1)
    rows = []
    for size in _sizes():
        n_req = max(2, 16 // (size // 32))
        aligned, unaligned = [], []
        for _ in range(n_req):
            gx = rng.integers(0, (256 - size) // 64 + 1) * 64
            gz = rng.integers(0, max(1, (64 - size // 4) // 16)) * 16
            aligned.append(((gx, gx, gz),
                            (gx + size, gx + size, gz + size // 4)))
            ox = int(rng.integers(1, 250 - size))
            oz = int(rng.integers(1, 60 - size // 4))
            unaligned.append(((ox, ox, oz),
                              (ox + size, ox + size, oz + size // 4)))
        for label, store, boxes in [
                ("aligned_memory", mem_store, aligned),
                ("aligned_disk", disk_store, aligned),
                ("unaligned", mem_store, unaligned)]:
            dt, mb = _timed_cutouts(store, boxes)
            rows.append({"name": f"fig10/{label}/{size}",
                         "us_per_call": dt / len(boxes) * 1e6,
                         "derived": f"{mb / dt:.1f}MBps"})
    return rows


def fig11_concurrency() -> List[Dict]:
    """Throughput vs #parallel requests (paper: scales past core count,
    degrades with oversubscription)."""
    vol = _make_volume()
    store = _store()
    ingest(store, 0, vol)
    rng = np.random.default_rng(2)
    boxes = []
    for _ in range(32):
        x = int(rng.integers(0, 192))
        z = int(rng.integers(0, 48))
        boxes.append(((x, x, z), (x + 64, x + 64, z + 16)))
    rows = []
    for workers in ((1, 4) if _tiny() else (1, 2, 4, 8)):
        dt, mb = _timed_cutouts(store, boxes, n_workers=workers)
        rows.append({"name": f"fig11/parallel/{workers}",
                     "us_per_call": dt / len(boxes) * 1e6,
                     "derived": f"{mb / dt:.1f}MBps"})
    return rows


def fig12_annotation_write() -> List[Dict]:
    """Annotation write throughput vs region size (paper: write path is
    read-modify-write + index maintenance; throughput collapses for large
    regions relative to reads)."""
    spec = DatasetSpec(name="img", volume_shape=(256, 256, 64),
                       dtype="uint8", base_cuboid=CUBOID)
    rows = []
    rng = np.random.default_rng(3)
    for size in _sizes():
        proj = AnnotationProject("w", spec)
        labels = (rng.integers(1, 6, size=(size, size, size // 4))
                  .astype(np.uint32))      # >90% labeled, low entropy
        mb = labels.nbytes / 1e6
        t0 = time.perf_counter()
        a = proj.meta.create()
        proj.write(0, (0, 0, 0), np.where(labels > 0,
                                          np.uint32(a.ann_id), 0))
        dt = time.perf_counter() - t0
        rows.append({"name": f"fig12/annotation_write/{size}",
                     "us_per_call": dt * 1e6,
                     "derived": f"{mb / dt:.1f}MBps_uncompressed"})
    # read-back comparison at one size (paper: writes << reads)
    t0 = time.perf_counter()
    proj.read(0, (0, 0, 0), (128, 128, 32))
    dt_read = time.perf_counter() - t0
    rows.append({"name": "fig12/read_same_region/128",
                 "us_per_call": dt_read * 1e6,
                 "derived": f"{(128 * 128 * 32 * 4 / 1e6) / dt_read:.1f}MBps"})
    return rows


def fig13_write_paths() -> List[Dict]:
    """Small random synapse writes: dedicated write path (SSD node) vs
    writing through the read path (database node). Paper: the SSD node
    sustains >150% of the DB node on random small writes."""
    spec = DatasetSpec(name="img", volume_shape=(256, 256, 64),
                       dtype="uint8", base_cuboid=CUBOID)
    rng = np.random.default_rng(4)

    def synapse_batch(n=64):
        out = []
        for _ in range(n):
            pos = (int(rng.integers(0, 250)), int(rng.integers(0, 250)),
                   int(rng.integers(0, 60)))
            vol = np.ones((4, 4, 2), np.uint32)
            out.append((Annotation(0, ann_type="synapse",
                                   confidence=float(rng.random())),
                        pos, vol))
        return out

    rows = []
    tmp = tempfile.mkdtemp(prefix="ocp_f13_")
    for label, kwargs in [
            ("db_node", dict(backend=DirectoryBackend(tmp))),
            ("ssd_node", dict(write_path_backend=MemoryBackend()))]:
        proj = AnnotationProject("s", spec, **kwargs)
        batch = synapse_batch()
        t0 = time.perf_counter()
        proj.batch_write_objects(0, batch)
        dt = time.perf_counter() - t0
        rows.append({"name": f"fig13/{label}",
                     "us_per_call": dt / len(batch) * 1e6,
                     "derived": f"{len(batch) / dt:.1f}_objects_per_s"})
    return rows
