"""HTTP front-door benchmarks (the paper's Web-services tier on a socket).

Three stories, all over a real ephemeral-port `ThreadingHTTPServer`:

  * ``req/s under the admission limiter``: concurrent clients hammer
    small cutout GETs; rows report sustained requests/s, how many rode a
    coalesced batch, and how many were shed (503) — the front door must
    degrade by refusing, not by collapsing.
  * ``during-failover read latency``: reader threads sample cutouts over
    HTTP while ``DELETE /nodes/<i>`` decommissions a live owner of a
    replication-2 cluster; rows report baseline vs during-failover
    latency and ``lost_reads`` (non-200 or bit-different responses —
    must be 0).
  * ``wire overhead``: the same cutout in-process vs over HTTP (raw and
    zlib), isolating serialization + socket cost.

``BENCH_PRESET=tiny`` shrinks volumes for the CI smoke job.
"""
from __future__ import annotations

import json
import os
import threading
import time
import urllib.request
from typing import Dict, List

import numpy as np

from repro.cluster import ClusterStore, VolumeService
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest
from repro.obs.hist import Histogram, describe
from repro.serve.http_front import FrontDoor


def preset() -> str:
    return os.environ.get("BENCH_PRESET", "full")


def _shape():
    return (64, 64, 32) if preset() == "tiny" else (128, 128, 64)


def _spec(shape):
    return DatasetSpec(name="frontdoor_bench", volume_shape=shape,
                       dtype="uint8", base_cuboid=(16, 16, 8))


def _get(url):
    with urllib.request.urlopen(url, timeout=30) as resp:
        return resp.status, dict(resp.headers), resp.read()


def _boxes(shape, n, size, seed=23):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        lo = [int(rng.integers(0, s - size)) for s in shape]
        out.append((lo, [l + size for l in lo]))
    return out


def _box_url(base, lo, hi):
    box = "/".join(f"{a},{b}" for a, b in zip(lo, hi))
    return f"{base}/frontdoor/cutout/0/{box}"


def throughput_rows() -> List[Dict]:
    shape = _shape()
    vol = np.random.default_rng(3).integers(1, 255, size=shape,
                                            dtype=np.uint8)
    store = ClusterStore(_spec(shape), n_nodes=2, replication=2,
                         cache_bytes=32 << 20)
    ingest(store, 0, vol)
    service = VolumeService()
    service.add_dataset("frontdoor", store)
    n_clients = 4 if preset() == "tiny" else 8
    n_reqs = 20 if preset() == "tiny" else 60
    boxes = _boxes(shape, 12, size=16)
    rows: List[Dict] = []
    with FrontDoor(service) as door:
        failures = [0]
        lat = Histogram()  # shared: observe() is thread-safe

        def client(tid):
            rng = np.random.default_rng(60 + tid)
            for _ in range(n_reqs):
                lo, hi = boxes[int(rng.integers(0, len(boxes)))]
                with lat.time():
                    status, _h, _p = _get(_box_url(door.url, lo, hi))
                if status != 200:
                    failures[0] += 1

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        dt = time.perf_counter() - t0
        counters = door.counters()
        total = n_clients * n_reqs
        rows.append({
            "name": f"frontdoor/req_s/{shape[0]}",
            "us_per_call": dt / total * 1e6,
            "derived": (f"{total / dt:.0f}req_s;{n_clients}clients"
                        f";admit={door.admit_limit}"
                        f";coalesced={counters.get('coalesced', 0)}"
                        f";shed={counters['shed']}"
                        f";failures={failures[0]}"
                        f";{describe(lat)}")})

        # wire overhead: one box, in-process vs raw HTTP vs zlib HTTP
        lo, hi = boxes[0]
        reps = 10 if preset() == "tiny" else 30
        t0 = time.perf_counter()
        for _ in range(reps):
            cutout(store, 0, lo, hi)
        t_proc = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            _get(_box_url(door.url, lo, hi))
        t_raw = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            _get(_box_url(door.url, lo, hi) + "?encode=zlib")
        t_zlib = (time.perf_counter() - t0) / reps
        rows.append({
            "name": f"frontdoor/wire_overhead/{shape[0]}",
            "us_per_call": (t_raw - t_proc) * 1e6,
            "derived": (f"inproc={t_proc * 1e6:.0f}us"
                        f";http_raw={t_raw * 1e6:.0f}us"
                        f";http_zlib={t_zlib * 1e6:.0f}us")})
    store.close()
    return rows


def failover_rows() -> List[Dict]:
    shape = _shape()
    vol = np.random.default_rng(5).integers(1, 255, size=shape,
                                            dtype=np.uint8)
    store = ClusterStore(_spec(shape), n_nodes=3, replication=2)
    ingest(store, 0, vol)
    store.flush()
    service = VolumeService()
    service.add_dataset("frontdoor", store)
    boxes = _boxes(shape, 8, size=8, seed=71)
    with FrontDoor(service) as door:
        # baseline latency against the steady 3-node topology
        h_before = Histogram()
        for lo, hi in boxes:
            with h_before.time():
                _get(_box_url(door.url, lo, hi))

        h_during = Histogram()  # thread-safe: readers observe directly
        lost = [0]
        stop = threading.Event()
        lock = threading.Lock()

        def reader(seed):
            rng = np.random.default_rng(seed)
            while not stop.is_set():
                lo, hi = boxes[int(rng.integers(0, len(boxes)))]
                t0 = time.perf_counter()
                try:
                    status, headers, payload = _get(_box_url(door.url, lo, hi))
                except Exception:
                    status, payload = 0, b""
                h_during.observe(time.perf_counter() - t0)
                ok = status == 200
                if ok:
                    got = np.frombuffer(
                        payload, dtype=headers["X-Dtype"]).reshape(
                        tuple(int(s) for s in headers["X-Shape"].split(",")))
                    sl = tuple(slice(a, b) for a, b in zip(lo, hi))
                    ok = np.array_equal(got, vol[sl])
                if not ok:
                    with lock:
                        lost[0] += 1

        threads = [threading.Thread(target=reader, args=(81 + i,))
                   for i in range(2)]
        for t in threads:
            t.start()
        t0 = time.perf_counter()
        req = urllib.request.Request(f"{door.url}/frontdoor/nodes/1",
                                     method="DELETE")
        with urllib.request.urlopen(req, timeout=300) as resp:
            assert json.loads(resp.read())["n_nodes"] == 2
        t_failover = time.perf_counter() - t0
        stop.set()
        for t in threads:
            t.join(timeout=60)
    store.close()
    mean_before = h_before.mean
    mean_during = h_during.mean if h_during.count else mean_before
    return [
        {"name": f"frontdoor/failover/{shape[0]}",
         "us_per_call": t_failover * 1e6,
         "derived": "remove_node_live_owner;replication=2"},
        {"name": f"frontdoor/read_during_failover/{shape[0]}",
         "us_per_call": mean_during * 1e6,
         "derived": (f"{mean_during / mean_before:.2f}x_vs_baseline"
                     f";{describe(h_during)}"
                     f";lost_reads={lost[0]}")},
    ]


def rows() -> List[Dict]:
    return throughput_rows() + failover_rows()
