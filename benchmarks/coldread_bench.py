"""Cold-read pipeline benchmarks (paper §5: cutouts are assembly-bound).

Three read paths over the same disk-backed volume, every cutout cold
(cache empty, page-cache warm — isolating the *assembly* cost the paper
measures):

  * ``serial``   — the pre-pipeline cold path, reproduced verbatim as the
    baseline this PR replaced: the fan-out returns compressed blobs, the
    caller thread decodes every one serially, places them through an
    intermediate dict second pass, and always copies the result through
    the trim.
  * ``parallel`` — the pipelined path without prefetch: fetch + decode
    chunked across the decode pool, each worker assembling straight into
    the shared output buffer, aligned requests returned zero-copy.
  * ``pipelined`` — parallel plus plan-driven segment prefetch: the next
    curve segments stream into the hot-cuboid cache while the current one
    decodes (the cache is cleared before every rep, so each read is cold;
    prefetch hits are *within* one cutout's schedule).

The speedup and prefetch hit-rate rows are the PR's acceptance numbers,
and every policy's output is verified bit-identical to ``cutout_loop``
(the correctness oracle) across 1/2/4 shards.

``BENCH_PRESET=tiny`` shrinks volumes for the CI smoke job.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.cluster import ClusterStore, attach_cache
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, cutout_loop, ingest, plan_cutout
from repro.core.store import (CuboidStore, DecodePolicy, DirectoryBackend,
                              decompress)


def _cuboid():
    # tiny keeps a multi-run schedule (so prefetch still engages) without
    # drowning the smoke job in per-file overhead
    return (16, 16, 16) if preset() == "tiny" else (32, 32, 16)


def serial_decode_cutout(store, r, lo, hi):
    """The pre-pipeline cold path this PR replaced (the PR 1 planned
    read): one batch blob fetch, then every blob decoded serially in the
    caller thread, placed via an intermediate per-key dict, and the
    result always copied through the trim."""
    grid = store.spec.grid(r)
    lo, hi = grid.clamp_box(lo, hi)
    dtype = np.dtype(store.spec.dtype)
    plan = plan_cutout(grid, r, lo, hi)
    buf = np.zeros(plan.buf_shape, dtype=dtype)
    cshape = grid.cuboid_shape
    blobs = store.fetch_runs(r, plan.runs)
    for m, sl, keep in zip(plan.cells, plan.buf_slices, plan.keep_shapes):
        blob = blobs.get(int(m))
        if blob is None:
            continue
        block = decompress(blob, cshape, dtype)
        buf[sl] = block[tuple(slice(0, s) for s in keep)]
    return np.ascontiguousarray(buf[plan.trim])


def preset() -> str:
    return os.environ.get("BENCH_PRESET", "full")


def _shape():
    return (64, 64, 64) if preset() == "tiny" else (256, 256, 256)


def _spec(shape):
    return DatasetSpec(name="coldread_bench", volume_shape=shape,
                       dtype="uint8", base_cuboid=_cuboid())


def _volume(shape):
    """Structured-plus-noise data: compresses ~2-4x like real EM imagery,
    so decompress cost (the assembly bound) is realistic — pure random
    bytes would make zlib a near-memcpy and hide the decode work."""
    rng = np.random.default_rng(11)
    x = np.linspace(0.0, 8 * np.pi, shape[0], dtype=np.float32)
    y = np.linspace(0.0, 6 * np.pi, shape[1], dtype=np.float32)
    base = (96.0 + 64.0 * np.sin(x)[:, None, None]
            + 48.0 * np.cos(y)[None, :, None])
    noise = rng.integers(0, 24, size=shape).astype(np.float32)
    return np.clip(base + noise, 0, 255).astype(np.uint8)


def _policies():
    workers = max(2, os.cpu_count() or 2)
    chunk = 8 if preset() == "tiny" else 32
    return {
        "serial": DecodePolicy(workers=0, prefetch_segments=0),
        "parallel": DecodePolicy(workers=workers, chunk=chunk,
                                 prefetch_segments=0),
        "pipelined": DecodePolicy(workers=workers, chunk=chunk,
                                  prefetch_segments=2),
    }


def _timed_cold(read, store, boxes, repeats, clear=None):
    """Best-of-``repeats`` per box (medians drown in scheduler noise on
    shared runners), averaged across boxes."""
    per_box = []
    for lo, hi in boxes:
        best = float("inf")
        for _ in range(repeats):
            if clear is not None:
                clear()
            t0 = time.perf_counter()
            read(store, 0, lo, hi)
            best = min(best, time.perf_counter() - t0)
        per_box.append(best)
    return sum(per_box) / len(per_box)


def pipeline_rows() -> List[Dict]:
    shape = _shape()
    vol = _volume(shape)
    # One aligned full-volume read (a single giant run: pure fetch+decode
    # pipelining) and one offset box (a multi-run schedule: segment
    # prefetch engages) — together the shapes real §4.2 traffic takes.
    boxes = [((0, 0, 0), shape), (_cuboid(), shape)]
    repeats = 2 if preset() == "tiny" else 3
    mb = float(np.mean([np.prod([h - l for l, h in zip(lo, hi)])
                        for lo, hi in boxes])) / 1e6
    rows: List[Dict] = []
    times: Dict[str, float] = {}
    with tempfile.TemporaryDirectory(prefix="ocp-coldread-") as root:
        seed = CuboidStore(_spec(shape), backend=DirectoryBackend(root))
        ingest(seed, 0, vol)
        oracles = [cutout_loop(seed, 0, lo, hi) for lo, hi in boxes]
        for name, pol in _policies().items():
            read = serial_decode_cutout if name == "serial" else cutout
            store = CuboidStore(_spec(shape), backend=DirectoryBackend(root),
                                decode_policy=pol)
            clear = None
            if pol.prefetch_segments:
                cache = attach_cache(store, 4 * vol.nbytes)
                clear = cache.clear
            identical = all(
                np.array_equal(read(store, 0, lo, hi), want)
                for (lo, hi), want in zip(boxes, oracles))
            if clear is not None:
                clear()
            t = _timed_cold(read, store, boxes, repeats, clear=clear)
            times[name] = t
            rs = store.read_stats
            decode_mbps = ((rs.decoded_blocks * int(np.prod(_cuboid())))
                           / max(rs.decode_s, 1e-9) / 1e6)
            derived = f"{mb / t:.1f}MBps;identical={identical}"
            if name != "serial":
                derived += f";{times['serial'] / t:.2f}x_vs_serial"
                derived += f";decode={decode_mbps:.0f}MBps"
            if pol.prefetch_segments:
                c = store.cache.counters()
                issued = max(1, c["prefetch_insertions"])
                derived += (f";prefetch_hit_rate="
                            f"{c['prefetch_hits'] / issued:.2f}")
            rows.append({"name": f"coldread/{name}/{shape[0]}",
                         "us_per_call": t * 1e6, "derived": derived})
    return rows


def shard_rows() -> List[Dict]:
    """Pipelined cold cutouts over 1/2/4 shards, still oracle-identical."""
    shape = tuple(min(s, 64) for s in _shape())
    vol = _volume(shape)
    workers = max(2, os.cpu_count() or 2)
    pol = DecodePolicy(workers=workers, prefetch_segments=2)
    boxes = [((0, 0, 0), shape), ((13, 7, 5), tuple(s - 3 for s in shape))]
    ref = CuboidStore(_spec(shape))
    ingest(ref, 0, vol)
    rows = []
    oracles = [cutout_loop(ref, 0, lo, hi) for lo, hi in boxes]
    for n_nodes in (1, 2, 4):
        sub = ClusterStore(_spec(shape), n_nodes=n_nodes,
                           cache_bytes=4 * vol.nbytes, write_behind=False,
                           decode_policy=pol)
        ingest(sub, 0, vol)

        def clear():
            for node in sub.nodes:
                node.cache.clear()

        # identity checked outside the timed window (the oracle is a slow
        # serial loop; timing it would swamp the path under test)
        clear()
        identical = all(
            np.array_equal(cutout(sub, 0, lo, hi), want)
            for (lo, hi), want in zip(boxes, oracles))
        t = _timed_cold(cutout, sub, boxes, repeats=2, clear=clear)
        rows.append({"name": f"coldread/shards{n_nodes}/{shape[0]}",
                     "us_per_call": t * 1e6,
                     "derived": f"identical={identical}"})
        sub.close()
    return rows


def rows() -> List[Dict]:
    return pipeline_rows() + shard_rows()
