"""Elastic-rebalancing benchmarks (paper §6: dynamically redistribute data).

Two stories:

  * ``migrate``: a 2->4-node rebalance over an ingested volume — segment
    migration throughput (keys/s and MB/s of compressed blobs moved) and
    the resulting occupancy spread (`keys_per_node`), plus the shrink
    back to 2 nodes.
  * ``read latency during a move``: reader threads sample random cutouts
    continuously while the rebalance runs; rows report the baseline
    latency, the during-move latency, and their ratio — the paper's
    requirement that redistribution not take the cluster offline.  Every
    sampled cutout is verified bit-identical against the pre-ingested
    volume (zero stale reads).

``BENCH_PRESET=tiny`` shrinks volumes for the CI smoke job.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List

import numpy as np

from repro.cluster import ClusterStore
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest


def preset() -> str:
    return os.environ.get("BENCH_PRESET", "full")


def _shape():
    return (64, 64, 64) if preset() == "tiny" else (256, 256, 256)


def _spec(shape):
    return DatasetSpec(name="rebalance_bench", volume_shape=shape,
                       dtype="uint8", base_cuboid=(32, 32, 16))


def _boxes(shape, n, seed=31):
    rng = np.random.default_rng(seed)
    size = tuple(max(8, s // 4) for s in shape)
    out = []
    for _ in range(n):
        lo = tuple(int(rng.integers(0, s - sz)) for s, sz in zip(shape, size))
        out.append((lo, tuple(l + sz for l, sz in zip(lo, size))))
    return out


def migration_and_read_latency() -> List[Dict]:
    shape = _shape()
    vol = np.random.default_rng(17).integers(0, 255, size=shape,
                                             dtype=np.uint8)
    cluster = ClusterStore(_spec(shape), n_nodes=2,
                           cache_bytes=64 << 20, write_behind=True)
    ingest(cluster, 0, vol)
    boxes = _boxes(shape, n=6)

    # baseline read latency (steady 2-node topology, warm-ish)
    samples_before: List[float] = []
    for lo, hi in boxes:
        t0 = time.perf_counter()
        cutout(cluster, 0, lo, hi)
        samples_before.append(time.perf_counter() - t0)

    # readers sample cutouts while the 2->4 rebalance migrates segments
    samples_during: List[float] = []
    stale = [0]
    stop = threading.Event()
    lock = threading.Lock()

    def reader(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            lo, hi = boxes[int(rng.integers(0, len(boxes)))]
            t0 = time.perf_counter()
            got = cutout(cluster, 0, lo, hi)
            dt = time.perf_counter() - t0
            sl = tuple(slice(a, b) for a, b in zip(lo, hi))
            ok = np.array_equal(got, vol[sl])
            with lock:
                samples_during.append(dt)
                if not ok:
                    stale[0] += 1

    threads = [threading.Thread(target=reader, args=(41 + i,))
               for i in range(2)]
    for t in threads:
        t.start()
    t0 = time.perf_counter()
    stats = cluster.rebalance(target=4)
    t_move = time.perf_counter() - t0
    stop.set()
    for t in threads:
        t.join(timeout=60)

    per_node = cluster.keys_per_node()
    t0 = time.perf_counter()
    shrink = cluster.rebalance(target=2)
    t_shrink = time.perf_counter() - t0
    cluster.close()

    keys_s = stats["moved_keys"] / max(t_move, 1e-9)
    mb_s = stats["moved_bytes"] / 1e6 / max(t_move, 1e-9)
    mean_before = float(np.mean(samples_before))
    mean_during = float(np.mean(samples_during)) if samples_during \
        else mean_before
    rows = [
        {"name": f"rebalance/migrate_2to4/{shape[0]}",
         "us_per_call": t_move * 1e6,
         "derived": (f"{stats['moved_keys']}keys;{keys_s:.0f}keys_s"
                     f";{mb_s:.1f}MBps"
                     f";spread={max(per_node) - min(per_node)}")},
        {"name": f"rebalance/migrate_4to2/{shape[0]}",
         "us_per_call": t_shrink * 1e6,
         "derived": f"{shrink['moved_keys']}keys"},
        {"name": f"rebalance/read_baseline/{shape[0]}",
         "us_per_call": mean_before * 1e6,
         "derived": f"{len(samples_before)}samples"},
        {"name": f"rebalance/read_during_move/{shape[0]}",
         "us_per_call": mean_during * 1e6,
         "derived": (f"{mean_during / mean_before:.2f}x_vs_baseline"
                     f";{len(samples_during)}samples"
                     f";stale_reads={stale[0]}")},
    ]
    return rows


def rows() -> List[Dict]:
    return migration_and_read_latency()
