"""Benchmark driver: one section per paper table/figure + kernel/roofline.

Prints ``name,us_per_call,derived`` CSV (one row per measurement); with
``--json PATH`` the same rows are also written as a JSON array (the CI
bench-smoke artifact, so BENCH_* trajectories accumulate across PRs).
``--preset tiny`` shrinks volumes for smoke runs, ``--sections a,b``
restricts to named sections.  Roofline rows are read from
dryrun_results.json when present (produced by
``python -m repro.launch.dryrun --all --mesh both --out dryrun_results.json``).
"""
import argparse
import json
import os
import sys


def roofline_rows():
    root = os.path.join(os.path.dirname(__file__), "..")
    path = next((p for p in (os.path.join(root, "dryrun_all.json"),
                             os.path.join(root, "dryrun_results.json"))
                 if os.path.exists(p)), None)
    if path is None:
        return [{"name": "roofline/missing", "us_per_call": 0.0,
                 "derived": "run_launch.dryrun_first"}]
    with open(path) as f:
        cells = json.load(f)
    rows = []
    for c in cells:
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        t_step = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        rows.append({
            "name": f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            "us_per_call": t_step * 1e6,
            "derived": (f"bottleneck={r['bottleneck']}"
                        f";frac={r['roofline_fraction']:.3f}"
                        f";useful={r['useful_ratio']:.2f}"),
        })
    return rows


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--preset", choices=("tiny", "full"), default=None,
                        help="tiny = CI smoke sizes (sets BENCH_PRESET)")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="also write rows as a JSON array")
    parser.add_argument("--sections", default=None,
                        help="comma-separated section filter, e.g. "
                             "fig10,cluster")
    args = parser.parse_args(argv)
    if args.preset:
        os.environ["BENCH_PRESET"] = args.preset

    from . import (cache_bench, cluster_bench, coldread_bench, faults_bench,
                   figs, frontdoor_bench, kernels_bench, obs_bench,
                   rebalance_bench, tier_bench)

    sections = [
        ("fig10", figs.fig10_cutout_throughput),
        ("fig11", figs.fig11_concurrency),
        ("fig12", figs.fig12_annotation_write),
        ("fig13", figs.fig13_write_paths),
        ("cluster", cluster_bench.rows),
        ("cache", cache_bench.rows),
        ("coldread", coldread_bench.rows),
        ("rebalance", rebalance_bench.rows),
        ("faults", faults_bench.rows),
        ("tier", tier_bench.rows),
        ("frontdoor", frontdoor_bench.rows),
        ("obs", obs_bench.rows),
        ("curves", kernels_bench.curve_panel_traffic),
        ("attn", kernels_bench.attention_paths),
        ("ssd", kernels_bench.ssd_duality),
        ("moe", kernels_bench.moe_padding_elision),
        ("roofline", roofline_rows),
    ]
    if args.sections:
        wanted = set(args.sections.split(","))
        unknown = wanted - {label for label, _ in sections}
        if unknown:
            parser.error(f"unknown sections: {sorted(unknown)}")
        sections = [(label, fn) for label, fn in sections if label in wanted]

    print("name,us_per_call,derived")
    all_rows = []
    failures = 0
    for label, fn in sections:
        try:
            for row in fn():
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"{row['derived']}")
                all_rows.append(row)
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{label}/ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"preset": os.environ.get("BENCH_PRESET", "full"),
                       "rows": all_rows}, f, indent=1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
