"""Storage-tier benchmarks: read latency under a sustained write load.

The acceptance number for the tier split (paper §4.1's SSD-write /
disk-read separation): with a single fsync-on `DirectoryBackend` serving
both paths, every durable write costs a per-file
write+fsync+rename+fsync, and concurrent readers queue behind that
traffic.  The tiered store lands the same writes as sequential appends
on a `LogBackend` (one fsync per batch) while a background `Compactor`
trickles sealed segments into the read tier — so the read path keeps its
curve-sequential layout and its p99 stops inheriting the writer's sync
stalls.

Rows per mode (``single`` = one fsync-on directory backend both paths,
``tiered`` = log write tier + compacted read tier + background
compactor):

  * ``write``     — mean latency of one durable cuboid write,
  * ``read_p99``  — p99 cutout latency while the writer hammers,
  * plus a ``derived`` identity flag: after quiescing (flush + final
    compaction) every key written during the run must read back equal to
    the last value the writer recorded for it, and the surviving volume
    must match a `MemoryBackend` oracle replay — the tiers may never buy
    latency with correctness.

``BENCH_PRESET=tiny`` shrinks the run for the CI smoke job.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time
from typing import Dict, List

import numpy as np

from repro.core.compact import Compactor
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest
from repro.core.store import CuboidStore, DirectoryBackend
from repro.core.wal import TierPolicy, tiered_store


def preset() -> str:
    return os.environ.get("BENCH_PRESET", "full")


def _shape():
    return (64, 64, 32) if preset() == "tiny" else (128, 128, 64)


def _cuboid():
    return (16, 16, 8)


def _spec(shape):
    return DatasetSpec(name="tier_bench", volume_shape=shape,
                       dtype="uint8", base_cuboid=_cuboid())


def _volume(shape):
    rng = np.random.default_rng(23)
    x = np.linspace(0.0, 8 * np.pi, shape[0], dtype=np.float32)
    base = 96.0 + 64.0 * np.sin(x)[:, None, None]
    noise = rng.integers(0, 24, size=shape).astype(np.float32)
    return np.clip(base + noise, 0, 255).astype(np.uint8)


def _build(mode: str, root: str, shape):
    if mode == "single":
        # the pre-split store: one directory backend, durable writes
        # pay write+fsync+rename+fsync inline on the serving path
        return CuboidStore(_spec(shape),
                           backend=DirectoryBackend(root, fsync=True))
    return tiered_store(_spec(shape), root=root,
                        policy=TierPolicy(write_tier="log", fsync=True))


def _measure(mode: str, shape, vol, n_reads: int, read_boxes) -> Dict:
    n_cells = int(np.prod([s // c for s, c in zip(shape, _cuboid())]))
    with tempfile.TemporaryDirectory(prefix=f"ocp-tier-{mode}-") as root:
        store = _build(mode, root, shape)
        ingest(store, 0, vol)
        compactor = None
        if mode == "tiered":
            compactor = Compactor(store, interval=0.01, min_sealed=1)
            store.compact()  # start the run with a drained log
            compactor.start()

        stop = threading.Event()
        written: Dict[int, int] = {}   # morton -> fill value last written
        write_ns: List[int] = []
        errors: List[BaseException] = []

        def writer():
            rng = np.random.default_rng(5)
            i = 0
            try:
                while not stop.is_set():
                    m = int(rng.integers(0, n_cells))
                    i += 1
                    fill = 1 + (i % 250)
                    data = np.full(_cuboid(), fill, dtype=np.uint8)
                    t0 = time.perf_counter_ns()
                    store.write_cuboid(0, m, data)
                    write_ns.append(time.perf_counter_ns() - t0)
                    written[m] = fill
            except BaseException as e:  # pragma: no cover - surfaced below
                errors.append(e)

        t = threading.Thread(target=writer)
        t.start()
        read_ns = []
        try:
            for k in range(n_reads):
                lo, hi = read_boxes[k % len(read_boxes)]
                t0 = time.perf_counter_ns()
                cutout(store, 0, lo, hi)
                read_ns.append(time.perf_counter_ns() - t0)
        finally:
            stop.set()
            t.join()
            if compactor is not None:
                compactor.stop()
        if errors:
            raise errors[0]

        # quiesce, then the identity gate: last-write-wins vs the
        # writer's own record AND vs a memory-oracle replay of the run
        store.flush()
        store.compact()
        oracle = CuboidStore(_spec(shape))
        ingest(oracle, 0, vol)
        for m, fill in written.items():
            oracle.write_cuboid(
                0, m, np.full(_cuboid(), fill, dtype=np.uint8))
        identical = all(
            np.array_equal(store.read_cuboid(0, m), oracle.read_cuboid(0, m))
            for m in range(n_cells))
        compactions = dict(store.compactions)
        store.close()
    return {
        "write_us": float(np.mean(write_ns)) / 1e3 if write_ns else 0.0,
        "read_p99_us": float(np.percentile(read_ns, 99)) / 1e3,
        "read_mean_us": float(np.mean(read_ns)) / 1e3,
        "writes": len(write_ns),
        "identical": identical,
        "compaction_runs": compactions["runs"],
    }


def rows() -> List[Dict]:
    shape = _shape()
    vol = _volume(shape)
    n_reads = 100 if preset() == "tiny" else 200
    read_boxes = [((0, 0, 0), shape),
                  (tuple(c // 2 for c in _cuboid()),
                   tuple(s - 3 for s in shape))]
    out: List[Dict] = []
    results = {mode: _measure(mode, shape, vol, n_reads, read_boxes)
               for mode in ("single", "tiered")}
    for mode, r in results.items():
        derived = (f"identical={r['identical']};writes={r['writes']}"
                   f";read_mean={r['read_mean_us']:.0f}us"
                   f";write={r['write_us']:.0f}us")
        if mode == "tiered":
            base = results["single"]
            derived += (f";p99_vs_single="
                        f"{base['read_p99_us'] / max(r['read_p99_us'], 1e-9):.2f}x"
                        f";write_vs_single="
                        f"{base['write_us'] / max(r['write_us'], 1e-9):.2f}x"
                        f";compactions={r['compaction_runs']}")
        out.append({"name": f"tier/{mode}/{shape[0]}",
                    "us_per_call": r["read_p99_us"],
                    "derived": derived})
    return out


if __name__ == "__main__":
    for row in rows():
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
