"""End-to-end LM training through every substrate: Morton-sharded token
store -> stateless prefetching pipeline -> jit'd train step -> async
cuboid-chunked checkpoints -> failure injection + exact recovery.

Trains a ~1M-param SmolLM-family model for a few hundred steps on CPU and
verifies loss decreases AND that an injected node failure mid-run recovers
to the identical trajectory.

Run:  PYTHONPATH=src python examples/train_lm.py  (~2-4 min on CPU)
"""
import tempfile

from repro.launch.train import main as train_main


def run():
    ckpt = tempfile.mkdtemp(prefix="ocp_ckpt_")
    out = train_main([
        "--arch", "smollm-135m", "--smoke",
        "--steps", "120",
        "--seq-len", "128",
        "--batch", "8",
        "--lr", "3e-3",
        "--ckpt-dir", ckpt,
        "--ckpt-every", "25",
        "--inject-failure-at", "60",     # node dies at step 60
        "--microbatches", "2",           # grad accumulation path
        "--grad-compression", "bf16",    # cross-pod compression hook
    ])
    losses = out["losses"]
    assert losses[-1] < losses[0] * 0.8, "loss should decrease"
    print(f"OK: {losses[0]:.3f} -> {losses[-1]:.3f} with failure recovery, "
          f"microbatching, bf16 grad compression")


if __name__ == "__main__":
    run()
