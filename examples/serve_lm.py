"""Batched serving example: prefill a batch of prompts, decode greedily.

Uses the reduced gemma-2b config (MQA + GeGLU) on CPU; the identical step
function is what the decode_32k dry-run lowers for the production mesh.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    serve_main(["--arch", "gemma-2b", "--smoke", "--batch", "4",
                "--prompt-len", "12", "--gen", "12"])
