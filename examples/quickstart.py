"""Quickstart: the OCP spatial database in 2 minutes.

Build a dataset, ingest a volume, cut out regions, annotate objects, query
them back — the paper's full service surface (§3-§4) through the Python
API instead of REST URLs.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.annotations import Annotation, AnnotationProject
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import CutoutStats, cutout, ingest, project
from repro.core.store import CuboidStore, MemoryBackend


def main():
    # --- a dataset: 256x256x64 "EM" volume with a 3-level hierarchy ------
    spec = DatasetSpec(name="demo_em", volume_shape=(256, 256, 64),
                       dtype="uint8", n_resolutions=3,
                       base_cuboid=(64, 64, 16))
    store = CuboidStore(spec)
    rng = np.random.default_rng(0)
    vol = rng.integers(0, 255, size=spec.volume_shape, dtype=np.uint8)
    ingest(store, 0, vol)
    print(f"ingested {vol.nbytes/1e6:.0f}MB into "
          f"{len(store.stored_keys())} cuboids")

    # --- cutouts (the paper's core service) -------------------------------
    stats = CutoutStats()
    sub = cutout(store, 0, (30, 40, 10), (158, 168, 42), stats=stats)
    print(f"cutout {sub.shape}: {stats.runs} morton runs, "
          f"{stats.cuboids_read} cuboids, "
          f"{stats.bytes_discarded/1e6:.1f}MB read-amplification")

    # an XY tile for the viewer (paper §3.3, dynamic tile building)
    tile = project(store, 0, (0, 0, 32), (256, 256, 33), axis=2)
    print(f"tile {tile.shape} served from 3-d cuboids")

    # --- annotations (paper §3.2): separate project, same index space ----
    proj = AnnotationProject("synapses", spec, enable_exceptions=True,
                             write_path_backend=MemoryBackend())
    a = proj.meta.create(ann_type="synapse", confidence=0.98)
    b = proj.meta.create(ann_type="synapse", confidence=0.42)
    blob = np.zeros((8, 8, 4), np.uint32)
    blob[2:6, 2:6, 1:3] = 1
    proj.write(0, (100, 100, 20), blob * a.ann_id)
    proj.write(0, (102, 102, 20), blob * b.ann_id, discipline="exception")

    # predicate query, paper's URL: objects/type/synapse/confidence/geq/0.9
    ids = proj.meta.query(("ann_type", "eq", "synapse"),
                          ("confidence", "geq", 0.9))
    print(f"high-confidence synapses: {ids}")
    lo, dense = proj.object_cutout(a.ann_id, 0)
    print(f"object {a.ann_id}: bbox@{lo}, {int((dense>0).sum())} voxels, "
          f"centroid {proj.centroid(a.ann_id, 0).round(1)}")
    # multiply-labeled voxel via exceptions (both objects overlap here)
    print("labels at (104,104,21):", proj.voxel_labels(0, (104, 104, 21)))

    # writes landed on the write path; migrate to the read path (C4)
    n = proj.store.migrate()
    print(f"migrated {n} cuboids from SSD write path to DB read path")


if __name__ == "__main__":
    main()
