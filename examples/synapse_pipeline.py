"""The paper's driving workload end-to-end (§2, Fig 7): parallel synapse
detection over cutouts, with annotation writes to a separated write path,
low-resolution large-structure masking, and spatial analysis of results.

Run:  PYTHONPATH=src python examples/synapse_pipeline.py
"""
import numpy as np

from repro.core.annotations import AnnotationProject
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import build_hierarchy, ingest
from repro.core.store import CuboidStore, MemoryBackend
from repro.vision import run_parallel_detection


def synthetic_cortex(shape=(128, 128, 32), n_synapses=24, seed=7):
    rng = np.random.default_rng(seed)
    vol = rng.normal(100, 4, size=shape).astype(np.float32)
    centers = []
    for _ in range(n_synapses):
        c = [int(rng.integers(8, s - 8)) for s in shape]
        centers.append(c)
        xx, yy, zz = np.ogrid[:shape[0], :shape[1], :shape[2]]
        d2 = (xx - c[0]) ** 2 + (yy - c[1]) ** 2 + ((zz - c[2]) * 2) ** 2
        vol += 90.0 * np.exp(-d2 / 9.0)
    # one big bright "blood vessel" that must be masked out (paper §3.1)
    vol[40:90, 40:50, :] += 60.0
    return vol, centers


def main():
    vol, centers = synthetic_cortex()
    spec = DatasetSpec(name="cortex", volume_shape=vol.shape,
                       dtype="float32", n_resolutions=2,
                       base_cuboid=(32, 32, 16))
    store = CuboidStore(spec)
    ingest(store, 0, vol)
    build_hierarchy(store)          # resolution pyramid (paper §3.1)

    # annotations go to a dedicated write path ("SSD node", paper §4.1)
    proj = AnnotationProject("detections", spec,
                             write_path_backend=MemoryBackend())
    n = run_parallel_detection(store, proj, r=0, tile=(64, 64, 32),
                               n_workers=4, threshold=2.0, min_voxels=4,
                               batch_size=40, lowres_level=1)
    print(f"wrote {n} synapse annotations "
          f"(planted {len(centers)}; vessel region masked)")
    print(f"write path absorbed "
          f"{proj.store.write_stats.writes} cuboid writes; "
          f"read path served {proj.store.read_stats.reads} reads")

    # spatial analysis (paper §2): distances between detections
    ids = proj.meta.query(("ann_type", "eq", "synapse"))
    cents = np.array([proj.centroid(i, 0) for i in ids[:12]])
    if len(cents) >= 2:
        d = np.linalg.norm(cents[:, None] - cents[None], axis=-1)
        np.fill_diagonal(d, np.inf)
        print(f"nearest-neighbor distances: "
              f"min {d.min():.1f}, median {np.median(d.min(1)):.1f} voxels")
    hi = proj.meta.query(("ann_type", "eq", "synapse"),
                         ("confidence", "geq", 0.6))
    print(f"{len(hi)}/{len(ids)} detections above confidence 0.6")
    proj.store.migrate()            # cool the project back to disk nodes


if __name__ == "__main__":
    main()
