"""Deterministic, seeded fault injection at the cluster's node boundary.

The paper's cluster is an always-on public service; the only way to trust
its fault-tolerance story (health machine, quorum writes, failover —
``repro.cluster.store``) is to *drive* it with faults that are repeatable
under a seed.  This module is that harness:

* :class:`FaultPlan` — one node's fault policy: a seeded probability of
  injected errors / hangs, fixed added latency, an explicit per-op
  schedule, and a crash switch (``crash()`` makes every subsequent op
  raise :class:`NodeCrashed` instantly — the network-partition model —
  until ``restart()``; the node's data survives, exactly like a process
  restart over durable storage).
* :class:`FaultyNode` — a transparent proxy wrapping a ``CuboidStore``
  shard.  Data-plane ops (reads, writes, the health probe) consult the
  plan before delegating; everything else (stats, admin, migration
  plumbing) passes straight through, so the cluster's own machinery keeps
  working while its data path misbehaves.
* :func:`faulty_factory` — a ``NodeFactory`` for ``ClusterStore`` that
  wraps chosen shards in faulty proxies, configured explicitly or from
  the ``REPRO_FAULT_*`` knobs (node ``i`` draws from ``seed + i``, so a
  whole chaos run replays from one number).
* :func:`crash_schedule_hook` — composes the harness with the storage
  tier's existing ``set_crash_hook`` points: a hook that errors on the
  N-th hit of a named crashpoint, so a chaos walk can ALSO tear the
  durable-put path mid-write.

Faults injected here raise :class:`FaultInjected` (or sleep); they never
corrupt stored data — the harness models failing *machines*, and the
acceptance bar (zero acked writes lost, reads oracle-identical) is about
what the cluster does around them.
"""

from __future__ import annotations

import random
import threading
import time
from typing import Callable, Dict, Iterable, Optional, Type

from ..analysis import knobs
from ..core.cuboid import DatasetSpec
from ..core.store import CuboidStore


class FaultInjected(RuntimeError):
    """An error injected by the fault harness (not a real storage fault)."""


class NodeCrashed(FaultInjected):
    """The wrapped node is crashed: every data-plane op fails instantly."""


class FaultPlan:
    """One node's deterministic fault policy.

    ``schedule`` maps an intercepted-op ordinal (0-based, counted across
    all faulted ops on the node) to ``"error"``, ``"hang"``, ``"crash"``
    or ``"restart"`` — exact, replayable placement.  The seeded RNG adds
    probabilistic faults on top: ``error_rate`` / ``hang_rate`` per op,
    ``latency_s`` on every op.  Thread-safe; the internal lock is a plain
    leaf (never held across delegation or sleeps).
    """

    def __init__(
        self,
        seed: int = 0,
        error_rate: float = 0.0,
        latency_s: float = 0.0,
        hang_s: float = 0.0,
        hang_rate: float = 0.0,
        schedule: Optional[Dict[int, str]] = None,
    ):
        self.error_rate = float(error_rate)
        self.latency_s = float(latency_s)
        self.hang_s = float(hang_s)
        self.hang_rate = float(hang_rate)
        self.schedule = dict(schedule or {})
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self.ops = 0
        self.crashed = False
        self.injected_errors = 0
        self.injected_hangs = 0
        self.injected_latency_s = 0.0
        self.crashes = 0
        self.restarts = 0

    @classmethod
    def from_knobs(cls, seed: Optional[int] = None) -> "FaultPlan":
        """A plan from the ``REPRO_FAULT_*`` knobs (chaos runs toggle the
        whole harness through the environment)."""
        return cls(
            seed=knobs.get_int("REPRO_FAULT_SEED", 0) if seed is None else seed,
            error_rate=knobs.get_float("REPRO_FAULT_ERROR_RATE", 0.0) or 0.0,
            latency_s=(knobs.get_float("REPRO_FAULT_LATENCY_MS", 0.0) or 0.0) / 1e3,
            hang_s=(knobs.get_float("REPRO_FAULT_HANG_MS", 0.0) or 0.0) / 1e3,
            hang_rate=knobs.get_float("REPRO_FAULT_HANG_RATE", 0.0) or 0.0,
        )

    def crash(self) -> None:
        """Kill the node: every op raises ``NodeCrashed`` until restart."""
        with self._lock:
            if not self.crashed:
                self.crashed = True
                self.crashes += 1

    def restart(self) -> None:
        """Bring the node back (its durable data was never touched)."""
        with self._lock:
            if self.crashed:
                self.crashed = False
                self.restarts += 1

    def before_op(self, op: str) -> None:
        """Consult the plan before one intercepted op: may sleep (latency,
        hang) or raise (injected error, crashed node)."""
        with self._lock:
            n = self.ops
            self.ops += 1
            planned = self.schedule.get(n)
            if planned == "crash" and not self.crashed:
                self.crashed = True
                self.crashes += 1
            elif planned == "restart" and self.crashed:
                self.crashed = False
                self.restarts += 1
            crashed = self.crashed
            roll_error = planned == "error" or (
                self.error_rate > 0 and self._rng.random() < self.error_rate
            )
            roll_hang = planned == "hang" or (
                self.hang_rate > 0 and self._rng.random() < self.hang_rate
            )
        if crashed:
            raise NodeCrashed(f"node is crashed (op #{n}: {op})")
        if roll_hang and self.hang_s > 0:
            with self._lock:
                self.injected_hangs += 1
            time.sleep(self.hang_s)
        elif self.latency_s > 0:
            with self._lock:
                self.injected_latency_s += self.latency_s
            time.sleep(self.latency_s)
        if roll_error:
            with self._lock:
                self.injected_errors += 1
            raise FaultInjected(f"injected fault (op #{n}: {op})")

    def counters(self) -> Dict[str, object]:
        with self._lock:
            return {
                "ops": self.ops,
                "crashed": self.crashed,
                "errors": self.injected_errors,
                "hangs": self.injected_hangs,
                "latency_s": self.injected_latency_s,
                "crashes": self.crashes,
                "restarts": self.restarts,
            }


class FaultyNode:
    """A ``CuboidStore`` proxy that injects its :class:`FaultPlan` into
    every data-plane op before delegating to the wrapped store.

    Only the ops the *cluster's* degraded paths must survive are
    intercepted — single/batch reads, writes, and ``has_cuboid`` (the
    health probe); introspection and the migration/repair plumbing
    (``stored_keys``, ``ingest_blobs``, ``flush`` …) pass through so the
    cluster can still heal a node whose serving path is down.  Attribute
    reads and writes delegate too (``ClusterStore`` assigns
    ``decode_policy`` and wires caches onto its shards).
    """

    _OWN_ATTRS = frozenset({"inner", "plan", "name"})

    def __init__(self, inner: CuboidStore, plan: Optional[FaultPlan] = None,
                 name: str = "node"):
        self.__dict__["inner"] = inner
        self.__dict__["plan"] = plan or FaultPlan()
        self.__dict__["name"] = name

    def __getattr__(self, attr):
        return getattr(self.inner, attr)

    def __setattr__(self, attr, value):
        if attr in self._OWN_ATTRS:
            self.__dict__[attr] = value
        else:
            setattr(self.inner, attr, value)

    def __repr__(self) -> str:
        return f"FaultyNode({self.name!r}, crashed={self.plan.crashed})"

    def crash(self) -> None:
        self.plan.crash()

    def restart(self) -> None:
        self.plan.restart()

    # -- intercepted data plane --------------------------------------------
    def read_cuboid(self, *args, **kwargs):
        self.plan.before_op("read_cuboid")
        return self.inner.read_cuboid(*args, **kwargs)

    def write_cuboid(self, *args, **kwargs):
        self.plan.before_op("write_cuboid")
        return self.inner.write_cuboid(*args, **kwargs)

    def has_cuboid(self, *args, **kwargs):
        self.plan.before_op("has_cuboid")
        return self.inner.has_cuboid(*args, **kwargs)

    def read_run(self, *args, **kwargs):
        self.plan.before_op("read_run")
        return self.inner.read_run(*args, **kwargs)

    def fetch_runs(self, *args, **kwargs):
        self.plan.before_op("fetch_runs")
        return self.inner.fetch_runs(*args, **kwargs)

    def fetch_blocks(self, *args, **kwargs):
        self.plan.before_op("fetch_blocks")
        return self.inner.fetch_blocks(*args, **kwargs)

    def store_cuboids(self, *args, **kwargs):
        self.plan.before_op("store_cuboids")
        return self.inner.store_cuboids(*args, **kwargs)


def _afflicted_from_knob() -> Optional[frozenset]:
    raw = knobs.get_str("REPRO_FAULT_NODES", "")
    if not raw.strip():
        return None  # all nodes
    return frozenset(int(tok) for tok in raw.split(",") if tok.strip())


def faulty_factory(
    base_factory: Optional[Callable[[int, DatasetSpec], CuboidStore]] = None,
    plans: Optional[Dict[int, FaultPlan]] = None,
    seed: Optional[int] = None,
    nodes: Optional[Iterable[int]] = None,
):
    """A ``NodeFactory`` wrapping built shards in :class:`FaultyNode`.

    ``plans`` pins explicit per-node plans; otherwise each afflicted node
    gets ``FaultPlan.from_knobs(seed + i)`` (``seed`` defaulting to the
    ``REPRO_FAULT_SEED`` knob).  ``nodes`` limits which indexes are
    wrapped (default: the ``REPRO_FAULT_NODES`` knob, else all).  The
    returned factory exposes the proxies it built as ``factory.built``
    ({index: FaultyNode}) so a chaos driver can crash/restart them.
    """
    from ..cluster.store import _default_node_factory

    base = base_factory or _default_node_factory
    afflicted = frozenset(nodes) if nodes is not None else _afflicted_from_knob()
    base_seed = knobs.get_int("REPRO_FAULT_SEED", 0) if seed is None else seed

    def factory(i: int, spec: DatasetSpec) -> CuboidStore:
        node = base(i, spec)
        if afflicted is not None and i not in afflicted:
            return node
        plan = (plans or {}).get(i)
        if plan is None:
            plan = FaultPlan.from_knobs(seed=base_seed + i)
        proxy = FaultyNode(node, plan, name=f"node{i}")
        factory.built[i] = proxy
        return proxy

    factory.built = {}
    return factory


def crash_schedule_hook(
    schedule: Dict[str, int],
    exc: Type[BaseException] = FaultInjected,
) -> Callable[[str], None]:
    """A ``set_crash_hook`` hook erroring on the N-th hit of each named
    crashpoint — composes this harness with the storage tier's
    ``crashpoint()`` markers (``dir.put.synced``, ``wal.append.written``,
    …) so a chaos run can tear the durable-put path at an exact syscall
    boundary, deterministically."""
    counts: Dict[str, int] = {}
    lock = threading.Lock()

    def hook(name: str) -> None:
        with lock:
            nth = schedule.get(name)
            if nth is None:
                return
            counts[name] = counts.get(name, 0) + 1
            hit = counts[name]
        if hit == nth:
            raise exc(f"injected crash at point {name!r} (hit #{hit})")

    return hook
