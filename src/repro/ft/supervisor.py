"""Fault tolerance: checkpoint/restart supervision + straggler monitoring.

At thousand-node scale the mean time between node failures drops below job
length, so the runtime — not the operator — must own recovery. The
supervisor wraps the step loop:

  * periodic async checkpoints (write path off the step path, paper C4),
  * failure detection (exceptions / missed heartbeats) triggers restore
    from the last committed manifest and replay — the data pipeline's
    stateless batch addressing makes replay exact,
  * elastic restart: restore onto a different host count via the curve
    re-partition (paper C3),
  * straggler monitoring: per-worker EMA of step times; workers slower
    than ``threshold x median`` are flagged and their data work units are
    re-issued to the steal queue (pipeline.overdecompose).

On a real cluster the failure signal comes from the coordinator
(jax.distributed heartbeats); here `FailureInjector` produces deterministic
failures so recovery is testable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..ckpt import CheckpointManager, restore_checkpoint


class WorkerFailure(RuntimeError):
    """A (simulated) node failure."""

    def __init__(self, worker: int, step: int):
        super().__init__(f"worker {worker} failed at step {step}")
        self.worker = worker
        self.step = step


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: worker_id}."""
    schedule: Dict[int, int]
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.schedule and step not in self.fired:
            self.fired.add(step)
            raise WorkerFailure(self.schedule[step], step)


class StragglerMonitor:
    """EMA step-time tracking per worker; flags >threshold x median."""

    def __init__(self, n_workers: int, alpha: float = 0.3,
                 threshold: float = 1.8):
        self.ema = np.zeros(n_workers)
        self.alpha = alpha
        self.threshold = threshold
        self.reissued: List[int] = []

    def record(self, worker: int, dt: float) -> None:
        e = self.ema[worker]
        self.ema[worker] = dt if e == 0 else (
            self.alpha * dt + (1 - self.alpha) * e)

    def stragglers(self) -> List[int]:
        active = self.ema[self.ema > 0]
        if len(active) < 2:
            return []
        med = float(np.median(active))
        return [int(i) for i in np.nonzero(
            self.ema > self.threshold * med)[0]]

    def reissue(self, worker: int) -> None:
        self.reissued.append(worker)


class TrainingSupervisor:
    """Run a step function under checkpoint/restart supervision.

    ``step_fn(state, step) -> state`` must be pure in (state, step) —
    jax train steps and the stateless data pipeline satisfy this, which is
    what makes recovery-by-replay exact.
    """

    def __init__(self, ckpt_dir: str, ckpt_every: int = 10, keep: int = 3,
                 injector: Optional[FailureInjector] = None,
                 max_restarts: int = 8):
        self.mgr = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.injector = injector
        self.max_restarts = max_restarts
        self.restarts = 0
        self.recovery_log: List[Dict] = []

    def run(self, state, step_fn: Callable, n_steps: int,
            state_to_tree: Callable = lambda s: s,
            tree_to_state: Callable = lambda t, s: t):
        import jax
        import numpy as np
        # step-0 snapshot: a cold restart (no committed checkpoint yet)
        # must replay from the INITIAL state, not the mutated one
        initial = jax.tree.map(np.asarray, state_to_tree(state))
        step = 0
        while step < n_steps:
            try:
                if self.injector is not None:
                    self.injector.check(step)
                state = step_fn(state, step)
                if (step + 1) % self.ckpt_every == 0:
                    self.mgr.save_async(step + 1, state_to_tree(state))
                step += 1
            except WorkerFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.mgr.wait()  # drain in-flight checkpoint writes
                last = self.mgr.latest_step()
                if last is None:
                    state = tree_to_state(initial, state)  # cold restart
                    restart_step = 0
                else:
                    _, tree = restore_checkpoint(self.mgr.ckpt_dir, last)
                    state = tree_to_state(tree, state)
                    restart_step = last
                self.recovery_log.append({
                    "failed_step": e.step, "worker": e.worker,
                    "restored_to": restart_step,
                    "lost_steps": step - restart_step})
                step = restart_step
        self.mgr.wait()
        return state
