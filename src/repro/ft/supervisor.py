"""Fault tolerance: checkpoint/restart supervision + straggler monitoring.

At thousand-node scale the mean time between node failures drops below job
length, so the runtime — not the operator — must own recovery. The
supervisor wraps the step loop:

  * periodic async checkpoints (write path off the step path, paper C4),
  * failure detection (exceptions / missed heartbeats) triggers restore
    from the last committed manifest and replay — the data pipeline's
    stateless batch addressing makes replay exact,
  * elastic restart: restore onto a different host count via the curve
    re-partition (paper C3),
  * straggler monitoring: per-worker EMA of step times; workers slower
    than ``threshold x median`` are flagged and their data work units are
    re-issued to the steal queue (pipeline.overdecompose),
  * storage-tier watching: `ClusterWatch` polls the data cluster's
    observability gauges (key occupancy, write-behind queue depth,
    sealed log segments, replication health, per-segment access heat)
    and advises the live control verbs — ``POST /rebalance`` on
    occupancy skew, ``POST /flush`` on queue pressure, ``POST /compact``
    on log backlog, ``re_replicate`` on a replication gap; and
    `StorageSupervisor` closes that loop by *executing* the advice on a
    background tick (the driver behind background compaction and
    re-replication).

On a real cluster the failure signal comes from the coordinator
(jax.distributed heartbeats); here `FailureInjector` produces deterministic
failures so recovery is testable.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..ckpt import CheckpointManager, restore_checkpoint


class WorkerFailure(RuntimeError):
    """A (simulated) node failure."""

    def __init__(self, worker: int, step: int):
        super().__init__(f"worker {worker} failed at step {step}")
        self.worker = worker
        self.step = step


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: worker_id}."""
    schedule: Dict[int, int]
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.schedule and step not in self.fired:
            self.fired.add(step)
            raise WorkerFailure(self.schedule[step], step)


class StragglerMonitor:
    """EMA step-time tracking per worker; flags >threshold x median."""

    def __init__(self, n_workers: int, alpha: float = 0.3,
                 threshold: float = 1.8):
        self.ema = np.zeros(n_workers)
        self.alpha = alpha
        self.threshold = threshold
        self.reissued: List[int] = []

    def record(self, worker: int, dt: float) -> None:
        e = self.ema[worker]
        self.ema[worker] = dt if e == 0 else (
            self.alpha * dt + (1 - self.alpha) * e)

    def stragglers(self) -> List[int]:
        active = self.ema[self.ema > 0]
        if len(active) < 2:
            return []
        med = float(np.median(active))
        return [int(i) for i in np.nonzero(
            self.ema > self.threshold * med)[0]]

    def reissue(self, worker: int) -> None:
        self.reissued.append(worker)


class ClusterWatch:
    """Advise storage-tier control actions from observability gauges.

    The training tier recovers by checkpoint/restart; the storage tier's
    analogous loop is *watch and re-shape*: the same gauges the
    ``/metrics`` scrape exports (``keys_per_node``, write-behind
    ``depth``, segment heat) feed two live control verbs the cluster
    already serves — rebalance on occupancy skew, flush on queue
    pressure.  The watch only *advises*; invoking the verbs stays with
    the operator (or the caller's loop) because both move data.

    The watch is also the cluster's failure detector tick: ``sample()``
    drives ``probe_health()`` when the store has one, and ``advise()``
    turns node health into two more verbs — ``failover`` when a node has
    stayed dead for ``dead_ticks`` consecutive samples (debounced so one
    probe blip never evicts a node), and ``resync`` for a recovering node
    (or a live one with queued repair writes) so it is caught up via
    anti-entropy before serving reads again.

    Works against any store with ``topology()``; queue, heat, and health
    signals degrade gracefully when the store lacks them (single-node
    tiers).
    """

    def __init__(self, store, skew: float = 1.5, max_queue_depth: int = 256,
                 heat_top: int = 4, max_sealed_segments: int = 1,
                 dead_ticks: int = 2):
        self.store = store
        self.skew = skew                    # max/mean occupancy ratio that trips
        self.max_queue_depth = max_queue_depth
        self.heat_top = heat_top
        # sealed log segments across the cluster that trip "compact"
        self.max_sealed_segments = max_sealed_segments
        # consecutive dead samples before failover is advised
        self.dead_ticks = max(1, int(dead_ticks))
        self._dead_streak: Dict[int, int] = {}
        self._last_n_nodes: Optional[int] = None
        self.history: List[Dict] = []

    def sample(self) -> Dict:
        """One gauge snapshot, appended to ``history``."""
        if hasattr(self.store, "probe_health"):
            self.store.probe_health()  # the cheap failure-detector tick
        topo = (self.store.topology() if hasattr(self.store, "topology")
                else {"n_nodes": 1, "keys_per_node": []})
        replication = int(topo.get("replication", 1))
        snap: Dict = {
            "n_nodes": int(topo["n_nodes"]),
            "rebalancing": bool(topo.get("rebalancing", False)),
            "keys_per_node": [int(k) for k in topo.get("keys_per_node", [])],
            "replication": replication,
            "replication_target": int(
                topo.get("replication_target", replication)),
            "queue_depth": 0,
            "sealed_segments": 0,
            "hot": [],
        }
        if hasattr(self.store, "queue_counters"):
            snap["queue_depth"] = int(self.store.queue_counters().get("depth", 0))
        if hasattr(self.store, "tier_counters"):
            snap["sealed_segments"] = int(
                self.store.tier_counters().get("sealed", 0))
        elif hasattr(self.store, "tier_stats"):
            log = self.store.tier_stats().get("log")
            snap["sealed_segments"] = int(log["sealed"]) if log else 0
        if hasattr(self.store, "access_heat"):
            heat = self.store.access_heat(top=self.heat_top)
            snap["hot"] = [tuple(row) for row in heat["read"]]
        snap["health"] = [str(h) for h in topo.get("health", [])]
        snap["repair"] = []
        if hasattr(self.store, "node_health"):
            snap["repair"] = [int(h["repair_pending"])
                              for h in self.store.node_health()]
        # Debounce death: a node must stay dead across consecutive samples
        # before failover fires.  Streaks are keyed by node index, so any
        # membership change (indexes shift) resets them all.
        if self._last_n_nodes != snap["n_nodes"]:
            self._dead_streak.clear()
            self._last_n_nodes = snap["n_nodes"]
        for i, state in enumerate(snap["health"]):
            if state == "dead":
                self._dead_streak[i] = self._dead_streak.get(i, 0) + 1
            else:
                self._dead_streak.pop(i, None)
        snap["dead_streaks"] = dict(self._dead_streak)
        self.history.append(snap)
        return snap

    def advise(self, snap: Optional[Dict] = None) -> List[Dict]:
        """Actions implied by a snapshot (default: the latest sampled)."""
        if snap is None:
            snap = self.history[-1] if self.history else self.sample()
        actions: List[Dict] = []
        keys = snap["keys_per_node"]
        occupied = [k for k in keys if k > 0]
        if len(keys) > 1 and occupied and not snap["rebalancing"]:
            mean = sum(keys) / len(keys)
            if mean > 0 and max(keys) / mean > self.skew:
                actions.append({
                    "action": "rebalance",
                    "reason": f"occupancy skew {max(keys) / mean:.2f} > {self.skew}",
                    "keys_per_node": keys,
                })
        if snap["queue_depth"] > self.max_queue_depth:
            actions.append({
                "action": "flush",
                "reason": (f"write-behind depth {snap['queue_depth']} > "
                           f"{self.max_queue_depth}"),
            })
        if snap.get("sealed_segments", 0) >= self.max_sealed_segments:
            actions.append({
                "action": "compact",
                "reason": (f"{snap['sealed_segments']} sealed log "
                           f"segment(s) awaiting merge into the read tier"),
            })
        if (snap.get("replication", 1) < snap.get("replication_target", 1)
                and not snap["rebalancing"]):
            actions.append({
                "action": "re_replicate",
                "reason": (f"effective replication {snap['replication']} < "
                           f"target {snap['replication_target']}"),
            })
        health = snap.get("health", [])
        repair = snap.get("repair", [])
        for i, state in enumerate(health):
            backlog = repair[i] if i < len(repair) else 0
            if state == "recovering" or (state == "alive" and backlog > 0):
                actions.append({
                    "action": "resync",
                    "node": i,
                    "reason": (f"node {i} is {state} with {backlog} repair "
                               f"write(s) queued; anti-entropy resync"),
                })
        if not snap.get("rebalancing", False):
            streaks = snap.get("dead_streaks", {})
            for i, state in enumerate(health):
                if state == "dead" and streaks.get(i, 0) >= self.dead_ticks:
                    actions.append({
                        "action": "failover",
                        "node": i,
                        "reason": (f"node {i} dead for {streaks[i]} "
                                   f"consecutive samples; promote replicas"),
                    })
                    break  # one removal per tick: indexes shift afterwards
        return actions

    def step(self) -> List[Dict]:
        """Sample then advise — one watch-loop tick."""
        return self.advise(self.sample())


class StorageSupervisor:
    """Close the watch loop: sample the gauges, *execute* the advice.

    `ClusterWatch` only advises; this supervisor owns acting on it — the
    runtime-not-operator recovery doctrine applied to the storage tier.
    Per tick (`step`, or the background thread `start` runs every
    ``interval`` seconds) it maps advised actions to store verbs:

    * ``flush`` — drain the write-behind queues (queue pressure),
    * ``compact`` — merge sealed log segments into the read tier (this is
      what drives ``repro.core.compact`` in the background),
    * ``re_replicate`` — heal under-replicated segments after a shrink
      (``replication`` below ``replication_target``),
    * ``rebalance`` — only when ``allow_rebalance=True``; occupancy moves
      whole key ranges, so it stays opt-in,
    * ``failover`` — remove a node the health machine has held dead for
      ``dead_ticks`` samples (re-verified against ``node_health()`` at
      execution time so a healed or already-removed node is skipped); the
      removal migration itself promotes replicas, and any residual gap is
      healed by the existing ``re_replicate`` advice,
    * ``resync`` — anti-entropy catch-up for a recovering node (or a live
      one with queued repair writes) before it serves reads again.

    Topology verbs run with ``wait=False`` and a concurrent admin op just
    skips the tick (the advice re-fires next tick if still true); any
    other verb failure is swallowed the same way — recorded on the action
    dict, never allowed to kill the supervisor thread.  ``log`` records
    every executed action for inspection.
    """

    def __init__(self, store, watch: Optional[ClusterWatch] = None,
                 interval: float = 0.25, allow_rebalance: bool = False,
                 allow_failover: bool = True):
        self.store = store
        self.watch = watch or ClusterWatch(store)
        self.interval = interval
        self.allow_rebalance = allow_rebalance
        self.allow_failover = allow_failover
        self.log: List[Dict] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _execute(self, action: Dict) -> bool:
        from ..cluster.store import RebalanceInFlight  # lazy: keep ft light
        kind = action["action"]
        store = self.store
        try:
            if kind == "flush" and hasattr(store, "flush"):
                store.flush()
            elif kind == "compact" and hasattr(store, "compact"):
                store.compact()
            elif kind == "re_replicate" and hasattr(store, "re_replicate"):
                store.re_replicate(wait=False)
            elif (kind == "rebalance" and self.allow_rebalance
                    and hasattr(store, "rebalance")):
                store.rebalance(wait=False)
            elif kind == "resync" and hasattr(store, "resync_node"):
                store.resync_node(action["node"], wait=False)
            elif (kind == "failover" and self.allow_failover
                    and hasattr(store, "remove_node")):
                idx = action["node"]
                health = (store.node_health()
                          if hasattr(store, "node_health") else [])
                if not (0 <= idx < len(health)) or health[idx]["state"] != "dead":
                    return False  # healed or already removed since advised
                store.remove_node(idx, wait=False)
            else:
                return False
        except RebalanceInFlight:
            return False  # an admin op holds the lock; re-advised next tick
        except Exception as e:
            # The supervisor tick must outlive any one verb: record and
            # move on (the advice re-fires next tick if still true).
            action["error"] = repr(e)
            return False
        return True

    def step(self) -> List[Dict]:
        """One tick: watch, execute, log.  Returns the executed actions."""
        executed = [a for a in self.watch.step() if self._execute(a)]
        self.log.extend(executed)
        return executed

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.interval):
                self.step()

        self._thread = threading.Thread(
            target=loop, name="ocp-storage-supervisor", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()


class TrainingSupervisor:
    """Run a step function under checkpoint/restart supervision.

    ``step_fn(state, step) -> state`` must be pure in (state, step) —
    jax train steps and the stateless data pipeline satisfy this, which is
    what makes recovery-by-replay exact.
    """

    def __init__(self, ckpt_dir: str, ckpt_every: int = 10, keep: int = 3,
                 injector: Optional[FailureInjector] = None,
                 max_restarts: int = 8):
        self.mgr = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.injector = injector
        self.max_restarts = max_restarts
        self.restarts = 0
        self.recovery_log: List[Dict] = []

    def run(self, state, step_fn: Callable, n_steps: int,
            state_to_tree: Callable = lambda s: s,
            tree_to_state: Callable = lambda t, s: t):
        import jax
        import numpy as np
        # step-0 snapshot: a cold restart (no committed checkpoint yet)
        # must replay from the INITIAL state, not the mutated one
        initial = jax.tree.map(np.asarray, state_to_tree(state))
        step = 0
        while step < n_steps:
            try:
                if self.injector is not None:
                    self.injector.check(step)
                state = step_fn(state, step)
                if (step + 1) % self.ckpt_every == 0:
                    self.mgr.save_async(step + 1, state_to_tree(state))
                step += 1
            except WorkerFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.mgr.wait()  # drain in-flight checkpoint writes
                last = self.mgr.latest_step()
                if last is None:
                    state = tree_to_state(initial, state)  # cold restart
                    restart_step = 0
                else:
                    _, tree = restore_checkpoint(self.mgr.ckpt_dir, last)
                    state = tree_to_state(tree, state)
                    restart_step = last
                self.recovery_log.append({
                    "failed_step": e.step, "worker": e.worker,
                    "restored_to": restart_step,
                    "lost_steps": step - restart_step})
                step = restart_step
        self.mgr.wait()
        return state
