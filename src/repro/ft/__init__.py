from .supervisor import (ClusterWatch, FailureInjector, StorageSupervisor,
                         StragglerMonitor, TrainingSupervisor, WorkerFailure)

__all__ = ["ClusterWatch", "FailureInjector", "StorageSupervisor",
           "StragglerMonitor", "TrainingSupervisor", "WorkerFailure"]
