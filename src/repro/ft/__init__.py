from .supervisor import (ClusterWatch, FailureInjector, StragglerMonitor,
                         TrainingSupervisor, WorkerFailure)

__all__ = ["ClusterWatch", "FailureInjector", "StragglerMonitor",
           "TrainingSupervisor", "WorkerFailure"]
