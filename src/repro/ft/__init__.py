from .faults import (FaultInjected, FaultPlan, FaultyNode, NodeCrashed,
                     crash_schedule_hook, faulty_factory)
from .supervisor import (ClusterWatch, FailureInjector, StorageSupervisor,
                         StragglerMonitor, TrainingSupervisor, WorkerFailure)

__all__ = ["ClusterWatch", "FailureInjector", "StorageSupervisor",
           "StragglerMonitor", "TrainingSupervisor", "WorkerFailure",
           "FaultInjected", "FaultPlan", "FaultyNode", "NodeCrashed",
           "crash_schedule_hook", "faulty_factory"]
