from .supervisor import (FailureInjector, StragglerMonitor,
                         TrainingSupervisor, WorkerFailure)

__all__ = ["FailureInjector", "StragglerMonitor", "TrainingSupervisor",
           "WorkerFailure"]
