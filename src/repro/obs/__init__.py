"""Observability: request tracing, mergeable latency histograms, metric
registry/exposition, and structured logging.

The sensory system the cluster's remaining roadmap items read from —
``GET /metrics`` (Prometheus text), ``GET /trace/<id>`` (span tree), the
structured access/slow-request log, and the heat/queue gauges the
supervisor and rebalancer consume.
"""

from .hist import BOUNDS, Histogram, describe
from .log import access_enabled, access_log, slow_request, slow_threshold_s
from .registry import REGISTRY, Metric, Registry, metric, render_labels
from .trace import (
    RING,
    SpanRing,
    TraceContext,
    activate,
    bind,
    current,
    event,
    maybe_start,
    mint_trace_id,
    sample_period,
    span,
    trace_spans,
    trace_tree,
)

__all__ = [
    "BOUNDS",
    "Histogram",
    "describe",
    "access_enabled",
    "access_log",
    "slow_request",
    "slow_threshold_s",
    "REGISTRY",
    "Metric",
    "Registry",
    "metric",
    "render_labels",
    "RING",
    "SpanRing",
    "TraceContext",
    "activate",
    "bind",
    "current",
    "event",
    "maybe_start",
    "mint_trace_id",
    "sample_period",
    "span",
    "trace_spans",
    "trace_tree",
]
