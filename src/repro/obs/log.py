"""Structured (JSON-lines) logging: access log + slow-request dumps.

The stdlib ``BaseHTTPRequestHandler`` writes raw access lines to stderr,
which interleaves with test output and bench tables.  This module gives
the front door a structured replacement that is **silent by default**:

* ``REPRO_ACCESS_LOG=1`` — emit one JSON line per HTTP request
  (method, path, status, duration, trace id when sampled).
* ``REPRO_SLOW_MS=<threshold>`` — any request slower than the threshold
  dumps a structured ``slow_request`` record carrying its span tree (the
  trace the operator would otherwise have to re-trigger and re-capture).

Records go through a standard :mod:`logging` logger (``repro.obs``), so
embedders can attach their own handlers; when nothing is configured and
a record *is* enabled, a stderr handler is attached lazily on first use.
"""

from __future__ import annotations

import json
import logging
import sys
import threading
import time
from typing import Any, Dict, Optional

from ..analysis import knobs
from ..analysis.witness import ordered_lock

__all__ = ["LOGGER", "access_enabled", "slow_threshold_s", "access_log", "slow_request", "emit"]

LOGGER = logging.getLogger("repro.obs")
LOGGER.setLevel(logging.INFO)

_handler_lock = ordered_lock("obs.log", 93)


def _ensure_handler() -> None:
    """Attach a stderr JSON-line handler once, only when something is
    actually emitted — a logger with no records configures nothing."""
    if LOGGER.handlers or LOGGER.propagate and logging.getLogger().handlers:
        return
    with _handler_lock:
        if LOGGER.handlers:
            return
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter("%(message)s"))
        LOGGER.addHandler(handler)
        LOGGER.propagate = False


def access_enabled() -> bool:
    return knobs.get_flag("REPRO_ACCESS_LOG", False)


def slow_threshold_s() -> Optional[float]:
    """``REPRO_SLOW_MS`` as seconds, or ``None`` when unset/disabled."""
    ms = knobs.get_float("REPRO_SLOW_MS", None)
    if ms is None:
        return None
    return ms / 1000.0 if ms >= 0 else None


def emit(kind: str, **fields: Any) -> None:
    """One JSON line: ``{"kind": ..., "ts": ..., **fields}``."""
    _ensure_handler()
    record: Dict[str, Any] = {"kind": kind, "ts": round(time.time(), 6)}
    record.update(fields)
    LOGGER.info(json.dumps(record, default=str, separators=(",", ":")))


def access_log(
    method: str,
    path: str,
    status: int,
    dur_s: float,
    trace_id: Optional[str] = None,
) -> None:
    """One structured access-log line, gated on ``REPRO_ACCESS_LOG=1``."""
    if not access_enabled():
        return
    fields: Dict[str, Any] = {
        "method": method,
        "path": path,
        "status": int(status),
        "dur_ms": round(dur_s * 1e3, 3),
    }
    if trace_id:
        fields["trace"] = trace_id
    emit("access", **fields)


def slow_request(
    method: str,
    path: str,
    dur_s: float,
    trace_id: Optional[str],
    tree: Any,
) -> None:
    """Threshold-triggered span-tree dump (caller already checked the
    duration against :func:`slow_threshold_s`)."""
    emit(
        "slow_request",
        method=method,
        path=path,
        dur_ms=round(dur_s * 1e3, 3),
        threshold_ms=round((slow_threshold_s() or 0.0) * 1e3, 3),
        trace=trace_id,
        spans=tree,
    )
