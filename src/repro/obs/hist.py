"""Streaming latency histograms: fixed log-spaced buckets, mergeable.

Every :class:`Histogram` in the process shares ONE bucket layout —
``BOUNDS[i] = 1e-6 * 2**i`` seconds, from 1µs up past a minute, plus the
+Inf overflow — so histograms merge across nodes, shards, and runs by
plain bucket-count addition (associative and commutative; the test suite
asserts both and that counts are conserved).  That is the property the
cluster needs: each node observes locally with no coordination, and the
``/metrics`` scrape (or a bench harness) merges after the fact.

``observe`` is lock-cheap: one ``bisect`` on a 27-entry tuple and three
updates under a short lock.  Percentiles are read from the bucket CDF
(upper bucket edge — a conservative estimate, exact to within one
log-bucket's resolution), which is how the bench sections report p50/p99
without keeping raw samples.
"""

from __future__ import annotations

import math
import threading
import time
from bisect import bisect_left
from typing import Dict, Iterable, List, Optional, Sequence

from ..analysis.witness import ordered_lock

__all__ = ["BOUNDS", "Histogram"]

# Upper bucket bounds in seconds: 1µs, 2µs, 4µs, ... ~67s (27 buckets),
# then +Inf.  Fixed for the whole process so histograms always merge.
BOUNDS: Sequence[float] = tuple(1e-6 * 2.0**i for i in range(27))


class Histogram:
    """One metric's latency distribution over the shared log buckets."""

    __slots__ = ("counts", "count", "sum", "_lock")

    def __init__(self):
        self.counts = [0] * (len(BOUNDS) + 1)  # last slot = +Inf overflow
        self.count = 0
        self.sum = 0.0
        self._lock = ordered_lock("obs.hist", 92)

    # -- recording ----------------------------------------------------------
    def observe(self, seconds: float) -> None:
        idx = bisect_left(BOUNDS, seconds)
        with self._lock:
            self.counts[idx] += 1
            self.count += 1
            self.sum += seconds

    class _Timer:
        __slots__ = ("_hist", "_t0")

        def __init__(self, hist: "Histogram"):
            self._hist = hist
            self._t0 = 0.0

        def __enter__(self):
            self._t0 = time.perf_counter()
            return self

        def __exit__(self, *exc):
            self._hist.observe(time.perf_counter() - self._t0)
            return False

    def time(self) -> "Histogram._Timer":
        """``with hist.time(): ...`` — observe one timed block."""
        return Histogram._Timer(self)

    # -- merging (the cross-node property) ----------------------------------
    def merge(self, other: "Histogram") -> "Histogram":
        """A NEW histogram holding both inputs' observations (inputs are
        untouched) — bucket-wise addition over the shared bounds."""
        out = Histogram()
        with self._lock:
            mine = list(self.counts)
            my_count, my_sum = self.count, self.sum
        with other._lock:
            theirs = list(other.counts)
            their_count, their_sum = other.count, other.sum
        out.counts = [a + b for a, b in zip(mine, theirs)]
        out.count = my_count + their_count
        out.sum = my_sum + their_sum
        return out

    @classmethod
    def merged(cls, parts: Iterable["Histogram"]) -> "Histogram":
        out = cls()
        for part in parts:
            out = out.merge(part)
        return out

    # -- reading ------------------------------------------------------------
    def percentile(self, q: float) -> float:
        """Upper bucket edge at quantile ``q`` in [0, 1] (0.0 if empty).

        Overflow observations report the last finite bound ×2 — a floor,
        flagged by being beyond every bucket."""
        if not (0.0 <= q <= 1.0):
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            total = self.count
            counts = list(self.counts)
        if total == 0:
            return 0.0
        rank = q * total
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if cum >= rank and c:
                return BOUNDS[i] if i < len(BOUNDS) else BOUNDS[-1] * 2.0
        return BOUNDS[-1] * 2.0

    @property
    def mean(self) -> float:
        with self._lock:
            return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {
                "bounds": list(BOUNDS),
                "counts": list(self.counts),
                "count": self.count,
                "sum": self.sum,
            }

    def prometheus_lines(self, name: str, label_str: str) -> List[str]:
        """The text-exposition lines for one labeled histogram series:
        cumulative ``_bucket{le=...}`` rows, then ``_sum`` / ``_count``.
        ``label_str`` is the pre-rendered ``key="value",...`` body (may be
        empty)."""
        with self._lock:
            counts = list(self.counts)
            total, s = self.count, self.sum
        sep = "," if label_str else ""
        lines = []
        cum = 0
        for bound, c in zip(BOUNDS, counts):
            cum += c
            le = format(bound, ".9g")
            lines.append(f'{name}_bucket{{{label_str}{sep}le="{le}"}} {cum}')
        lines.append(f'{name}_bucket{{{label_str}{sep}le="+Inf"}} {total}')
        head = f"{{{label_str}}}" if label_str else ""
        lines.append(f"{name}_sum{head} {format(s, '.9g')}")
        lines.append(f"{name}_count{head} {total}")
        return lines

    def __repr__(self) -> str:
        p50 = self.percentile(0.5)
        p99 = self.percentile(0.99)
        return f"Histogram(n={self.count}, p50={p50:.6f}s, p99={p99:.6f}s)"


def _fmt_seconds(seconds: float) -> str:
    if seconds <= 0:
        return "0"
    exp = math.floor(math.log10(seconds))
    if exp >= 0:
        return f"{seconds:.2f}s"
    if exp >= -3:
        return f"{seconds * 1e3:.2f}ms"
    return f"{seconds * 1e6:.0f}us"


def describe(hist: Histogram) -> str:
    """Human one-liner for bench ``derived`` columns: p50/p99 from the
    bucket CDF, never from raw samples."""
    return (
        f"p50={_fmt_seconds(hist.percentile(0.5))}"
        f";p99={_fmt_seconds(hist.percentile(0.99))}"
        f";n={hist.count}"
    )
