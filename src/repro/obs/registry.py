"""Metric registry + Prometheus text exposition (``GET /metrics``).

A :class:`Registry` owns named metric families.  Histograms register
lazily per label set (:meth:`Registry.histogram` returns the same
:class:`~repro.obs.hist.Histogram` for the same ``(name, labels)`` every
time — callers observe without holding references).  Counters and gauges
are *collected*, not stored: the live system already maintains its
counters (`PathStats`, cache/queue counters, heat maps), so scrape-time
collectors translate them into samples instead of double-counting into a
second store.  :meth:`prometheus_text` renders the whole registry in the
Prometheus text exposition format (version 0.0.4).

One process-global :data:`REGISTRY` backs the HTTP surface; tests and
benches build private registries for isolation.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from ..analysis.witness import ordered_lock
from .hist import Histogram

__all__ = ["Labels", "Sample", "Metric", "Registry", "REGISTRY", "render_labels"]

# Label sets travel as sorted tuples of (key, value) so they hash.
Labels = Tuple[Tuple[str, str], ...]
# One exposed number: (labels, value).
Sample = Tuple[Labels, float]


class Metric:
    """One collected family: name, type, help, and its current samples."""

    __slots__ = ("name", "mtype", "help", "samples")

    def __init__(self, name: str, mtype: str, help_text: str, samples: Iterable[Sample]):
        self.name = name
        self.mtype = mtype  # "counter" | "gauge"
        self.help = help_text
        self.samples = list(samples)


def _labels(labels: Optional[Dict[str, object]]) -> Labels:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def render_labels(labels: Labels) -> str:
    """``key="value",...`` body (escaped) for one sample's label set."""
    parts = []
    for k, v in labels:
        v = v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
        parts.append(f'{k}="{v}"')
    return ",".join(parts)


class Registry:
    """Histogram families + scrape-time collectors, rendered as text."""

    def __init__(self):
        self._lock = ordered_lock("obs.registry", 91)
        # {name: (help, {labels: Histogram})}
        self._hists: Dict[str, Tuple[str, Dict[Labels, Histogram]]] = {}
        self._collectors: List[Callable[[], Iterable[Metric]]] = []

    # -- histograms ---------------------------------------------------------
    def histogram(
        self,
        name: str,
        labels: Optional[Dict[str, object]] = None,
        help_text: str = "",
    ) -> Histogram:
        """The histogram for ``(name, labels)``, created on first use."""
        key = _labels(labels)
        with self._lock:
            entry = self._hists.get(name)
            if entry is None:
                entry = self._hists[name] = (help_text, {})
            series = entry[1]
            hist = series.get(key)
            if hist is None:
                hist = series[key] = Histogram()
            return hist

    def histograms(self, name: str) -> Dict[Labels, Histogram]:
        """Every label set of one family (live objects — merge, don't
        mutate)."""
        with self._lock:
            entry = self._hists.get(name)
            return dict(entry[1]) if entry else {}

    # -- collectors ---------------------------------------------------------
    def add_collector(self, fn: Callable[[], Iterable[Metric]]) -> None:
        """Register a scrape-time source of counter/gauge metrics."""
        with self._lock:
            self._collectors.append(fn)

    def remove_collector(self, fn: Callable[[], Iterable[Metric]]) -> None:
        with self._lock:
            if fn in self._collectors:
                self._collectors.remove(fn)

    # -- exposition ---------------------------------------------------------
    def prometheus_text(self, extra: Iterable[Metric] = ()) -> str:
        """The Prometheus text exposition of everything registered plus
        ``extra`` metrics the caller collected itself (e.g. per-dataset
        store counters the registry has no handle on)."""
        lines: List[str] = []
        with self._lock:
            hists = {name: (h, dict(series)) for name, (h, series) in self._hists.items()}
            collectors = list(self._collectors)
        for name in sorted(hists):
            help_text, series = hists[name]
            if help_text:
                lines.append(f"# HELP {name} {help_text}")
            lines.append(f"# TYPE {name} histogram")
            for labels in sorted(series):
                lines.extend(series[labels].prometheus_lines(name, render_labels(labels)))
        metrics: List[Metric] = []
        for fn in collectors:
            metrics.extend(fn())
        metrics.extend(extra)
        for metric in metrics:
            if metric.help:
                lines.append(f"# HELP {metric.name} {metric.help}")
            lines.append(f"# TYPE {metric.name} {metric.mtype}")
            for labels, value in metric.samples:
                body = render_labels(labels)
                head = f"{{{body}}}" if body else ""
                if float(value) == int(value):
                    rendered = str(int(value))
                else:
                    rendered = format(float(value), ".9g")
                lines.append(f"{metric.name}{head} {rendered}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Drop every histogram series and collector (test isolation)."""
        with self._lock:
            self._hists.clear()
            self._collectors.clear()


def metric(
    name: str,
    mtype: str,
    help_text: str,
    samples: Sequence[Tuple[Dict[str, object], float]],
) -> Metric:
    """Convenience constructor taking plain label dicts."""
    return Metric(name, mtype, help_text, [(_labels(ls), float(v)) for ls, v in samples])


#: The process-global registry the HTTP surface exposes.
REGISTRY = Registry()
