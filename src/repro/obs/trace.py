"""Per-request distributed tracing over a lock-cheap span ring buffer.

The paper's performance story (§6) attributes cutout latency to its
stages — disk reads, decompression, assembly, network.  This module is
the mechanism: a request-scoped :class:`TraceContext` travels down the
whole read/write pipeline (HTTP front door → cluster fan-out → node
fetch → decode workers → assembly sink) and every instrumented stage
emits a timestamped span into a fixed-size per-node ring buffer
(:class:`SpanRing`).  A completed request yields a span *tree* —
queue wait → admission → plan → per-node fetch → decode → assemble —
retrievable by trace id (``GET /trace/<id>`` on the front door).

Always-on-cheap is the design constraint: when no trace is sampled the
instrumentation reduces to one ``ContextVar.get()`` returning ``None``
per span site (:func:`span` returns a shared null context manager), so
the untraced hot path pays nanoseconds, not locks.  Sampling:

* ``REPRO_TRACE_SAMPLE`` — ``0`` (default) never samples, ``1`` samples
  every request, a fraction ``0 < p < 1`` samples one request in
  ``round(1/p)`` (deterministic counter, not RNG — cheap and exact).
* An explicit ``X-Trace-Id`` request header always traces, whatever the
  sample rate — the operator's "trace THIS request" hook.

Propagation: spans cross thread-pool boundaries (node fan-out, decode
chunks, prefetch tasks) via :func:`bind`, which captures the caller's
active span and re-installs it inside the worker — a no-op returning the
original callable when nothing is traced, so pools pay nothing either.
"""

from __future__ import annotations

import contextvars
import itertools
import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional

from ..analysis import knobs
from ..analysis.witness import ordered_lock

__all__ = [
    "SpanRing",
    "TraceContext",
    "RING",
    "current",
    "maybe_start",
    "activate",
    "span",
    "event",
    "bind",
    "trace_spans",
    "trace_tree",
    "sample_period",
]


class SpanRing:
    """Fixed-capacity ring of completed span records (dicts).

    One per process ("node" in this reproduction); appends take one short
    lock around an index bump + slot assignment, so a traced request costs
    O(spans) cheap appends and an untraced request costs zero.  Lookup
    scans the ring (capacity is small — observability data, not storage).
    """

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = int(capacity)
        self._buf: List[Optional[Dict[str, Any]]] = [None] * self.capacity
        self._idx = 0
        self._lock = ordered_lock("obs.ring", 90)
        self.appended = 0  # lifetime spans recorded (monotonic)
        self.dropped = 0  # spans overwritten before ever being read

    def append(self, record: Dict[str, Any]) -> None:
        with self._lock:
            if self._buf[self._idx] is not None:
                self.dropped += 1
            self._buf[self._idx] = record
            self._idx = (self._idx + 1) % self.capacity
            self.appended += 1

    def spans_for(self, trace_id: str) -> List[Dict[str, Any]]:
        """Every retained span of one trace, oldest first."""
        with self._lock:
            flat = self._buf[self._idx :] + self._buf[: self._idx]
        return [s for s in flat if s is not None and s["trace"] == trace_id]

    def counters(self) -> Dict[str, int]:
        with self._lock:
            resident = sum(1 for s in self._buf if s is not None)
        return {
            "capacity": self.capacity,
            "resident": resident,
            "appended": self.appended,
            "dropped": self.dropped,
        }

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._idx = 0


def _ring_capacity() -> int:
    return knobs.get_int("REPRO_TRACE_RING", 4096)


#: The per-node ring every instrumented stage writes into and the
#: ``GET /trace/<id>`` verb reads from.
RING = SpanRing(_ring_capacity())

_span_ids = itertools.count(1)  # 0 is the implicit root parent


class TraceContext:
    """One sampled request's identity: trace id + destination ring."""

    __slots__ = ("trace_id", "ring")

    def __init__(self, trace_id: str, ring: Optional[SpanRing] = None):
        self.trace_id = trace_id
        self.ring = ring if ring is not None else RING


class _Active:
    """What the context variable holds: (context, innermost open span)."""

    __slots__ = ("ctx", "span_id")

    def __init__(self, ctx: TraceContext, span_id: int):
        self.ctx = ctx
        self.span_id = span_id


_current: contextvars.ContextVar[Optional[_Active]] = contextvars.ContextVar(
    "repro_trace", default=None
)


def current() -> Optional[TraceContext]:
    """The active trace, or ``None`` (the untraced fast path)."""
    active = _current.get()
    return active.ctx if active is not None else None


def sample_period() -> int:
    """``REPRO_TRACE_SAMPLE`` as a sampling period: 0 = never, 1 = every
    request, k = one request in k (from a fractional rate)."""
    rate = knobs.get_float("REPRO_TRACE_SAMPLE", None)
    if rate is None:
        return 0
    if rate <= 0:
        return 0
    if rate >= 1:
        return 1
    return max(1, round(1.0 / rate))


_sample_counter = itertools.count()


def mint_trace_id() -> str:
    return uuid.uuid4().hex[:16]


def maybe_start(
    trace_id: Optional[str] = None, ring: Optional[SpanRing] = None
) -> Optional[TraceContext]:
    """Sampling decision for one request.

    An explicit ``trace_id`` (the ``X-Trace-Id`` header) always traces;
    otherwise one request per :func:`sample_period` gets a minted id.
    Returns ``None`` for the (cheap) untraced majority.
    """
    if trace_id:
        return TraceContext(str(trace_id), ring)
    period = sample_period()
    if period <= 0:
        return None
    if next(_sample_counter) % period != 0:
        return None
    return TraceContext(mint_trace_id(), ring)


class _Activation:
    """Installs a context as the root of the current control flow."""

    __slots__ = ("_ctx", "_token")

    def __init__(self, ctx: TraceContext):
        self._ctx = ctx
        self._token = None

    def __enter__(self) -> TraceContext:
        self._token = _current.set(_Active(self._ctx, 0))
        return self._ctx

    def __exit__(self, *exc) -> None:
        _current.reset(self._token)


def activate(ctx: TraceContext) -> _Activation:
    """``with activate(ctx): ...`` — make ``ctx`` current (root parent)."""
    return _Activation(ctx)


class _NullSpan:
    """Shared no-op context manager — the untraced path's entire cost."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    """An open span: times itself, nests children, records on exit.

    ``__enter__`` yields the (mutable) meta dict so stages can annotate
    results discovered mid-span (cache hits, byte counts) without a
    second record.
    """

    __slots__ = ("_name", "_meta", "_active", "_sid", "_token", "_t0")

    def __init__(self, name: str, meta: Dict[str, Any], active: _Active):
        self._name = name
        self._meta = meta
        self._active = active
        self._sid = next(_span_ids)
        self._token = None
        self._t0 = 0.0

    def __enter__(self) -> Dict[str, Any]:
        self._token = _current.set(_Active(self._active.ctx, self._sid))
        self._t0 = time.perf_counter()
        return self._meta

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur = time.perf_counter() - self._t0
        _current.reset(self._token)
        if exc_type is not None:
            self._meta["error"] = exc_type.__name__
        ctx = self._active.ctx
        ctx.ring.append(
            {
                "trace": ctx.trace_id,
                "id": self._sid,
                "parent": self._active.span_id,
                "name": self._name,
                "t0": self._t0,
                "dur_s": dur,
                "thread": threading.current_thread().name,
                "meta": self._meta,
            }
        )
        return False


def span(name: str, **meta: Any):
    """``with span("node.fetch", node=3) as s:`` — time one stage.

    Untraced: returns a shared null context manager (one ContextVar read,
    no allocation beyond the kwargs dict).  Traced: opens a child of the
    innermost active span; the yielded dict accepts extra annotations.
    """
    active = _current.get()
    if active is None:
        return _NULL
    return _Span(name, meta, active)


def event(name: str, **meta: Any) -> None:
    """A zero-duration span — point-in-time facts (prefetch admitted,
    cache verdicts) that should land in the tree without nesting."""
    active = _current.get()
    if active is None:
        return
    ctx = active.ctx
    ctx.ring.append(
        {
            "trace": ctx.trace_id,
            "id": next(_span_ids),
            "parent": active.span_id,
            "name": name,
            "t0": time.perf_counter(),
            "dur_s": 0.0,
            "thread": threading.current_thread().name,
            "meta": meta,
        }
    )


def bind(fn: Callable) -> Callable:
    """Carry the caller's active span across a thread-pool submit.

    Returns ``fn`` untouched when nothing is traced — pools on the
    untraced path pay a single ContextVar read per job.  Otherwise the
    wrapper re-installs the capturing span for the duration of the call,
    so worker-side spans nest under the submitting stage.
    """
    active = _current.get()
    if active is None:
        return fn

    def bound(*args, **kwargs):
        token = _current.set(active)
        try:
            return fn(*args, **kwargs)
        finally:
            _current.reset(token)

    return bound


def trace_spans(trace_id: str, ring: Optional[SpanRing] = None) -> List[Dict[str, Any]]:
    """Flat retained spans of one trace (oldest first)."""
    return (ring if ring is not None else RING).spans_for(trace_id)


def trace_tree(trace_id: str, ring: Optional[SpanRing] = None) -> List[Dict[str, Any]]:
    """The span tree: roots (parent missing from the ring) with nested
    ``children``, each child list ordered by start time.  Spans record on
    *exit*, so a parent appears after its children in the ring — the tree
    is assembled from ids, not arrival order."""
    spans = trace_spans(trace_id, ring)
    by_id = {s["id"]: dict(s, children=[]) for s in spans}
    roots: List[Dict[str, Any]] = []
    for s in spans:
        node = by_id[s["id"]]
        parent = by_id.get(s["parent"])
        if parent is not None:
            parent["children"].append(node)
        else:
            roots.append(node)
    for node in by_id.values():
        node["children"].sort(key=lambda c: c["t0"])
    roots.sort(key=lambda c: c["t0"])
    return roots
