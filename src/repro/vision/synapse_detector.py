"""Parallel synapse detection — the paper's driving application (§2, Fig 1).

The paper extracted 19M synapse detections from the bock11 volume with 20
parallel workers reading cutouts and issuing small annotation writes. We
reproduce the *pipeline shape* in JAX:

  workers ->  cutout (read path)  ->  DoG blob filter + threshold
          ->  connected components (label propagation, jax.lax loop)
          ->  size filter (synapses span tens of voxels, §3.1)
          ->  large-structure false-positive mask from a LOW resolution
              level (paper: blood vessels/cell bodies at res 5)
          ->  batch annotation writes (write path / SSD node)

Everything numeric is jittable; workers are host threads, matching the
paper's concurrency model (parallel Web-service requests).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.annotations import Annotation, AnnotationProject
from ..core.cutout import cutout
from ..core.store import CuboidStore


def _gauss_kernel(sigma: float, radius: int) -> jnp.ndarray:
    x = jnp.arange(-radius, radius + 1, dtype=jnp.float32)
    k = jnp.exp(-0.5 * (x / max(sigma, 1e-6)) ** 2)
    return k / k.sum()


@functools.partial(jax.jit, static_argnames=("sigmas", "radius"))
def gaussian_blur(vol: jnp.ndarray, sigmas: Tuple[float, ...],
                  radius: int = 4) -> jnp.ndarray:
    """Separable anisotropic Gaussian blur (sigma per dim; EM Z is coarse)."""
    out = vol.astype(jnp.float32)
    for d, s in enumerate(sigmas):
        if s <= 0:
            continue
        k = _gauss_kernel(s, radius)
        moved = jnp.moveaxis(out, d, -1)
        flat = moved.reshape(-1, moved.shape[-1])
        pad = jnp.pad(flat, ((0, 0), (radius, radius)), mode="edge")
        conv = jax.vmap(lambda row: jnp.convolve(row, k, mode="valid"))(pad)
        out = jnp.moveaxis(conv.reshape(moved.shape), -1, d)
    return out


@functools.partial(jax.jit, static_argnames=("sigma1", "sigma2", "radius"))
def difference_of_gaussians(vol, sigma1=(1.0, 1.0, 0.5),
                            sigma2=(3.0, 3.0, 1.5), radius=4):
    """Band-pass blob response; synapses are bright compact blobs."""
    return gaussian_blur(vol, sigma1, radius) - gaussian_blur(
        vol, sigma2, radius)


@functools.partial(jax.jit, static_argnames=("connectivity",))
def connected_components(mask: jnp.ndarray,
                         connectivity: int = 6) -> jnp.ndarray:
    """Label 3-d connected components by iterative min-label propagation.

    Each foreground voxel starts with its flat index + 1; every sweep takes
    the min over face neighbors; a `lax.while_loop` runs to fixpoint. On TPU
    this is embarrassingly vectorizable (shifts + minimum) — the adaptation
    of a classically pointer-chasing CPU algorithm to SIMD hardware.
    """
    fg = mask != 0
    init = jnp.where(
        fg, jnp.arange(1, mask.size + 1,
                       dtype=jnp.int32).reshape(mask.shape), 0)
    big = jnp.int32(mask.size + 2)

    def neighbor_min(lab):
        padded = jnp.where(fg, lab, big)
        best = padded
        for d in range(mask.ndim):
            for shift in (1, -1):
                rolled = jnp.roll(padded, shift, axis=d)
                # zero-pad the wrap-around plane
                idx = 0 if shift == 1 else -1
                rolled = _set_plane(rolled, d, idx, big)
                best = jnp.minimum(best, rolled)
        return jnp.where(fg, jnp.minimum(lab, best), 0)

    def cond(state):
        lab, prev, it = state
        return jnp.logical_and(jnp.any(lab != prev), it < mask.size)

    def body(state):
        lab, _, it = state
        return neighbor_min(lab), lab, it + 1

    lab, _, _ = jax.lax.while_loop(
        cond, body, (neighbor_min(init), init, jnp.int32(0)))
    return lab


def _set_plane(arr, axis, idx, value):
    sl = [slice(None)] * arr.ndim
    sl[axis] = idx
    return arr.at[tuple(sl)].set(value)


@functools.partial(jax.jit, static_argnames=("sigma", "radius", "quantile"))
def large_structure_mask(lowres_vol, sigma=(6.0, 6.0, 3.0), radius=8,
                         quantile=0.9):
    """Mask of large bright structures (vessels, somata) at low resolution.

    Paper §3.1: computed at res 5 where 'structures are large and detectable
    at low resolution and the computation requires all data in memory'.
    The heavy blur is what makes this selective for LARGE structures:
    synapse-scale blobs wash out, vessel/soma-scale structures persist.
    """
    smooth = gaussian_blur(lowres_vol, sigma, radius)
    thr = jnp.quantile(smooth, quantile)
    return smooth >= thr


@dataclasses.dataclass
class Detection:
    centroid: Tuple[float, ...]
    n_voxels: int
    bbox_lo: Tuple[int, ...]
    bbox_hi: Tuple[int, ...]
    confidence: float


def detect_synapses(vol: np.ndarray, threshold: float = 2.0,
                    min_voxels: int = 8, max_voxels: int = 512,
                    exclusion_mask: Optional[np.ndarray] = None
                    ) -> Tuple[List[Detection], np.ndarray]:
    """Detect synapse-like blobs in one cutout. Returns detections + labels."""
    x = jnp.asarray(vol, dtype=jnp.float32)
    resp = difference_of_gaussians(x)
    resp = (resp - resp.mean()) / (resp.std() + 1e-6)
    mask = resp > threshold
    if exclusion_mask is not None:
        mask = jnp.logical_and(mask, ~jnp.asarray(exclusion_mask))
    labels = np.asarray(connected_components(mask))
    dets: List[Detection] = []
    out_labels = np.zeros_like(labels)
    resp_np = np.asarray(resp)
    next_id = 1
    for lab in np.unique(labels):
        if lab == 0:
            continue
        where = np.argwhere(labels == lab)
        n = len(where)
        if not (min_voxels <= n <= max_voxels):
            continue  # too small = noise; too big = not a synapse (§3.1)
        lo = where.min(axis=0)
        hi = where.max(axis=0) + 1
        conf = float(1.0 / (1.0 + np.exp(
            -resp_np[tuple(where.T)].mean())))
        dets.append(Detection(tuple(where.mean(axis=0)), n,
                              tuple(int(v) for v in lo),
                              tuple(int(v) for v in hi), conf))
        out_labels[tuple(where.T)] = next_id
        next_id += 1
    return dets, out_labels


def run_parallel_detection(image_store: CuboidStore,
                           project: AnnotationProject,
                           r: int, tile: Sequence[int],
                           n_workers: int = 4,
                           threshold: float = 2.0,
                           min_voxels: int = 8,
                           batch_size: int = 40,
                           lowres_level: Optional[int] = None) -> int:
    """The full paper workflow: parallel workers over a tiling of the volume.

    Each worker: cutout -> detect -> batch-write annotations (batch of 40,
    the size the paper found doubled synapse-finder throughput).
    Returns number of synapses written.
    """
    grid = image_store.spec.grid(r)
    vol_shape = grid.volume_shape
    tiles = []
    t = list(tile)
    for x0 in range(0, vol_shape[0], t[0]):
        for y0 in range(0, vol_shape[1], t[1]):
            for z0 in range(0, vol_shape[2], t[2]):
                lo = (x0, y0, z0)
                hi = tuple(min(v, o + s)
                           for v, o, s in zip(vol_shape, lo, t))
                tiles.append((lo, hi))

    excl_full = None
    if lowres_level is not None and lowres_level < image_store.spec.n_resolutions:
        lg = image_store.spec.grid(lowres_level)
        low = cutout(image_store, lowres_level, (0,) * 3, lg.volume_shape)
        excl_full = np.asarray(large_structure_mask(
            jnp.asarray(low, jnp.float32)))

    def scale_mask(lo, hi):
        if excl_full is None:
            return None
        f = 1 << (lowres_level - r)
        sub = excl_full[lo[0] // f:max(lo[0] // f + 1, -(-hi[0] // f)),
                        lo[1] // f:max(lo[1] // f + 1, -(-hi[1] // f)),
                        lo[2]:hi[2]]
        out = np.repeat(np.repeat(sub, f, axis=0), f, axis=1)
        return out[:hi[0] - lo[0], :hi[1] - lo[1], :hi[2] - lo[2]]

    total = 0

    def work(box):
        nonlocal total
        lo, hi = box
        vol = cutout(image_store, r, lo, hi)
        dets, labels = detect_synapses(
            vol, threshold=threshold, min_voxels=min_voxels,
            exclusion_mask=scale_mask(lo, hi))
        if not dets:
            return 0
        # batch writes of `batch_size` objects (paper §4.2)
        objs = []
        for i, d in enumerate(dets):
            sub = (labels == i + 1).astype(np.uint32)
            objs.append((Annotation(0, ann_type="synapse",
                                    confidence=d.confidence,
                                    kv={"n_voxels": d.n_voxels}),
                         lo, sub))
        written = 0
        for i in range(0, len(objs), batch_size):
            ids = project.batch_write_objects(r, objs[i:i + batch_size])
            written += len(ids)
        return written

    with cf.ThreadPoolExecutor(max_workers=n_workers) as ex:
        for n in ex.map(work, tiles):
            total += n
    return total
