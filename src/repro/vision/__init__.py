from .synapse_detector import (connected_components, detect_synapses,
                               difference_of_gaussians, gaussian_blur,
                               large_structure_mask, run_parallel_detection)

__all__ = ["connected_components", "detect_synapses",
           "difference_of_gaussians", "gaussian_blur",
           "large_structure_mask", "run_parallel_detection"]
