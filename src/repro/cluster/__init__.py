"""Spatially-partitioned data cluster (paper §4.1): sharded stores,
stateless routing, and the RESTful-style service verbs over them."""

from .handlers import (
    HANDLERS,
    VolumeService,
    dispatch,
    get_annotation_bbox,
    get_cutout,
    get_object_cutout,
    get_projection,
    put_cutout,
)
from .router import Router
from .store import ClusterStore

__all__ = [
    "ClusterStore",
    "Router",
    "VolumeService",
    "HANDLERS",
    "dispatch",
    "get_cutout",
    "put_cutout",
    "get_projection",
    "get_annotation_bbox",
    "get_object_cutout",
]
