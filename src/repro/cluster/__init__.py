"""Spatially-partitioned data cluster (paper §4.1): sharded stores,
stateless routing, the hot-cuboid cache tier + write-behind ingest queue
(paper §6 vision), and the RESTful-style service verbs over them."""

from .cache import (
    CuboidCache,
    WriteBehindQueue,
    attach_cache,
    enable_write_behind,
)
from .handlers import (
    HANDLERS,
    VolumeService,
    dispatch,
    get_annotation_bbox,
    get_cutout,
    get_object_cutout,
    get_projection,
    get_stats,
    post_flush,
    put_cutout,
)
from .router import Router
from .store import ClusterStore

__all__ = [
    "ClusterStore",
    "Router",
    "CuboidCache",
    "WriteBehindQueue",
    "attach_cache",
    "enable_write_behind",
    "VolumeService",
    "HANDLERS",
    "dispatch",
    "get_cutout",
    "put_cutout",
    "get_projection",
    "get_annotation_bbox",
    "get_object_cutout",
    "post_flush",
    "get_stats",
]
