"""Spatially-partitioned *elastic* data cluster (paper §4.1 + §6):
sharded stores, stateless routing over movable curve partitions, live
rebalancing with segment migration, the hot-cuboid cache tier +
write-behind ingest queue, and the RESTful-style service verbs over
them."""

from ..core.store import DecodePolicy
from .cache import (
    CuboidCache,
    WriteBehindQueue,
    attach_cache,
    enable_write_behind,
)
from .handlers import (
    HANDLERS,
    VolumeService,
    dispatch,
    get_annotation_bbox,
    get_cutout,
    get_object_cutout,
    get_projection,
    get_stats,
    get_topology,
    post_flush,
    post_rebalance,
    put_cutout,
)
from .router import Partition, Router
from .store import ClusterStore

__all__ = [
    "ClusterStore",
    "Router",
    "Partition",
    "DecodePolicy",
    "CuboidCache",
    "WriteBehindQueue",
    "attach_cache",
    "enable_write_behind",
    "VolumeService",
    "HANDLERS",
    "dispatch",
    "get_cutout",
    "put_cutout",
    "get_projection",
    "get_annotation_bbox",
    "get_object_cutout",
    "post_flush",
    "get_stats",
    "get_topology",
    "post_rebalance",
]
