"""Spatially-partitioned *elastic* data cluster (paper §4.1 + §6):
sharded stores with per-segment replication, stateless routing over
movable curve partitions, live rebalancing with segment migration, the
hot-cuboid cache tier + write-behind ingest queue, and the RESTful-style
service verbs (flat verb table + URL-routed v1 paths) over them."""

from ..core.store import DecodePolicy
from .api import ApiError, parse_url, url_dispatch
from .cache import (
    CuboidCache,
    WriteBehindQueue,
    attach_cache,
    enable_write_behind,
)
from .handlers import (
    HANDLERS,
    VolumeService,
    dispatch,
    get_annotation_bbox,
    get_cutout,
    get_object_cutout,
    get_projection,
    get_stats,
    get_topology,
    post_add_node,
    post_batch_cutout,
    post_flush,
    post_rebalance,
    post_remove_node,
    put_cutout,
)
from .router import Partition, Router
from .store import ClusterStore, RebalanceInFlight

__all__ = [
    "ClusterStore",
    "RebalanceInFlight",
    "Router",
    "Partition",
    "DecodePolicy",
    "CuboidCache",
    "WriteBehindQueue",
    "attach_cache",
    "enable_write_behind",
    "VolumeService",
    "HANDLERS",
    "dispatch",
    "ApiError",
    "parse_url",
    "url_dispatch",
    "get_cutout",
    "put_cutout",
    "get_projection",
    "get_annotation_bbox",
    "get_object_cutout",
    "post_batch_cutout",
    "post_flush",
    "get_stats",
    "get_topology",
    "post_rebalance",
    "post_add_node",
    "post_remove_node",
]
