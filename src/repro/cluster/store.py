"""Spatially-partitioned *elastic* cluster store (paper §4.1 + §6).

A :class:`ClusterStore` owns N node shards — each a full `CuboidStore` with
its own read/write backends and `PathStats` (the paper's database node with
a disk-array read path and an SSD write path) — and routes every cuboid and
run to its owning node with a stateless :class:`Router`.  It implements the
same storage interface the cutout engine drives (`fetch_runs`,
`store_cuboids`, `read_cuboid`, ...), so `cutout()` / `write_cutout()` work
unchanged over a cluster, and batch I/O fans out across nodes in parallel
(one thread per touched node: the paper's parallel-requests doctrine C8
applied *inside* one request).

Elasticity (paper §6 "dynamically redistribute data"): the cluster is not
pinned to its initial shard count.  ``rebalance(target=...)`` re-cuts the
per-resolution curve partitions by occupancy and migrates the keys whose
owner changes *live* — concurrent reads and writes stay bit-identical
throughout.  ``add_node()`` / ``remove_node()`` grow and shrink the node
set through the same protocol.  The migration protocol, per segment move:

1. **register** — the move set is published and a grace period waits for
   in-flight ops, so every subsequent write to a moving key *double-writes*
   to both the old and the new owner (through each node's write-behind
   queue when attached — the queue is the natural double-write buffer).
2. **copy** — existing keys in the moving range are streamed from the old
   owner to the new one as compressed blobs, in small batches under the
   move lock, so a racing double-write can never be clobbered by a stale
   copy.  Reads keep routing to the old owner, which stays complete.
3. **swap** — a new Router (new `Partition` boundaries) is published
   atomically; a grace period drains readers still on the old boundaries.
4. **cleanup** — after a final writer grace period, moved keys are deleted
   from the old owner and dropped from its `CuboidCache` (the new owner's
   cache absorbed them during the copy).

Topology (the node tuple + the Router) is an immutable snapshot swapped
atomically, so every op sees one consistent (nodes, boundaries) pair even
while a rebalance is in flight; `GET /topology` exposes it.
"""

from __future__ import annotations

import concurrent.futures as cf
import contextlib
import dataclasses
import functools
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import morton
from ..core.cuboid import DatasetSpec
from ..core.store import BlockSink, CuboidStore, DecodePolicy, Key, MemoryBackend, PathStats
from .cache import attach_cache, enable_write_behind
from .router import Partition, Router

NodeFactory = Callable[[int, DatasetSpec], CuboidStore]

# (start, stop, src_node, dst_node) — one migrating curve segment.
Move = Tuple[int, int, int, int]


def _default_node_factory(node: int, spec: DatasetSpec) -> CuboidStore:
    """In-memory node with a separated write path (SSD-node analogue)."""
    return CuboidStore(spec, backend=MemoryBackend(), write_path_backend=MemoryBackend())


# Gauges describe *current* per-node occupancy, not accumulated work:
# summing them over-reports (a 2-node cluster would claim twice the real
# queue peak), so the cluster aggregate takes the max instead.
_GAUGE_FIELDS = frozenset({"queue_depth", "queue_peak"})


def _sum_stats(parts: Sequence[PathStats]) -> PathStats:
    out = PathStats()
    for p in parts:
        for f in dataclasses.fields(PathStats):
            if f.name in _GAUGE_FIELDS:
                setattr(out, f.name, max(getattr(out, f.name), getattr(p, f.name)))
            else:
                setattr(out, f.name, getattr(out, f.name) + getattr(p, f.name))
    return out


@dataclasses.dataclass(frozen=True)
class _Topology:
    """One atomic (nodes, router) snapshot — ops resolve both together."""

    nodes: Tuple[CuboidStore, ...]
    router: Router


class _OpGate:
    """RCU-style grace periods for topology changes.

    Data ops enter/exit; `synchronize()` opens a new epoch and blocks until
    every op that started under an older epoch has drained.  Rebalance uses
    it so a published move set / router swap is *seen* by all traffic
    before the next phase relies on it.  Ops never block each other.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._epoch = 0
        self._active: Dict[int, int] = {}

    @contextlib.contextmanager
    def op(self):
        with self._cond:
            epoch = self._epoch
            self._active[epoch] = self._active.get(epoch, 0) + 1
        try:
            yield
        finally:
            with self._cond:
                self._active[epoch] -= 1
                if self._active[epoch] == 0:
                    del self._active[epoch]
                    self._cond.notify_all()

    def synchronize(self, timeout: float = 60.0) -> None:
        with self._cond:
            self._epoch += 1
            fence = self._epoch
            deadline = time.monotonic() + timeout
            while any(e < fence and n > 0 for e, n in self._active.items()):
                self._cond.wait(0.05)
                # measured against the clock, not wakeup counts: notify_all
                # fires on every op completion and would exhaust a counter
                # in seconds under sustained traffic
                if time.monotonic() >= deadline:
                    raise TimeoutError("op gate synchronize timed out")


def _move_dst(moves: Dict[int, Tuple[Move, ...]], r: int, m: int) -> Optional[int]:
    """Destination node if (r, m) is currently migrating, else None."""
    for start, stop, _, dst in moves.get(r, ()):
        if start <= m < stop:
            return dst
    return None


class ClusterStore:
    """N `CuboidStore` shards behind one storage interface.

    ``node_factory(i, spec)`` builds shard ``i`` — supply it to give nodes
    directory backends, distinct write paths, etc.  ``max_workers`` bounds
    per-request node parallelism (default: one worker per node; ``0``/``1``
    forces serial fan-out, useful for deterministic profiling).

    ``cache_bytes`` attaches a hot-cuboid cache to every node (the budget
    is split evenly across the initial shards; nodes added later get the
    same per-node budget); ``write_behind`` attaches a per-node
    write-behind ingest queue (``flush()`` is the durability barrier, see
    ``repro.cluster.cache``).  Both default to the ``REPRO_CACHE_BYTES`` /
    ``REPRO_WRITE_BEHIND`` environment knobs (the CI cache matrix leg runs
    tier-1 with them set), and neither overrides a tier the node factory
    already attached.

    Elasticity: ``rebalance(target=n)`` / ``add_node()`` /
    ``remove_node()`` re-partition by occupancy (``keys_per_node()`` is
    the signal) and migrate keys live; see the module docstring for the
    coherence protocol.  ``topology()`` is the introspection snapshot the
    ``GET /topology`` verb serves.
    """

    def __init__(
        self,
        spec: DatasetSpec,
        n_nodes: int = 2,
        node_factory: Optional[NodeFactory] = None,
        max_workers: Optional[int] = None,
        cache_bytes: Optional[int] = None,
        write_behind: Optional[bool] = None,
        write_behind_items: int = 512,
        decode_policy: Optional[DecodePolicy] = None,
    ):
        self.spec = spec
        self._node_factory = node_factory or _default_node_factory
        if cache_bytes is None:
            cache_bytes = int(os.environ.get("REPRO_CACHE_BYTES", "0") or 0) or None
        if write_behind is None:
            write_behind = os.environ.get("REPRO_WRITE_BEHIND", "0") not in ("", "0")
        self._node_cache_bytes = max(1, int(cache_bytes) // n_nodes) if cache_bytes else 0
        self._write_behind = bool(write_behind)
        self._write_behind_items = write_behind_items
        # One DecodePolicy for every shard: the per-node fan-out workers
        # decode into a pool shared across the whole process, so the
        # cluster's cold-read parallelism is nodes x decode chunks without
        # per-node thread oversubscription.  None leaves factory-built
        # nodes on their own (env-derived) policy.
        self.decode_policy = decode_policy
        nodes = tuple(self._build_node(i) for i in range(n_nodes))
        self._topo = _Topology(nodes, Router(spec, n_nodes))
        self._gate = _OpGate()
        # Serializes whole rebalances; RLock so add/remove can nest into
        # rebalance().
        self._admin_lock = threading.RLock()
        # Serializes the copy phase with double-writes to *moving* keys so
        # a stale copy can never clobber a fresher concurrent write.
        self._move_lock = threading.Lock()
        # {resolution: ((start, stop, src, dst), ...)} — published
        # atomically; empty outside an active migration.
        self._moves: Dict[int, Tuple[Move, ...]] = {}
        self._cfg_max_workers = max_workers
        self._retired_pools: List[cf.ThreadPoolExecutor] = []
        workers = n_nodes if max_workers is None else max_workers
        if workers > 1:
            self._pool = cf.ThreadPoolExecutor(max_workers=workers, thread_name_prefix="ocp-node")
        else:
            self._pool = None
        # Request-level pool for batch_cutout's multi-box overlap — lazily
        # created, and deliberately DISTINCT from the node fan-out pool: a
        # batch job itself fans out to nodes and blocks on their futures,
        # and nesting both levels in one bounded pool deadlocks the moment
        # every worker holds a waiting outer job.
        self._batch_pool: Optional[cf.ThreadPoolExecutor] = None
        self._batch_lock = threading.Lock()

    def _build_node(self, i: int, factory: Optional[NodeFactory] = None) -> CuboidStore:
        node = (factory or self._node_factory)(i, self.spec)
        if self._node_cache_bytes and node.cache is None:
            attach_cache(node, self._node_cache_bytes)
        if self._write_behind and node.write_behind is None:
            enable_write_behind(node, max_items=self._write_behind_items)
        if self.decode_policy is not None:
            node.decode_policy = self.decode_policy
        return node

    # -- cluster admin -----------------------------------------------------
    @property
    def nodes(self) -> List[CuboidStore]:
        """The current node shards (a snapshot copy — topology may move)."""
        return list(self._topo.nodes)

    @property
    def router(self) -> Router:
        return self._topo.router

    @property
    def n_nodes(self) -> int:
        return len(self._topo.nodes)

    @property
    def has_cache(self) -> bool:
        return any(node.cache is not None for node in self._topo.nodes)

    def flush(self) -> int:
        """Durability barrier: drain every node's write-behind queue.

        Returns the total number of pending writes applied.  When it
        returns, everything previously written through the cluster is in
        the node backends (the contract ``POST /flush`` exposes)."""
        with self._gate.op():
            nodes = self._topo.nodes
            jobs = {i: nodes[i].flush for i in range(len(nodes))}
            return sum(self._fan_out(jobs).values())

    def close(self) -> None:
        for node in self._topo.nodes:
            node.close()  # flushes + stops per-node write-behind flushers
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        with self._batch_lock:  # serialize with run_batch's lazy creation
            batch_pool, self._batch_pool = self._batch_pool, None
        if batch_pool is not None:
            batch_pool.shutdown(wait=True)
        for pool in self._retired_pools:
            pool.shutdown(wait=True)
        self._retired_pools = []

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _fan_out(self, jobs: Dict[int, Callable[[], object]]) -> Dict[int, object]:
        """Run one job per touched node, in parallel when a pool exists."""
        pool = self._pool
        if pool is None or len(jobs) <= 1:
            return {n: job() for n, job in jobs.items()}
        futures = {n: pool.submit(job) for n, job in jobs.items()}
        return {n: f.result() for n, f in futures.items()}

    # -- single-cuboid ops (routed) ----------------------------------------
    def read_cuboid(self, r: int, m: int, channel: int = 0) -> np.ndarray:
        with self._gate.op():
            topo = self._topo
            return topo.nodes[topo.router.owner(r, m)].read_cuboid(r, m, channel)

    def write_cuboid(self, r: int, m: int, data: np.ndarray, channel: int = 0) -> None:
        with self._gate.op():
            topo = self._topo
            owner = topo.router.owner(r, m)
            dst = _move_dst(self._moves, r, m) if self._moves else None
            if dst is None or dst == owner:
                topo.nodes[owner].write_cuboid(r, m, data, channel)
            else:
                # double-write: the segment is migrating owner -> dst;
                # serialize with the copier so it can't overwrite us.
                with self._move_lock:
                    topo.nodes[owner].write_cuboid(r, m, data, channel)
                    topo.nodes[dst].write_cuboid(r, m, data, channel)

    def has_cuboid(self, r: int, m: int, channel: int = 0) -> bool:
        with self._gate.op():
            topo = self._topo
            return topo.nodes[topo.router.owner(r, m)].has_cuboid(r, m, channel)

    # -- batch ops (routed + parallel) -------------------------------------
    def read_run(self, r: int, start: int, stop: int, channel: int = 0) -> List[np.ndarray]:
        """Run read in curve order, split at partition boundaries."""
        with self._gate.op():
            topo = self._topo
            out: List[np.ndarray] = []
            for node, a, b in topo.router.split_run(r, start, stop):
                out.extend(topo.nodes[node].read_run(r, a, b, channel))
            return out

    def fetch_runs(
        self,
        r: int,
        runs: Sequence[Tuple[int, int]],
        channel: int = 0,
        decode: bool = False,
    ):
        """Batch blob fetch: split runs by owner, fetch nodes in parallel.

        ``decode=True`` is the pipelined cold-read mode: each node worker
        decompresses its own runs' blobs (chunked over the shared decode
        pool) and the merged result maps morton index to decoded block —
        decode work rides the per-node fan-out instead of serializing in
        the caller thread.
        """
        with self._gate.op():
            topo = self._topo
            by_node = topo.router.split_runs(r, list(runs))
            jobs = {
                node: functools.partial(
                    topo.nodes[node].fetch_runs, r, node_runs, channel, decode=decode
                )
                for node, node_runs in by_node.items()
            }
            merged: Dict[int, object] = {}
            for part in self._fan_out(jobs).values():
                merged.update(part)
            return merged

    def fetch_blocks(
        self,
        r: int,
        runs: Sequence[Tuple[int, int]],
        channel: int = 0,
        sink: Optional[BlockSink] = None,
    ) -> Dict[int, Optional[np.ndarray]]:
        """Decoded-cuboid batch fetch, fanned out per node.

        Every node worker runs the full pipelined cold path on its own
        runs — cache lookups, parallel decompress, plan-driven segment
        prefetch — and with ``sink`` it assembles straight into the
        caller's shared output buffer (the cutout engine passes a sink
        writing disjoint ``buf_slices``, so concurrent node workers never
        race).  Without a sink, returns the merged block dict.
        """
        with self._gate.op():
            topo = self._topo
            by_node = topo.router.split_runs(r, list(runs))
            jobs = {
                node: functools.partial(
                    topo.nodes[node].fetch_blocks, r, node_runs, channel, sink=sink
                )
                for node, node_runs in by_node.items()
            }
            merged: Dict[int, Optional[np.ndarray]] = {}
            for part in self._fan_out(jobs).values():
                if part:
                    merged.update(part)
            return merged

    def run_batch(self, jobs: Sequence[Callable[[], object]]) -> List[object]:
        """Overlap independent request-level jobs (the §4.2 batch
        interface): each job typically drives a whole cutout, whose node
        fan-out and decode chunks then pipeline with the other boxes'.
        Serial when request parallelism is disabled (``max_workers<=1``).
        """
        jobs = list(jobs)
        # Request overlap is its own axis: a single-node cluster still
        # pipelines one box's I/O against another's decode.  Only an
        # explicit max_workers<=1 (the deterministic-profiling knob)
        # forces serial execution.
        serial = self._cfg_max_workers is not None and self._cfg_max_workers <= 1
        if serial or len(jobs) <= 1:
            return [job() for job in jobs]
        with self._batch_lock:
            if self._batch_pool is None:
                self._batch_pool = cf.ThreadPoolExecutor(
                    max_workers=min(8, max(2, len(self._topo.nodes))),
                    thread_name_prefix="ocp-batch",
                )
            pool = self._batch_pool
        futures = [pool.submit(job) for job in jobs]
        return [f.result() for f in futures]

    def store_cuboids(self, r: int, blocks: Dict[int, np.ndarray], channel: int = 0) -> None:
        """Batch write: group blocks by owner, write nodes in parallel.

        Blocks inside a migrating segment are written to *both* the old
        and the new owner (under the move lock), keeping the destination
        complete before the boundary swap makes it authoritative.
        """
        with self._gate.op():
            topo = self._topo
            moves = self._moves.get(r, ()) if self._moves else ()
            by_node: Dict[int, Dict[int, np.ndarray]] = {}
            doubling: Dict[int, Dict[int, np.ndarray]] = {}
            for m, data in blocks.items():
                owner = topo.router.owner(r, m)
                dst = None
                if moves:
                    dst = next((d for a, b, _, d in moves if a <= m < b), None)
                if dst is not None and dst != owner:
                    # migrating: double-write owner + dst under the move
                    # lock (serialized with the copier)
                    doubling.setdefault(owner, {})[m] = data
                    doubling.setdefault(dst, {})[m] = data
                else:
                    by_node.setdefault(owner, {})[m] = data
            if by_node:  # non-moving blocks never wait on the move lock
                jobs = {
                    node: functools.partial(
                        topo.nodes[node].store_cuboids, r, node_blocks, channel
                    )
                    for node, node_blocks in by_node.items()
                }
                self._fan_out(jobs)
            if doubling:
                jobs = {
                    node: functools.partial(
                        topo.nodes[node].store_cuboids, r, node_blocks, channel
                    )
                    for node, node_blocks in doubling.items()
                }
                with self._move_lock:
                    self._fan_out(jobs)

    # -- elasticity (paper §6: dynamically redistribute data) ---------------
    def topology(self) -> Dict[str, object]:
        """Introspection snapshot served by ``GET /topology``."""
        with self._gate.op():
            topo = self._topo
            return {
                "n_nodes": len(topo.nodes),
                "elastic": True,
                "rebalancing": bool(self._moves),
                "segments": {
                    r: topo.router.segments(r) for r in range(self.spec.n_resolutions)
                },
                "keys_per_node": self._key_counts(topo),
                "cache_nodes": sum(1 for n in topo.nodes if n.cache is not None),
                "write_behind_nodes": sum(
                    1 for n in topo.nodes if n.write_behind is not None
                ),
            }

    def add_node(
        self, node_factory: Optional[NodeFactory] = None, rebalance: bool = True
    ) -> int:
        """Grow the cluster by one shard; returns the new node's index.

        With ``rebalance=True`` (default) keys migrate onto it immediately
        (occupancy-balanced); otherwise it joins owning nothing until the
        next ``rebalance()``.
        """
        with self._admin_lock:
            index = self.n_nodes
            if rebalance:
                self.rebalance(target=index + 1, node_factory=node_factory)
            else:
                self._widen(index + 1, node_factory)
            return index

    def remove_node(self, node: int = -1) -> Dict[str, object]:
        """Shrink the cluster: migrate ``node``'s keys off, then drop it."""
        with self._admin_lock:
            topo = self._topo
            n = len(topo.nodes)
            if n <= 1:
                raise ValueError("cannot remove the last node")
            idx = node if node >= 0 else n + node
            if not (0 <= idx < n):
                raise ValueError(f"node {node} out of range for {n} nodes")
            t0 = time.perf_counter()
            # Target: node idx owns nothing; the survivors re-cut by
            # occupancy.  Built by inserting a zero-span segment at idx
            # into the (n-1)-way balanced bounds.
            occupancy = self._occupancy(topo)
            target_parts: Dict[int, Partition] = {}
            final_parts: Dict[int, Partition] = {}
            for r in range(self.spec.n_resolutions):
                survivors = Partition.balanced(
                    occupancy.get(r, ()), topo.router.n_cells(r), n - 1
                )
                b = survivors.bounds
                target_parts[r] = Partition(b[: idx + 1] + (b[idx],) + b[idx + 1 :])
                final_parts[r] = survivors
            moved_keys, moved_bytes = self._migrate_live(topo, target_parts)
            # Drop the (now empty) node from the topology, then let every
            # in-flight op drain before closing it.
            kept = topo.nodes[:idx] + topo.nodes[idx + 1 :]
            self._swap_topo(_Topology(kept, Router(self.spec, n - 1, final_parts)))
            self._gate.synchronize()
            topo.nodes[idx].close()
            return {
                "n_nodes": n - 1,
                "removed": idx,
                "moved_keys": moved_keys,
                "moved_bytes": moved_bytes,
                "seconds": time.perf_counter() - t0,
            }

    def rebalance(
        self,
        target: Optional[int] = None,
        node_factory: Optional[NodeFactory] = None,
        batch_keys: int = 64,
    ) -> Dict[str, object]:
        """Re-partition by occupancy and migrate keys live.

        ``target`` is the desired node count (default: keep the current
        one and only move boundaries).  Growth appends fresh shards first
        (owning nothing), shrink drops the trailing shards after their
        keys migrate off.  Returns migration stats; see the module
        docstring for the coherence protocol.
        """
        with self._admin_lock:
            t0 = time.perf_counter()
            n_old = self.n_nodes
            n_new = n_old if target is None else int(target)
            if n_new <= 0:
                raise ValueError("rebalance target must be positive")
            if n_new > n_old:
                self._widen(n_new, node_factory)
            topo = self._topo
            n_wide = len(topo.nodes)
            occupancy = self._occupancy(topo)
            target_parts: Dict[int, Partition] = {}
            final_parts: Dict[int, Partition] = {}
            for r in range(self.spec.n_resolutions):
                n_cells = topo.router.n_cells(r)
                part = Partition.balanced(occupancy.get(r, ()), n_cells, n_new)
                final_parts[r] = part
                if n_wide > n_new:  # shrinking: trailing shards own nothing
                    part = Partition(part.bounds + (n_cells,) * (n_wide - n_new))
                target_parts[r] = part
            try:
                moved_keys, moved_bytes = self._migrate_live(
                    topo, target_parts, batch_keys=batch_keys
                )
            except BaseException:
                if n_new > n_old:
                    self._unwiden(n_old)
                raise
            if n_wide > n_new:
                topo = self._topo
                dropped = topo.nodes[n_new:]
                self._swap_topo(
                    _Topology(topo.nodes[:n_new], Router(self.spec, n_new, final_parts))
                )
                self._gate.synchronize()
                for node in dropped:
                    node.close()
            return {
                "n_nodes": n_new,
                "moved_keys": moved_keys,
                "moved_bytes": moved_bytes,
                "seconds": time.perf_counter() - t0,
            }

    def _swap_topo(self, topo: _Topology) -> None:
        self._topo = topo  # atomic reference swap; ops snapshot it once
        if self._cfg_max_workers is not None:
            return  # caller pinned the worker count; keep it
        pool = self._pool
        workers = getattr(pool, "_max_workers", 0) if pool is not None else 0
        if len(topo.nodes) > max(workers, 1):
            # Grow fan-out parallelism with the cluster.  The old pool is
            # retired, not shut down: in-flight ops hold a reference and
            # may still submit to it; close() reaps every generation.
            if pool is not None:
                self._retired_pools.append(pool)
            self._pool = cf.ThreadPoolExecutor(
                max_workers=len(topo.nodes), thread_name_prefix="ocp-node"
            )

    def _widen(self, n_new: int, node_factory: Optional[NodeFactory]) -> None:
        """Append fresh shards that own nothing: every resolution's
        partition is pinned to its current bounds (+ empty tail segments),
        so ownership is unchanged until a migration moves it."""
        topo = self._topo
        n_old = len(topo.nodes)
        nodes = list(topo.nodes)
        for i in range(n_old, n_new):
            nodes.append(self._build_node(i, node_factory))
        pinned = {}
        for r in range(self.spec.n_resolutions):
            part = topo.router.partition(r)
            pinned[r] = Partition(part.bounds + (part.n_cells,) * (n_new - n_old))
        self._swap_topo(_Topology(tuple(nodes), Router(self.spec, n_new, pinned)))
        self._gate.synchronize()  # all traffic now sees the widened topology

    def _unwiden(self, n_old: int) -> None:
        """Undo `_widen` after a failed grow-migration: drop the appended
        shards again — but only while they still own nothing (the failed
        migration never swapped ownership onto them; `_migrate_live`'s
        rollback already wiped any blobs it landed there).  Without this,
        every failed ``POST /rebalance`` would leak a set of phantom
        nodes (threads, queues, caches) and misreport the cluster size."""
        topo = self._topo
        tail_segments = [
            seg
            for r in range(self.spec.n_resolutions)
            for seg in topo.router.segments(r)[n_old:]
        ]
        if any(a != b for a, b in tail_segments):
            return  # ownership already moved; the widened nodes must stay
        dropped = topo.nodes[n_old:]
        parts = {
            r: Partition(topo.router.partition(r).bounds[: n_old + 1])
            for r in range(self.spec.n_resolutions)
        }
        self._swap_topo(_Topology(topo.nodes[:n_old], Router(self.spec, n_old, parts)))
        self._gate.synchronize()
        for node in dropped:
            try:
                node.close()
            except Exception:
                continue  # the original migration failure is re-raising

    def _occupancy(self, topo: _Topology) -> Dict[int, List[int]]:
        """{resolution: multiset of occupied cells} — the rebalance signal
        (`keys_per_node()` is its per-node projection)."""
        occupancy: Dict[int, List[int]] = {}
        for node in topo.nodes:
            for r, _c, m in node.stored_keys():
                occupancy.setdefault(r, []).append(m)
        return occupancy

    def _migrate_live(
        self,
        topo: _Topology,
        target_parts: Dict[int, Partition],
        batch_keys: int = 64,
    ) -> Tuple[int, int]:
        """Move ownership from the current partitions to ``target_parts``
        with zero lost or stale reads (the module-docstring protocol).
        Returns (moved_keys, moved_bytes)."""
        moves: Dict[int, Tuple[Move, ...]] = {}
        for r, new_part in target_parts.items():
            diff = tuple(topo.router.partition(r).moves(new_part))
            if diff:
                moves[r] = diff
        if not moves:  # boundaries unchanged (or only empty ranges moved)
            self._swap_topo(_Topology(topo.nodes, topo.router.with_partitions(target_parts)))
            return 0, 0

        # 1. register: publish the move set; once every in-flight op has
        # drained, all writes to moving keys double-write.
        self._moves = moves
        moved_keys = moved_bytes = 0
        swapped = False
        try:
            self._gate.synchronize()
            src_nodes = {src for entries in moves.values() for _, _, src, _ in entries}
            keys_by_src = {src: topo.nodes[src].stored_keys() for src in src_nodes}
            # 2. copy: stream existing keys src -> dst in small batches
            # under the move lock (serialized with double-writes so a
            # stale copy can never overwrite a fresher concurrent write).
            for r, entries in sorted(moves.items()):
                for start, stop, src, dst in entries:
                    by_channel: Dict[int, List[int]] = {}
                    for kr, kc, km in keys_by_src[src]:
                        if kr == r and start <= km < stop:
                            by_channel.setdefault(kc, []).append(km)
                    for c, ms in sorted(by_channel.items()):
                        ms.sort()
                        for i in range(0, len(ms), batch_keys):
                            chunk = ms[i : i + batch_keys]
                            with self._move_lock:
                                blobs = topo.nodes[src].fetch_runs(
                                    r, morton.indices_to_runs(chunk), c
                                )
                                items = [((r, c, m), blobs.get(m)) for m in chunk]
                                topo.nodes[dst].ingest_blobs(items)
                            moved_keys += len(items)
                            moved_bytes += sum(len(b) for _, b in items if b)
            # 3. swap: the new boundaries become authoritative.  The move
            # set must stay published until every op that resolved owners
            # under the OLD router has drained — such a writer still
            # single-routes to the old owner and relies on the move entry
            # to double-write; retiring the set first would let its write
            # land on the old owner alone and be destroyed by cleanup.
            self._swap_topo(_Topology(topo.nodes, topo.router.with_partitions(target_parts)))
            swapped = True
            self._gate.synchronize()
        finally:
            # 4. retire the move set, then drain writers that may still
            # be double-writing before any key is deleted.
            self._moves = {}
            self._gate.synchronize()
            if not swapped:
                # A failed migration must not strand blobs on the
                # destinations: the old boundaries stay authoritative, and
                # anything landed on dst (copies *and* double-writes)
                # would resurrect as stale data when a later rebalance
                # re-assigns the range.  Under the old bounds dst owns
                # nothing inside a moved range and reads never routed
                # there, so wiping the range is invisible.
                self._rollback_destinations(topo, moves)
        # cleanup: every key in a moved range (including ones double-written
        # during the move) leaves the old owner's backends and cache.
        ranges_by_src: Dict[int, List[Tuple[int, int, int]]] = {}
        for r, entries in moves.items():
            for start, stop, src, _dst in entries:
                ranges_by_src.setdefault(src, []).append((r, start, stop))
        for src, ranges in ranges_by_src.items():
            node = topo.nodes[src]
            stale = [
                k
                for k in node.stored_keys()
                if any(k[0] == r and a <= k[2] < b for r, a, b in ranges)
            ]
            if stale:
                node.ingest_blobs([(k, None) for k in stale])
                if node.cache is not None:
                    node.cache.invalidate_many(stale)
        return moved_keys, moved_bytes

    @staticmethod
    def _rollback_destinations(topo: _Topology, moves: Dict[int, Tuple[Move, ...]]) -> None:
        """Best-effort: delete everything a failed migration landed on the
        destination nodes (called after the move set is retired)."""
        ranges_by_dst: Dict[int, List[Tuple[int, int, int]]] = {}
        for r, entries in moves.items():
            for start, stop, _src, dst in entries:
                ranges_by_dst.setdefault(dst, []).append((r, start, stop))
        for dst, ranges in ranges_by_dst.items():
            node = topo.nodes[dst]
            try:
                stranded = [
                    k
                    for k in node.stored_keys()
                    if any(k[0] == r and a <= k[2] < b for r, a, b in ranges)
                ]
                if stranded:
                    node.ingest_blobs([(k, None) for k in stranded])
                    if node.cache is not None:
                        node.cache.invalidate_many(stranded)
            except Exception:
                continue  # the original migration failure is re-raising

    # -- maintenance / introspection ---------------------------------------
    def migrate(self) -> int:
        """Flush every node's write path into its read path (SSD→DB)."""
        with self._gate.op():
            nodes = self._topo.nodes
            jobs = {i: nodes[i].migrate for i in range(len(nodes))}
            return sum(self._fan_out(jobs).values())

    def stored_keys(self) -> List[Key]:
        with self._gate.op():
            keys: List[Key] = []
            for node in self._topo.nodes:
                keys.extend(node.stored_keys())
            return sorted(keys)

    def storage_bytes(self) -> int:
        with self._gate.op():
            return sum(node.storage_bytes() for node in self._topo.nodes)

    def keys_per_node(self) -> List[int]:
        """Shard occupancy — the rebalancing signal.

        Counted without the flush barrier (pending write-behind writes
        are folded in from a queue snapshot): a monitoring loop polling
        occupancy must not keep draining the queues it is observing."""
        with self._gate.op():
            return self._key_counts(self._topo)

    def _key_counts(self, topo: _Topology) -> List[int]:
        nodes = topo.nodes
        jobs = {i: nodes[i].key_count for i in range(len(nodes))}
        counts = self._fan_out(jobs)
        return [counts[i] for i in range(len(nodes))]

    @property
    def read_stats(self) -> PathStats:
        """Cluster-aggregate read-path stats (per-node stats on `nodes`)."""
        return _sum_stats([n.read_stats for n in self._topo.nodes])

    @property
    def write_stats(self) -> PathStats:
        return _sum_stats([n.write_stats for n in self._topo.nodes])

    def cache_counters(self) -> Dict[str, int]:
        """Aggregate hot-cuboid cache counters across node shards."""
        total: Dict[str, int] = {}
        for node in self._topo.nodes:
            if node.cache is None:
                continue
            for k, v in node.cache.counters().items():
                total[k] = total.get(k, 0) + v
        return total

    def queue_counters(self) -> Dict[str, int]:
        """Aggregate write-behind queue counters across node shards."""
        total: Dict[str, int] = {}
        for node in self._topo.nodes:
            if node.write_behind is None:
                continue
            for k, v in node.write_behind.counters().items():
                total[k] = total.get(k, 0) + v
        return total
