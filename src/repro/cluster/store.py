"""Spatially-partitioned cluster store (paper §4.1).

A :class:`ClusterStore` owns N node shards — each a full `CuboidStore` with
its own read/write backends and `PathStats` (the paper's database node with
a disk-array read path and an SSD write path) — and routes every cuboid and
run to its owning node with a stateless :class:`Router`.  It implements the
same storage interface the cutout engine drives (`fetch_runs`,
`store_cuboids`, `read_cuboid`, ...), so `cutout()` / `write_cutout()` work
unchanged over a cluster, and batch I/O fans out across nodes in parallel
(one thread per touched node: the paper's parallel-requests doctrine C8
applied *inside* one request).
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import functools
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cuboid import DatasetSpec
from ..core.store import CuboidStore, Key, MemoryBackend, PathStats
from .cache import attach_cache, enable_write_behind
from .router import Router

NodeFactory = Callable[[int, DatasetSpec], CuboidStore]


def _default_node_factory(node: int, spec: DatasetSpec) -> CuboidStore:
    """In-memory node with a separated write path (SSD-node analogue)."""
    return CuboidStore(spec, backend=MemoryBackend(), write_path_backend=MemoryBackend())


def _sum_stats(parts: Sequence[PathStats]) -> PathStats:
    out = PathStats()
    for p in parts:
        for f in dataclasses.fields(PathStats):
            setattr(out, f.name, getattr(out, f.name) + getattr(p, f.name))
    return out


class ClusterStore:
    """N `CuboidStore` shards behind one storage interface.

    ``node_factory(i, spec)`` builds shard ``i`` — supply it to give nodes
    directory backends, distinct write paths, etc.  ``max_workers`` bounds
    per-request node parallelism (default: one worker per node; ``0``/``1``
    forces serial fan-out, useful for deterministic profiling).

    ``cache_bytes`` attaches a hot-cuboid cache to every node (the budget
    is split evenly across shards); ``write_behind`` attaches a per-node
    write-behind ingest queue (``flush()`` is the durability barrier, see
    ``repro.cluster.cache``).  Both default to the ``REPRO_CACHE_BYTES`` /
    ``REPRO_WRITE_BEHIND`` environment knobs (the CI cache matrix leg runs
    tier-1 with them set), and neither overrides a tier the node factory
    already attached.
    """

    def __init__(
        self,
        spec: DatasetSpec,
        n_nodes: int = 2,
        node_factory: Optional[NodeFactory] = None,
        max_workers: Optional[int] = None,
        cache_bytes: Optional[int] = None,
        write_behind: Optional[bool] = None,
        write_behind_items: int = 512,
    ):
        self.spec = spec
        self.router = Router(spec, n_nodes)
        factory = node_factory or _default_node_factory
        self.nodes: List[CuboidStore] = [factory(i, spec) for i in range(n_nodes)]
        if cache_bytes is None:
            cache_bytes = int(os.environ.get("REPRO_CACHE_BYTES", "0") or 0) or None
        if write_behind is None:
            write_behind = os.environ.get("REPRO_WRITE_BEHIND", "0") not in ("", "0")
        if cache_bytes:
            per_node = max(1, int(cache_bytes) // n_nodes)
            for node in self.nodes:
                if node.cache is None:
                    attach_cache(node, per_node)
        if write_behind:
            for node in self.nodes:
                if node.write_behind is None:
                    enable_write_behind(node, max_items=write_behind_items)
        workers = n_nodes if max_workers is None else max_workers
        if workers > 1:
            self._pool = cf.ThreadPoolExecutor(max_workers=workers, thread_name_prefix="ocp-node")
        else:
            self._pool = None

    # -- cluster admin -----------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def has_cache(self) -> bool:
        return any(node.cache is not None for node in self.nodes)

    def flush(self) -> int:
        """Durability barrier: drain every node's write-behind queue.

        Returns the total number of pending writes applied.  When it
        returns, everything previously written through the cluster is in
        the node backends (the contract ``POST /flush`` exposes)."""
        jobs = {i: self.nodes[i].flush for i in range(self.n_nodes)}
        return sum(self._fan_out(jobs).values())

    def close(self) -> None:
        for node in self.nodes:
            node.close()  # flushes + stops per-node write-behind flushers
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _fan_out(self, jobs: Dict[int, Callable[[], object]]) -> Dict[int, object]:
        """Run one job per touched node, in parallel when a pool exists."""
        if self._pool is None or len(jobs) <= 1:
            return {n: job() for n, job in jobs.items()}
        futures = {n: self._pool.submit(job) for n, job in jobs.items()}
        return {n: f.result() for n, f in futures.items()}

    # -- single-cuboid ops (routed) ----------------------------------------
    def read_cuboid(self, r: int, m: int, channel: int = 0) -> np.ndarray:
        return self.nodes[self.router.owner(r, m)].read_cuboid(r, m, channel)

    def write_cuboid(self, r: int, m: int, data: np.ndarray, channel: int = 0) -> None:
        self.nodes[self.router.owner(r, m)].write_cuboid(r, m, data, channel)

    def has_cuboid(self, r: int, m: int, channel: int = 0) -> bool:
        return self.nodes[self.router.owner(r, m)].has_cuboid(r, m, channel)

    # -- batch ops (routed + parallel) -------------------------------------
    def read_run(self, r: int, start: int, stop: int, channel: int = 0) -> List[np.ndarray]:
        """Run read in curve order, split at partition boundaries."""
        out: List[np.ndarray] = []
        for node, a, b in self.router.split_run(r, start, stop):
            out.extend(self.nodes[node].read_run(r, a, b, channel))
        return out

    def fetch_runs(
        self,
        r: int,
        runs: Sequence[Tuple[int, int]],
        channel: int = 0,
    ) -> Dict[int, Optional[bytes]]:
        """Batch blob fetch: split runs by owner, fetch nodes in parallel."""
        by_node = self.router.split_runs(r, list(runs))
        jobs = {
            node: functools.partial(self.nodes[node].fetch_runs, r, node_runs, channel)
            for node, node_runs in by_node.items()
        }
        merged: Dict[int, Optional[bytes]] = {}
        for part in self._fan_out(jobs).values():
            merged.update(part)
        return merged

    def fetch_blocks(
        self,
        r: int,
        runs: Sequence[Tuple[int, int]],
        channel: int = 0,
    ) -> Dict[int, Optional[np.ndarray]]:
        """Decoded-cuboid batch fetch (cache fast path), fanned out per node."""
        by_node = self.router.split_runs(r, list(runs))
        jobs = {
            node: functools.partial(self.nodes[node].fetch_blocks, r, node_runs, channel)
            for node, node_runs in by_node.items()
        }
        merged: Dict[int, Optional[np.ndarray]] = {}
        for part in self._fan_out(jobs).values():
            merged.update(part)
        return merged

    def store_cuboids(self, r: int, blocks: Dict[int, np.ndarray], channel: int = 0) -> None:
        """Batch write: group blocks by owner, write nodes in parallel."""
        by_node: Dict[int, Dict[int, np.ndarray]] = {}
        for m, data in blocks.items():
            by_node.setdefault(self.router.owner(r, m), {})[m] = data
        jobs = {
            node: functools.partial(self.nodes[node].store_cuboids, r, node_blocks, channel)
            for node, node_blocks in by_node.items()
        }
        self._fan_out(jobs)

    # -- maintenance / introspection ---------------------------------------
    def migrate(self) -> int:
        """Flush every node's write path into its read path (SSD→DB)."""
        jobs = {i: self.nodes[i].migrate for i in range(self.n_nodes)}
        return sum(self._fan_out(jobs).values())

    def stored_keys(self) -> List[Key]:
        keys: List[Key] = []
        for node in self.nodes:
            keys.extend(node.stored_keys())
        return sorted(keys)

    def storage_bytes(self) -> int:
        return sum(node.storage_bytes() for node in self.nodes)

    def keys_per_node(self) -> List[int]:
        """Shard occupancy (the rebalancing signal for later PRs)."""
        return [len(node.stored_keys()) for node in self.nodes]

    @property
    def read_stats(self) -> PathStats:
        """Cluster-aggregate read-path stats (per-node stats on `nodes`)."""
        return _sum_stats([n.read_stats for n in self.nodes])

    @property
    def write_stats(self) -> PathStats:
        return _sum_stats([n.write_stats for n in self.nodes])

    def cache_counters(self) -> Dict[str, int]:
        """Aggregate hot-cuboid cache counters across node shards."""
        total: Dict[str, int] = {}
        for node in self.nodes:
            if node.cache is None:
                continue
            for k, v in node.cache.counters().items():
                total[k] = total.get(k, 0) + v
        return total

    def queue_counters(self) -> Dict[str, int]:
        """Aggregate write-behind queue counters across node shards."""
        total: Dict[str, int] = {}
        for node in self.nodes:
            if node.write_behind is None:
                continue
            for k, v in node.write_behind.counters().items():
                total[k] = total.get(k, 0) + v
        return total
