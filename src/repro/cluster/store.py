"""Spatially-partitioned *elastic* cluster store (paper §4.1 + §6).

A :class:`ClusterStore` owns N node shards — each a full `CuboidStore` with
its own read/write backends and `PathStats` (the paper's database node with
a disk-array read path and an SSD write path) — and routes every cuboid and
run to its owning node with a stateless :class:`Router`.  It implements the
same storage interface the cutout engine drives (`fetch_runs`,
`store_cuboids`, `read_cuboid`, ...), so `cutout()` / `write_cutout()` work
unchanged over a cluster, and batch I/O fans out across nodes in parallel
(one thread per touched node: the paper's parallel-requests doctrine C8
applied *inside* one request).

Replication (paper §4.2 "no single point of failure" applied to the data
tier): ``replication=N`` keeps every curve segment on a *replica set* — a
successor ring of N nodes starting at the segment's partition owner.
Writes fan out to every member (each through its own write-behind queue),
reads go to the least-loaded member (the per-node ``PathStats.inflight``
gauge is the load signal), and removing a live member promotes the
surviving replicas with zero data loss because every key already lives on
all of them.

Elasticity (paper §6 "dynamically redistribute data"): the cluster is not
pinned to its initial shard count.  ``rebalance(target=...)`` re-cuts the
per-resolution curve partitions by occupancy and migrates the keys whose
*replica set* changes *live* — concurrent reads and writes stay
bit-identical throughout.  ``add_node()`` / ``remove_node()`` grow and
shrink the node set through the same protocol.  The migration protocol,
per moved curve range:

1. **register** — the move set (ranges whose membership changes) is
   published and a grace period waits for in-flight ops, so every
   subsequent write to a moving key *double-writes* to the old members
   and every member being added (through each node's write-behind queue
   when attached — the queue is the natural double-write buffer).
2. **copy** — existing keys in the moving range are streamed from a
   surviving old member to each added member as compressed blobs, in
   small batches under the move lock, so a racing double-write can never
   be clobbered by a stale copy.  Reads keep routing to the old members,
   which stay complete.
3. **swap** — the *final* topology (node tuple + Router) is published in
   one atomic swap, so replica sets are never evaluated against a
   half-migrated intermediate; a grace period drains readers still on the
   old boundaries.
4. **cleanup** — after a final writer grace period, keys leave the
   members dropped from each range's set (backends and `CuboidCache`;
   the added members' caches absorbed them during the copy).

Topology (the node tuple + the Router) is an immutable snapshot swapped
atomically, so every op sees one consistent (nodes, boundaries) pair even
while a rebalance is in flight; `GET /topology` exposes it.  During a
grow, freshly appended shards ride in the node tuple *without* entering
the Router until the final swap — they own nothing and serve nothing
while the copy phase fills them.
"""

from __future__ import annotations

import concurrent.futures as cf
import contextlib
import dataclasses
import functools
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..analysis import knobs
from ..analysis.witness import before_submit, ordered_lock, ordered_rlock
from ..core import morton
from ..core.cuboid import DatasetSpec
from ..core.store import BlockSink, CuboidStore, DecodePolicy, Key, MemoryBackend, PathStats
from ..obs import trace
from ..obs.registry import REGISTRY
from . import deadline
from .cache import attach_cache, enable_write_behind
from .router import Partition, Router

NodeFactory = Callable[[int, DatasetSpec], CuboidStore]

# (start, stop, old_members, new_members) — one curve range whose replica
# set changes.  Node indices are *pre-migration* (physical) positions in
# the topology the move set was computed against.
Move = Tuple[int, int, Tuple[int, ...], Tuple[int, ...]]


def _heat_bits() -> int:
    """Granularity of the per-segment access-heat map: morton indices are
    bucketed by ``m >> REPRO_HEAT_BITS`` (default 6 → 64-cuboid buckets),
    keeping the map small on petascale curves while still localizing hot
    regions to a partition-sized neighborhood."""
    return knobs.get_int("REPRO_HEAT_BITS", 6)


class RebalanceInFlight(RuntimeError):
    """A topology change (rebalance / add_node / remove_node) is already
    in progress.  Raised by ``rebalance(wait=False)`` and friends instead
    of queueing behind the admin lock; the HTTP layer maps it to 409."""


class NoLiveReplica(RuntimeError):
    """Every member of a replica set is excluded (failed this request or
    declared dead) — the read cannot be served from any surviving copy."""


class WriteQuorumError(RuntimeError):
    """A replicated write reached fewer live members than its quorum.

    The write is NOT acknowledged: retry it.  Any copies that did land
    are queued for anti-entropy repair on the members that missed them,
    so reads keep routing to members holding the freshest value."""


# Health states a node moves through (consecutive data-path errors and the
# probe tick drive the transitions; see `ClusterStore._record_error`):
#
#     alive --errors--> suspect --more errors--> dead --probe ok-->
#     recovering --resync_node()--> alive   (suspect heals straight back
#     to alive on any success)
#
# dead/recovering members serve no reads; suspect members are deprioritized
# in the least-loaded choice but still serve.
HEALTH_STATES = ("alive", "suspect", "dead", "recovering")
_NOT_SERVING = ("dead", "recovering")
_HEALTH_RANK = {"alive": 0, "suspect": 1, "recovering": 2, "dead": 3}


class _NodeHealth:
    """Mutable per-node health record (guarded by the cluster.health lock;
    `state` is additionally read unlocked as a monotonic-enough snapshot
    on the hot read path)."""

    __slots__ = ("state", "errors", "last_error", "since", "transitions")

    def __init__(self):
        self.state = "alive"
        self.errors = 0
        self.last_error: Optional[str] = None
        self.since = time.monotonic()
        self.transitions = 0

    def set(self, state: str) -> None:
        if state != self.state:
            self.state = state
            self.since = time.monotonic()
            self.transitions += 1


def _default_node_factory(node: int, spec: DatasetSpec) -> CuboidStore:
    """In-memory node with a separated write path (SSD-node analogue).

    Under ``REPRO_WRITE_TIER=log|dir`` the node gets the on-disk
    `TierPolicy` pair instead (append-log or directory write tier over a
    compacted read tier, in a scratch root the store owns) — the CI
    tier-matrix leg runs the whole suite through the log tier this way.
    """
    if knobs.get_raw("REPRO_WRITE_TIER") in ("log", "dir"):
        from ..core.wal import tiered_store

        return tiered_store(spec)
    return CuboidStore(spec, backend=MemoryBackend(), write_path_backend=MemoryBackend())


# Gauges describe *current* per-node occupancy, not accumulated work:
# summing them over-reports (a 2-node cluster would claim twice the real
# queue peak), so the cluster aggregate takes the max instead.
_GAUGE_FIELDS = frozenset({"queue_depth", "queue_peak", "inflight"})


def _sum_stats(parts: Sequence[PathStats]) -> PathStats:
    out = PathStats()
    for p in parts:
        for f in dataclasses.fields(PathStats):
            if f.name in _GAUGE_FIELDS:
                setattr(out, f.name, max(getattr(out, f.name), getattr(p, f.name)))
            else:
                setattr(out, f.name, getattr(out, f.name) + getattr(p, f.name))
    return out


@dataclasses.dataclass(frozen=True)
class _Topology:
    """One atomic (nodes, router) snapshot — ops resolve both together."""

    nodes: Tuple[CuboidStore, ...]
    router: Router


class _OpGate:
    """RCU-style grace periods for topology changes.

    Data ops enter/exit; `synchronize()` opens a new epoch and blocks until
    every op that started under an older epoch has drained.  Rebalance uses
    it so a published move set / router swap is *seen* by all traffic
    before the next phase relies on it.  Ops never block each other.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._epoch = 0
        self._active: Dict[int, int] = {}

    @contextlib.contextmanager
    def op(self):
        with self._cond:
            epoch = self._epoch
            self._active[epoch] = self._active.get(epoch, 0) + 1
        try:
            yield
        finally:
            with self._cond:
                self._active[epoch] -= 1
                if self._active[epoch] == 0:
                    del self._active[epoch]
                    self._cond.notify_all()

    def synchronize(self, timeout: float = 60.0) -> None:
        with self._cond:
            self._epoch += 1
            fence = self._epoch
            deadline = time.monotonic() + timeout
            while any(e < fence and n > 0 for e, n in self._active.items()):
                self._cond.wait(0.05)
                # measured against the clock, not wakeup counts: notify_all
                # fires on every op completion and would exhaust a counter
                # in seconds under sustained traffic
                if time.monotonic() >= deadline:
                    raise TimeoutError("op gate synchronize timed out")


def _move_extras(entries: Tuple[Move, ...], m: int, members: Tuple[int, ...]) -> Tuple[int, ...]:
    """Extra write targets while ``m``'s range is migrating: the members
    being *added* that the writer's (pre-swap) replica set doesn't list.
    Empty once the final topology is in the writer's snapshot — its
    replica set already names every authoritative member."""
    for start, stop, _old, new in entries:
        if start <= m < stop:
            return tuple(d for d in new if d not in members)
    return ()


class ClusterStore:
    """N `CuboidStore` shards behind one storage interface.

    ``node_factory(i, spec)`` builds shard ``i`` — supply it to give nodes
    directory backends, distinct write paths, etc.  ``max_workers`` bounds
    per-request node parallelism (default: one worker per node; ``0``/``1``
    forces serial fan-out, useful for deterministic profiling).

    ``cache_bytes`` attaches a hot-cuboid cache to every node (the budget
    is split evenly across the initial shards; nodes added later get the
    same per-node budget); ``write_behind`` attaches a per-node
    write-behind ingest queue (``flush()`` is the durability barrier, see
    ``repro.cluster.cache``).  Both default to the ``REPRO_CACHE_BYTES`` /
    ``REPRO_WRITE_BEHIND`` environment knobs (the CI cache matrix leg runs
    tier-1 with them set), and neither overrides a tier the node factory
    already attached.

    ``replication`` keeps every curve segment on that many nodes (a
    successor ring from the segment's owner, capped at the node count;
    default from the ``REPRO_REPLICATION`` env knob, else 1).  Writes fan
    out to every member; reads pick the member with the fewest in-flight
    jobs; losing any single member loses no data while ``replication >=
    2``.

    Elasticity: ``rebalance(target=n)`` / ``add_node()`` /
    ``remove_node()`` re-partition by occupancy (``keys_per_node()`` is
    the signal) and migrate keys live; see the module docstring for the
    coherence protocol.  ``topology()`` is the introspection snapshot the
    ``GET /topology`` verb serves.  Pass ``wait=False`` to fail fast with
    :class:`RebalanceInFlight` instead of queueing behind a concurrent
    topology change.
    """

    def __init__(
        self,
        spec: DatasetSpec,
        n_nodes: int = 2,
        node_factory: Optional[NodeFactory] = None,
        max_workers: Optional[int] = None,
        cache_bytes: Optional[int] = None,
        write_behind: Optional[bool] = None,
        write_behind_items: int = 512,
        decode_policy: Optional[DecodePolicy] = None,
        replication: Optional[int] = None,
    ):
        self.spec = spec
        self._node_factory = node_factory or _default_node_factory
        if cache_bytes is None:
            cache_bytes = knobs.get_int("REPRO_CACHE_BYTES", 0) or None
        if write_behind is None:
            write_behind = knobs.get_flag("REPRO_WRITE_BEHIND", False)
        if replication is None:
            replication = knobs.get_int("REPRO_REPLICATION", 1)
        self.replication = max(1, int(replication))
        self._node_cache_bytes = max(1, int(cache_bytes) // n_nodes) if cache_bytes else 0
        self._write_behind = bool(write_behind)
        self._write_behind_items = write_behind_items
        # One DecodePolicy for every shard: the per-node fan-out workers
        # decode into a pool shared across the whole process, so the
        # cluster's cold-read parallelism is nodes x decode chunks without
        # per-node thread oversubscription.  None leaves factory-built
        # nodes on their own (env-derived) policy.
        self.decode_policy = decode_policy
        nodes = tuple(self._build_node(i) for i in range(n_nodes))
        self._topo = _Topology(nodes, Router(spec, n_nodes, replication=self.replication))
        self._gate = _OpGate()
        # Serializes whole rebalances; RLock so add/remove can nest into
        # rebalance().
        self._admin_lock = ordered_rlock("cluster.admin", 10)
        # Serializes the copy phase with double-writes to *moving* keys so
        # a stale copy can never clobber a fresher concurrent write.
        self._move_lock = ordered_lock("cluster.move", 20)
        # {resolution: ((start, stop, old_members, new_members), ...)} —
        # published atomically; empty outside an active migration.  Member
        # indices are positions in `_moves_topo` (the pre-migration
        # snapshot): a writer consults the move set only while its own
        # topology snapshot IS that one, so the indices always line up.
        self._moves: Dict[int, Tuple[Move, ...]] = {}
        self._moves_topo: Optional[_Topology] = None
        self._cfg_max_workers = max_workers
        self._retired_pools: List[cf.ThreadPoolExecutor] = []
        workers = n_nodes if max_workers is None else max_workers
        if workers > 1:
            self._pool = cf.ThreadPoolExecutor(max_workers=workers, thread_name_prefix="ocp-node")
        else:
            self._pool = None
        # Per-segment access heat (ROADMAP item 5's signal): morton buckets
        # (m >> heat_bits) → touch counts, split by direction.  Updated
        # with one dict bump per routed run piece / written block — cheap
        # enough to stay always-on — and read by `access_heat()` (the
        # /metrics top-N exposition and the supervisor's ClusterWatch).
        self.heat_bits = _heat_bits()
        self._heat_lock = ordered_lock("cluster.heat", 75)
        self._read_heat: Dict[Tuple[int, int], int] = {}
        self._write_heat: Dict[Tuple[int, int], int] = {}
        # Request-level pool for batch_cutout's multi-box overlap — lazily
        # created, and deliberately DISTINCT from the node fan-out pool: a
        # batch job itself fans out to nodes and blocks on their futures,
        # and nesting both levels in one bounded pool deadlocks the moment
        # every worker holds a waiting outer job.
        self._batch_pool: Optional[cf.ThreadPoolExecutor] = None
        self._batch_lock = ordered_lock("cluster.batch", 76)
        # repr of the newest secondary error swallowed while rolling back a
        # failed grow (`_unwiden`); the primary error re-raises past it.
        self.last_unwiden_error: Optional[str] = None
        # -- fault tolerance: health machine + anti-entropy repair queue --
        # Health records are keyed by node identity (id(node)) so they
        # survive index shifts across topology swaps; `_swap_topo` prunes
        # entries whose node left the cluster.  Rank 22 sits between the
        # move lock (20) and the repair lock (24): write paths record
        # health while holding the move lock, and repair bookkeeping may
        # follow a health check — never the other way around.
        self._suspect_after = max(1, knobs.get_int("REPRO_SUSPECT_AFTER", 3))
        self._dead_after = max(self._suspect_after, knobs.get_int("REPRO_DEAD_AFTER", 6))
        self._health_lock = ordered_lock("cluster.health", 22)
        self._health: Dict[int, _NodeHealth] = {}
        # {id(node): {(r, channel, m), ...}} — keys a node missed (write
        # failures, writes skipped while it was dead).  Reads route around
        # a member that is dirty for the requested span; `resync_node`
        # replays the set from replica peers under the move lock.
        self._repair_lock = ordered_lock("cluster.repair", 24)
        self._dirty: Dict[int, set] = {}
        self.repair_enqueued = 0
        self.last_probe_error: Optional[str] = None
        self._prober: Optional[threading.Thread] = None
        self._prober_stop = threading.Event()

    def _build_node(self, i: int, factory: Optional[NodeFactory] = None) -> CuboidStore:
        node = (factory or self._node_factory)(i, self.spec)
        if self._node_cache_bytes and node.cache is None:
            attach_cache(node, self._node_cache_bytes)
        if self._write_behind and node.write_behind is None:
            enable_write_behind(node, max_items=self._write_behind_items)
        if self.decode_policy is not None:
            node.decode_policy = self.decode_policy
        return node

    # -- cluster admin -----------------------------------------------------
    @property
    def nodes(self) -> List[CuboidStore]:
        """The current node shards (a snapshot copy — topology may move)."""
        return list(self._topo.nodes)

    @property
    def router(self) -> Router:
        return self._topo.router

    @property
    def n_nodes(self) -> int:
        return len(self._topo.nodes)

    @property
    def has_cache(self) -> bool:
        return any(node.cache is not None for node in self._topo.nodes)

    def flush(self) -> int:
        """Durability barrier: drain every node's write-behind queue.

        Returns the total number of pending writes applied.  When it
        returns, everything previously written through the cluster is in
        the node backends (the contract ``POST /flush`` exposes)."""
        with self._gate.op():
            nodes = self._topo.nodes
            jobs = {i: nodes[i].flush for i in range(len(nodes))}
            return sum(self._fan_out(jobs).values())

    def compact(self, max_segments: Optional[int] = None) -> Dict[str, object]:
        """Fan ``CuboidStore.compact()`` out to every node: merge each
        shard's flushed log segments into its read tier (no-op per node
        without a log write tier).  The aggregate is what
        ``POST /compact`` returns."""
        with self._gate.op():
            nodes = self._topo.nodes
            jobs = {
                i: functools.partial(nodes[i].compact, max_segments)
                for i in range(len(nodes))
            }
            results = self._fan_out(jobs)
        agg = {"segments": 0, "keys": 0, "tombstones": 0, "bytes": 0, "seconds": 0.0}
        for stats in results.values():
            d = stats.asdict()
            for k in agg:
                agg[k] += d[k]
        agg["nodes"] = len(results)
        return agg

    def tier_counters(self) -> Dict[str, object]:
        """Cluster-wide tier gauges: per-node ``tier_stats`` summed (the
        ``tiers`` section of ``GET /stats`` and the supervisor's
        log-pressure signal)."""
        with self._gate.op():
            nodes = self._topo.nodes
        agg: Dict[str, object] = {
            "nodes": len(nodes),
            "log_nodes": 0,
            "sealed": 0,
            "log_bytes": 0,
            "live_keys": 0,
            "tombstones": 0,
            "torn_truncated": 0,
            "compactions": {
                "runs": 0,
                "segments": 0,
                "keys": 0,
                "tombstones": 0,
                "bytes": 0,
                "seconds": 0.0,
            },
        }
        for node in nodes:
            ts = node.tier_stats()
            for k, v in ts["compactions"].items():
                agg["compactions"][k] += v
            log = ts.get("log")
            if log:
                agg["log_nodes"] += 1
                for k in ("sealed", "log_bytes", "live_keys", "tombstones", "torn_truncated"):
                    agg[k] += log[k]
        return agg

    def synchronize(self, timeout: float = 60.0) -> None:
        """Grace-period barrier: block until every data op that was in
        flight when this was called has drained (new ops are unaffected).
        Raises ``TimeoutError`` when an op outlives ``timeout`` seconds —
        the signal a hung node is wedging topology changes."""
        self._gate.synchronize(timeout)

    def close(self) -> None:
        self.stop_prober()
        for node in self._topo.nodes:
            node.close()  # flushes + stops per-node write-behind flushers
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        with self._batch_lock:  # serialize with run_batch's lazy creation
            batch_pool, self._batch_pool = self._batch_pool, None
        if batch_pool is not None:
            batch_pool.shutdown(wait=True)
        for pool in self._retired_pools:
            pool.shutdown(wait=True)
        self._retired_pools = []

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _fan_out(self, jobs: Dict[int, Callable[[], object]]) -> Dict[int, object]:
        """Run one job per touched node, in parallel when a pool exists.

        Jobs cross the pool boundary through ``trace.bind`` so a sampled
        request's per-node spans nest under the stage that fanned out
        (bind is the identity function when nothing is traced)."""
        pool = self._pool
        if pool is None or len(jobs) <= 1:
            return {n: job() for n, job in jobs.items()}
        # The copy phase fans out *while holding* the move lock by design
        # (a stale copy must never clobber a racing double-write); node
        # jobs never take the move lock, so tell the witness it is safe.
        before_submit(allow=(self._move_lock,))
        futures = {n: pool.submit(trace.bind(job)) for n, job in jobs.items()}
        return {n: f.result() for n, f in futures.items()}

    def _fan_out_checked(
        self, jobs: Dict[int, Callable[[], object]], budget: Optional[float] = None
    ) -> Dict[int, Tuple[bool, object]]:
        """Failure-isolating fan-out: ``{node: (ok, value_or_error)}``.

        Unlike `_fan_out`, one node's exception never aborts the batch —
        the degraded paths need to know exactly which members failed so
        their pieces can be re-routed.  With a ``budget`` (seconds), each
        future is waited at most the budget remaining when its turn
        comes; an expired node reports a ``TimeoutError`` and its job is
        abandoned to finish in the background — a hung node is never
        waited on past the caller's deadline."""
        pool = self._pool
        out: Dict[int, Tuple[bool, object]] = {}
        if pool is None:
            for n, job in jobs.items():
                try:
                    out[n] = (True, job())
                except Exception as e:
                    out[n] = (False, e)
            return out
        before_submit(allow=(self._move_lock,))
        futures = {n: pool.submit(trace.bind(job)) for n, job in jobs.items()}
        t_end = None if budget is None else time.monotonic() + budget
        for n, f in futures.items():
            try:
                t = None if t_end is None else max(0.001, t_end - time.monotonic())
                out[n] = (True, f.result(timeout=t))
            except cf.TimeoutError:
                f.cancel()
                out[n] = (False, TimeoutError(
                    f"node {n} op exceeded the deadline budget"))
            except Exception as e:
                out[n] = (False, e)
        return out

    # -- node health (alive / suspect / dead / recovering) -------------------
    def _health_state(self, node: CuboidStore) -> str:
        # unlocked dict read: a benign snapshot — health transitions are
        # inherently racy against in-flight ops, and the paths consulting
        # this tolerate either side of the transition
        h = self._health.get(id(node))
        return h.state if h is not None else "alive"

    def _record_error(self, node: CuboidStore, exc: BaseException) -> None:
        """Data-path failure on a node: count it, degrade health on the
        consecutive-error thresholds (alive→suspect→dead)."""
        with self._health_lock:
            h = self._health.get(id(node))
            if h is None:
                h = self._health[id(node)] = _NodeHealth()
            h.errors += 1
            h.last_error = repr(exc)
            if h.state in ("alive", "recovering") and h.errors >= self._suspect_after:
                h.set("suspect")
            if h.state == "suspect" and h.errors >= self._dead_after:
                h.set("dead")

    def _record_ok(self, node: CuboidStore) -> None:
        """Data-path success: clear the consecutive-error count; a suspect
        member heals straight back to alive.  Dead/recovering members do
        NOT resurrect here — one lucky success must not short-circuit the
        probe + anti-entropy resync re-admission path."""
        h = self._health.get(id(node))  # unlocked fast path: nothing to clear
        if h is None or (h.errors == 0 and h.state != "suspect"):
            return
        with self._health_lock:
            h = self._health.get(id(node))
            if h is None:
                return
            h.errors = 0
            if h.state == "suspect":
                h.set("alive")

    def _probe_ok(self, node: CuboidStore) -> None:
        with self._health_lock:
            h = self._health.get(id(node))
            if h is None:
                return
            h.errors = 0
            if h.state == "suspect":
                h.set("alive")
            elif h.state == "dead":
                # back from the dead: it must resync (anti-entropy) before
                # serving reads again — `resync_node` flips it to alive
                h.set("recovering")

    def probe_health(self) -> Dict[str, object]:
        """One cheap health-probe tick over every node (a single-key
        existence check per node — no data transfer).

        Failed probes count toward the consecutive-error thresholds, so a
        dead node is detected even on an idle cluster; a successful probe
        heals suspect→alive and advances dead→recovering.  Runs inside
        the op gate so topology changes drain it like any data op.
        ``ClusterWatch.sample()`` calls this every supervisor tick;
        `start_prober` runs it from a dedicated thread instead."""
        summary: Dict[str, object] = {"probed": 0, "ok": 0, "failed": 0}
        with self._gate.op():
            topo = self._topo
            for node in topo.nodes:
                summary["probed"] += 1
                try:
                    node.has_cuboid(0, 0, 0)
                except Exception as e:
                    summary["failed"] += 1
                    self._record_error(node, e)
                else:
                    summary["ok"] += 1
                    self._probe_ok(node)
            summary["health"] = [self._health_state(n) for n in topo.nodes]
        return summary

    def start_prober(self, interval: float = 0.25) -> None:
        """Run `probe_health` on a background tick (idempotent).  Only
        needed when no `StorageSupervisor` is watching the cluster — its
        sample() already ticks the probe."""
        if self._prober is not None and self._prober.is_alive():
            return
        self._prober_stop.clear()

        def loop():
            while not self._prober_stop.wait(interval):
                try:
                    self.probe_health()
                except Exception as e:
                    # mid-close or mid-swap; record it and keep ticking
                    self.last_probe_error = repr(e)

        self._prober = threading.Thread(
            target=loop, name="ocp-health-prober", daemon=True)
        self._prober.start()

    def stop_prober(self) -> None:
        self._prober_stop.set()
        prober, self._prober = self._prober, None
        if prober is not None:
            prober.join(timeout=10.0)

    def mark_dead(self, node: int) -> None:
        """Operator override: declare a node dead right now (reads stop
        routing to it; writes skip it and queue repairs)."""
        topo = self._topo
        with self._health_lock:
            key = id(topo.nodes[node])
            h = self._health.get(key)
            if h is None:
                h = self._health[key] = _NodeHealth()
            h.set("dead")

    def node_health(self) -> List[Dict[str, object]]:
        """Per-node health snapshot — the ``/stats`` section and the
        ``repro_node_health`` metric family."""
        with self._gate.op():
            topo = self._topo
        repair = self._repair_counts(topo)
        out: List[Dict[str, object]] = []
        with self._health_lock:
            for i, node in enumerate(topo.nodes):
                h = self._health.get(id(node))
                out.append({
                    "node": i,
                    "state": h.state if h else "alive",
                    "consecutive_errors": h.errors if h else 0,
                    "transitions": h.transitions if h else 0,
                    "last_error": h.last_error if h else None,
                    "repair_pending": repair[i],
                })
        return out

    # -- anti-entropy repair queue -------------------------------------------
    def _mark_dirty(self, node: CuboidStore, key: Key) -> None:
        with self._repair_lock:
            self._dirty.setdefault(id(node), set()).add(key)
            self.repair_enqueued += 1

    def _clear_dirty(self, node: CuboidStore, keys: Iterable[Key]) -> None:
        """A successful write to ``node`` settles its pending repairs for
        those keys: the node now holds the freshest value, and replaying
        an older mark from a peer could roll an acked write back."""
        with self._repair_lock:
            dirty = self._dirty.get(id(node))
            if not dirty:
                return
            dirty.difference_update(keys)
            if not dirty:
                del self._dirty[id(node)]

    def _repair_counts(self, topo: _Topology) -> List[int]:
        with self._repair_lock:
            return [len(self._dirty.get(id(n), ())) for n in topo.nodes]

    def _dirty_overlap(self, node: CuboidStore, r: int, channel: int,
                       a: int, b: int) -> bool:
        """Does ``node`` hold a pending repair inside [a, b) at (r,
        channel)?  Such a member missed a write there — reads must prefer
        a member holding the freshest value."""
        with self._repair_lock:
            dirty = self._dirty.get(id(node))
            if not dirty:
                return False
            if b - a == 1:
                return (r, channel, a) in dirty
            return any(k[0] == r and k[1] == channel and a <= k[2] < b
                       for k in dirty)

    def _degraded_cluster(self, topo: _Topology) -> bool:
        """True when any current node is not alive or repairs are queued —
        the signal that flips writes onto the quorum slow path (under the
        move lock, serialized with the repair/migration copiers).
        Unlocked reads: a transition mid-write at worst sends one write
        down the fast path, which then fails exactly as it would have
        before health tracking existed."""
        if self._dirty:
            return True
        if self._health:
            for node in topo.nodes:
                h = self._health.get(id(node))
                if h is not None and h.state != "alive":
                    return True
        return False

    # -- access heat ---------------------------------------------------------
    def _touch_heat(self, heat: Dict[Tuple[int, int], int], r: int, m: int, n: int = 1) -> None:
        key = (r, m >> self.heat_bits)
        with self._heat_lock:
            heat[key] = heat.get(key, 0) + n

    def access_heat(self, top: Optional[int] = None) -> Dict[str, object]:
        """Per-segment access-heat counters: morton-bucket touch counts by
        direction, hottest first.  ``top`` truncates each direction to its
        N hottest buckets (the ``/metrics`` exposition asks for a top-N;
        the supervisor's ClusterWatch reads the full map)."""
        with self._heat_lock:
            read = dict(self._read_heat)
            write = dict(self._write_heat)

        def rank(heat: Dict[Tuple[int, int], int]) -> List[Tuple[int, int, int]]:
            rows = sorted(
                ((r, b, n) for (r, b), n in heat.items()), key=lambda t: (-t[2], t[0], t[1])
            )
            return rows[:top] if top is not None else rows

        return {"bits": self.heat_bits, "read": rank(read), "write": rank(write)}

    # -- replica selection --------------------------------------------------
    def _pick_replica(
        self,
        topo: _Topology,
        members: Tuple[int, ...],
        assigned: Optional[Dict[int, int]] = None,
    ) -> int:
        """Least-loaded member of a replica set (reads balance here).

        Load is the node's health rank (suspect members are deprioritized
        — they serve only when every alive member is busier), then the
        ``PathStats.inflight`` gauge (cluster read jobs it is serving
        *right now*) plus any pieces this caller already assigned it,
        tie-broken by lifetime reads so an idle cluster still round-robins
        instead of pinning the primary."""
        if len(members) == 1:
            return members[0]
        best = members[0]
        best_load = None
        for i in members:
            stats = topo.nodes[i].read_stats
            load = (
                _HEALTH_RANK.get(self._health_state(topo.nodes[i]), 0),
                stats.inflight + (assigned.get(i, 0) if assigned else 0),
                stats.reads,
                i,
            )
            if best_load is None or load < best_load:
                best, best_load = i, load
        return best

    def _filter_members(
        self,
        topo: _Topology,
        members: Tuple[int, ...],
        exclude,
        r: int,
        channel: int,
        a: int,
        b: int,
    ) -> Optional[Tuple[int, ...]]:
        """Members eligible to serve reads of [a, b) at (r, channel).

        Prefers members that are serving (not dead/recovering) and hold
        no pending repair inside the span, falling back one tier at a
        time so a fully degraded set still yields *something* to try
        rather than failing outright.  Returns ``()`` when every member
        is excluded (all failed this request), and ``None`` when only
        per-key routing can find clean members (a multi-key span with
        repairs scattered across every serving member)."""
        cands = [i for i in members if i not in exclude]
        if not cands:
            return ()
        serving = [i for i in cands
                   if self._health_state(topo.nodes[i]) not in _NOT_SERVING]
        pool = serving or cands
        if self._dirty:
            clean = [i for i in pool
                     if not self._dirty_overlap(topo.nodes[i], r, channel, a, b)]
            if clean:
                return tuple(clean)
            if b - a > 1:
                return None
        return tuple(pool)

    def _read_split(
        self,
        topo: _Topology,
        r: int,
        runs,
        channel: int = 0,
        exclude=frozenset(),
    ) -> Dict[int, List[Tuple[int, int]]]:
        """Split runs at partition boundaries and route each piece to the
        least-loaded *eligible* member of its replica set (dead and
        repair-pending members routed around; see `_filter_members`).
        Every routed piece bumps the read-heat bucket of its start index
        (piece-granular, not per-cuboid — heat is a ranking signal, not
        an exact count).  Raises :class:`NoLiveReplica` when a piece has
        no member left to try."""
        router = topo.router
        if router.n_replicas == 1 and not exclude:
            # sole-owner routing: health filtering has no alternative to
            # offer, so the fast path stands
            by_node = router.split_runs(r, runs)
            for pieces in by_node.values():
                for a, b in pieces:
                    self._touch_heat(self._read_heat, r, a, b - a)
            return by_node
        assigned: Dict[int, int] = {}
        by_node: Dict[int, List[Tuple[int, int]]] = {}
        for start, stop in runs:
            for members, a, b in router.split_run_replicas(r, start, stop):
                self._route_piece(topo, r, channel, members, a, b,
                                  exclude, assigned, by_node)
        return by_node

    def _route_piece(self, topo, r, channel, members, a, b, exclude,
                     assigned, by_node) -> None:
        cands = self._filter_members(topo, members, exclude, r, channel, a, b)
        if cands is None:
            # repairs scattered across every serving member: route per key
            # so each lands on a member holding its freshest value
            for m in range(a, b):
                self._route_piece(topo, r, channel, members, m, m + 1,
                                  exclude, assigned, by_node)
            return
        if not cands:
            raise NoLiveReplica(
                f"no serving replica for r={r} range [{a},{b}) "
                f"(members {members}, excluded {sorted(exclude)})")
        node = self._pick_replica(topo, cands, assigned)
        assigned[node] = assigned.get(node, 0) + 1
        by_node.setdefault(node, []).append((a, b))
        self._touch_heat(self._read_heat, r, a, b - a)

    @staticmethod
    def _serving_job(node: CuboidStore, fn: Callable[[], object], idx: int) -> Callable[[], object]:
        """Wrap a per-node read job so the node's inflight gauge tracks it
        (the signal `_pick_replica` balances on) and a sampled request
        gets one ``node.fetch`` span per fanned-out node."""

        def run():
            with trace.span("node.fetch", node=idx):
                with node.serving():
                    return fn()

        return run

    def _write_targets(self, topo: _Topology, r: int, m: int) -> Tuple[int, ...]:
        """Every node a write to (r, m) must reach: the snapshot router's
        replica set, plus members being added by an in-flight migration
        (only meaningful against the pre-migration snapshot)."""
        members = topo.router.replica_set(r, m)
        if self._moves and topo is self._moves_topo:
            extras = _move_extras(self._moves.get(r, ()), m, members)
            if extras:
                return members + extras
        return members

    def _call_node(self, node: CuboidStore, idx: int,
                   fn: Callable[[], object], budget: Optional[float]) -> object:
        """Run one node op, bounded by the caller's remaining deadline
        budget.  Without a budget (or without a pool) the call runs
        inline; with one it runs on the fan-out pool and is abandoned on
        expiry — the worker finishes (or keeps hanging) in the background
        while the caller fails over to the next replica."""
        job = self._serving_job(node, fn, idx)
        pool = self._pool
        if budget is None or pool is None:
            return job()
        before_submit(allow=(self._move_lock,))
        fut = pool.submit(trace.bind(job))
        try:
            return fut.result(timeout=max(0.001, budget))
        except cf.TimeoutError:
            fut.cancel()
            raise TimeoutError(
                f"node {idx} op exceeded the deadline budget") from None

    # -- single-cuboid ops (routed) ----------------------------------------
    def read_cuboid(self, r: int, m: int, channel: int = 0) -> np.ndarray:
        with self._gate.op():
            topo = self._topo
            members = topo.router.replica_set(r, m)
            self._touch_heat(self._read_heat, r, m)
            t_left = deadline.remaining()
            t_end = None if t_left is None else time.monotonic() + t_left
            tried: List[int] = []
            last: Optional[BaseException] = None
            while True:
                cands = self._filter_members(topo, members, tried,
                                             r, channel, m, m + 1)
                if not cands:
                    break
                budget = None if t_end is None else t_end - time.monotonic()
                if budget is not None and budget <= 0 and last is not None:
                    break  # budget spent; surface the last failure
                if budget is not None and len(cands) > 1:
                    # Split the remainder across untried members: a hung
                    # first replica must leave failover headroom.
                    budget = budget / len(cands)
                idx = self._pick_replica(topo, cands)
                tried.append(idx)
                node = topo.nodes[idx]
                try:
                    out = self._call_node(
                        node, idx,
                        functools.partial(node.read_cuboid, r, m, channel),
                        budget)
                except Exception as e:
                    self._record_error(node, e)
                    last = e
                    continue  # retry onto the next surviving replica
                self._record_ok(node)
                return out
            if last is not None:
                raise last
            raise NoLiveReplica(f"no serving replica for r={r} m={m}")

    def write_cuboid(self, r: int, m: int, data: np.ndarray, channel: int = 0) -> None:
        with self._gate.op():
            topo = self._topo
            members = topo.router.replica_set(r, m)
            self._touch_heat(self._write_heat, r, m)
            targets = self._write_targets(topo, r, m)
            migrating = len(targets) != len(members)
            if not migrating and not self._degraded_cluster(topo):
                for node in targets:
                    topo.nodes[node].write_cuboid(r, m, data, channel)
                return
            # Migrating double-writes and degraded-cluster writes both
            # serialize with the copiers through the move lock: a stale
            # copy (migration or repair) must never clobber this write.
            # Migrating keys are strict (every reachable target must ack —
            # a member added by the move becomes authoritative at swap and
            # its old members get range-dropped, so a quorum miss there
            # could strand the only fresh copy); others ack at a quorum of
            # live members and queue misses for repair.
            with self._move_lock:
                self._write_degraded(
                    topo, r, {m: data}, channel,
                    targets_of=lambda _m: targets,
                    strict_of=lambda _m: migrating)

    def has_cuboid(self, r: int, m: int, channel: int = 0) -> bool:
        with self._gate.op():
            topo = self._topo
            members = topo.router.replica_set(r, m)
            tried: List[int] = []
            last: Optional[BaseException] = None
            while True:
                cands = self._filter_members(topo, members, tried,
                                             r, channel, m, m + 1)
                if not cands:
                    break
                idx = self._pick_replica(topo, cands)
                tried.append(idx)
                node = topo.nodes[idx]
                try:
                    out = node.has_cuboid(r, m, channel)
                except Exception as e:
                    self._record_error(node, e)
                    last = e
                    continue
                self._record_ok(node)
                return out
            if last is not None:
                raise last
            raise NoLiveReplica(f"no serving replica for r={r} m={m}")

    # -- batch ops (routed + parallel) -------------------------------------
    def read_run(self, r: int, start: int, stop: int, channel: int = 0) -> List[np.ndarray]:
        """Run read in curve order, split at partition boundaries; each
        piece is served by the least-loaded eligible member of its
        replica set, failing over to the next member on error."""
        with self._gate.op():
            topo = self._topo
            out: List[np.ndarray] = []
            assigned: Dict[int, int] = {}
            for members, a, b in topo.router.split_run_replicas(r, start, stop):
                self._touch_heat(self._read_heat, r, a, b - a)
                out.extend(self._read_piece(topo, r, channel, members, a, b, assigned))
            return out

    def _read_piece(self, topo, r, channel, members, a, b, assigned) -> List[np.ndarray]:
        tried: List[int] = []
        last: Optional[BaseException] = None
        while True:
            cands = self._filter_members(topo, members, tried, r, channel, a, b)
            if cands is None:
                blocks: List[np.ndarray] = []
                for m in range(a, b):
                    blocks.extend(self._read_piece(
                        topo, r, channel, members, m, m + 1, assigned))
                return blocks
            if not cands:
                break
            idx = self._pick_replica(topo, cands, assigned)
            tried.append(idx)
            assigned[idx] = assigned.get(idx, 0) + 1
            node = topo.nodes[idx]
            try:
                with node.serving():
                    out = node.read_run(r, a, b, channel)
            except Exception as e:
                self._record_error(node, e)
                last = e
                continue
            self._record_ok(node)
            return out
        if last is not None:
            raise last
        raise NoLiveReplica(f"no serving replica for r={r} range [{a},{b})")

    def _fan_out_fetch(self, topo, r, runs, channel, node_call, merge) -> None:
        """Shared failover engine for the batch fetch paths.

        Splits ``runs`` across eligible replica members, fans out, and
        re-routes the pieces of every failed (or deadline-expired) node
        onto surviving members — round by round, excluding each member
        that already failed this request — until every piece lands or no
        member remains.  ``node_call(idx, node_runs)`` performs one
        node's fetch; ``merge(result)`` folds a successful one in (a
        retried sink write re-lands identical bytes in the same disjoint
        slices, so double-merges are benign)."""
        t_left = deadline.remaining()
        t_end = None if t_left is None else time.monotonic() + t_left
        failed: set = set()
        pending = list(runs)
        last: Optional[BaseException] = None
        rounds = 0
        while pending:
            try:
                by_node = self._read_split(topo, r, pending,
                                           channel=channel, exclude=failed)
            except NoLiveReplica:
                if last is not None:
                    raise last from None
                raise
            jobs = {
                idx: self._serving_job(
                    topo.nodes[idx],
                    functools.partial(node_call, idx, node_runs),
                    idx,
                )
                for idx, node_runs in by_node.items()
            }
            budget = None if t_end is None else t_end - time.monotonic()
            if budget is not None:
                # Leave failover headroom: early rounds get a slice of the
                # remainder so a hung node can't starve the retry rounds.
                rounds_left = max(1, topo.router.n_replicas - rounds)
                if rounds_left > 1:
                    budget = budget / rounds_left
            rounds += 1
            results = self._fan_out_checked(jobs, budget)
            pending = []
            for idx, (ok, value) in results.items():
                node = topo.nodes[idx]
                if ok:
                    self._record_ok(node)
                    merge(value)
                else:
                    self._record_error(node, value)
                    last = value
                    failed.add(idx)
                    pending.extend(by_node[idx])

    def fetch_runs(
        self,
        r: int,
        runs: Sequence[Tuple[int, int]],
        channel: int = 0,
        decode: bool = False,
    ):
        """Batch blob fetch: split runs by owner, fetch nodes in parallel.

        ``decode=True`` is the pipelined cold-read mode: each node worker
        decompresses its own runs' blobs (chunked over the shared decode
        pool) and the merged result maps morton index to decoded block —
        decode work rides the per-node fan-out instead of serializing in
        the caller thread.
        """
        with self._gate.op():
            topo = self._topo
            merged: Dict[int, object] = {}

            def node_call(idx, node_runs):
                return topo.nodes[idx].fetch_runs(r, node_runs, channel, decode=decode)

            self._fan_out_fetch(topo, r, list(runs), channel, node_call, merged.update)
            return merged

    def fetch_blocks(
        self,
        r: int,
        runs: Sequence[Tuple[int, int]],
        channel: int = 0,
        sink: Optional[BlockSink] = None,
    ) -> Dict[int, Optional[np.ndarray]]:
        """Decoded-cuboid batch fetch, fanned out per node.

        Every node worker runs the full pipelined cold path on its own
        runs — cache lookups, parallel decompress, plan-driven segment
        prefetch — and with ``sink`` it assembles straight into the
        caller's shared output buffer (the cutout engine passes a sink
        writing disjoint ``buf_slices``, so concurrent node workers never
        race).  Without a sink, returns the merged block dict.
        """
        with self._gate.op():
            topo = self._topo
            merged: Dict[int, Optional[np.ndarray]] = {}

            def node_call(idx, node_runs):
                return topo.nodes[idx].fetch_blocks(r, node_runs, channel, sink=sink)

            def merge(part):
                if part:
                    merged.update(part)

            self._fan_out_fetch(topo, r, list(runs), channel, node_call, merge)
            return merged

    def run_batch(self, jobs: Sequence[Callable[[], object]]) -> List[object]:
        """Overlap independent request-level jobs (the §4.2 batch
        interface): each job typically drives a whole cutout, whose node
        fan-out and decode chunks then pipeline with the other boxes'.
        Serial when request parallelism is disabled (``max_workers<=1``).
        """
        jobs = list(jobs)
        # Request overlap is its own axis: a single-node cluster still
        # pipelines one box's I/O against another's decode.  Only an
        # explicit max_workers<=1 (the deterministic-profiling knob)
        # forces serial execution.
        serial = self._cfg_max_workers is not None and self._cfg_max_workers <= 1
        if serial or len(jobs) <= 1:
            return [job() for job in jobs]
        with self._batch_lock:
            if self._batch_pool is None:
                self._batch_pool = cf.ThreadPoolExecutor(
                    max_workers=self.request_slots,
                    thread_name_prefix="ocp-batch",
                )
            pool = self._batch_pool
        before_submit()
        futures = [pool.submit(trace.bind(job)) for job in jobs]
        return [f.result() for f in futures]

    @property
    def request_slots(self) -> int:
        """Concurrency of the request-level batch pool (`run_batch`) — the
        admission signal an HTTP front door sizes its limiter from."""
        if self._cfg_max_workers is not None and self._cfg_max_workers <= 1:
            return 1
        return min(8, max(2, len(self._topo.nodes)))

    def store_cuboids(self, r: int, blocks: Dict[int, np.ndarray], channel: int = 0) -> None:
        """Batch write: group blocks by replica set, write nodes in
        parallel (every member gets every block it holds).

        Blocks inside a migrating range are *also* written to the members
        being added (under the move lock), keeping them complete before
        the topology swap makes them authoritative.
        """
        with self._gate.op():
            topo = self._topo
            moves = self._moves.get(r, ()) if (self._moves and topo is self._moves_topo) else ()
            if self._degraded_cluster(topo):
                # Degraded: some member is unhealthy or holds queued
                # repairs — every block takes the quorum path under the
                # move lock, serialized with the repair/migration copiers.
                for m in blocks:
                    self._touch_heat(self._write_heat, r, m)

                def targets_of(m):
                    members = topo.router.replica_set(r, m)
                    extras = _move_extras(moves, m, members) if moves else ()
                    return members + extras

                def strict_of(m):
                    members = topo.router.replica_set(r, m)
                    return bool(moves) and bool(_move_extras(moves, m, members))

                with self._move_lock:
                    self._write_degraded(topo, r, dict(blocks), channel,
                                         targets_of, strict_of)
                return
            by_node: Dict[int, Dict[int, np.ndarray]] = {}
            doubling: Dict[int, Dict[int, np.ndarray]] = {}
            for m, data in blocks.items():
                members = topo.router.replica_set(r, m)
                self._touch_heat(self._write_heat, r, m)
                extras = _move_extras(moves, m, members) if moves else ()
                if extras:
                    # migrating: double-write members + added members under
                    # the move lock (serialized with the copier)
                    for node in members + extras:
                        doubling.setdefault(node, {})[m] = data
                else:
                    for node in members:
                        by_node.setdefault(node, {})[m] = data
            if by_node:  # non-moving blocks never wait on the move lock
                jobs = {
                    node: functools.partial(
                        topo.nodes[node].store_cuboids, r, node_blocks, channel
                    )
                    for node, node_blocks in by_node.items()
                }
                self._fan_out(jobs)
            if doubling:
                jobs = {
                    node: functools.partial(
                        topo.nodes[node].store_cuboids, r, node_blocks, channel
                    )
                    for node, node_blocks in doubling.items()
                }
                with self._move_lock:
                    self._fan_out(jobs)

    def _write_degraded(
        self,
        topo: _Topology,
        r: int,
        blocks: Dict[int, np.ndarray],
        channel: int,
        targets_of: Callable[[int], Tuple[int, ...]],
        strict_of: Callable[[int], bool],
    ) -> None:
        """Replicated write with per-key quorum accounting (the degraded /
        migrating slow path; callers hold the move lock).

        ``targets_of(m)`` lists every node key ``m`` must reach.  Dead
        members are skipped outright — their miss goes straight to the
        repair queue (a write must never wait on a dead node).  Every
        other member is attempted serially; a failure degrades its health
        and queues the miss.  Each key must then ack on a quorum —
        majority of its non-dead targets, or ALL of them when
        ``strict_of(m)`` (migrating keys) — or :class:`WriteQuorumError`
        raises and the write is unacknowledged.  Either way each miss is
        marked dirty on the member that missed it, so reads keep routing
        to members holding the freshest value until repair replays it."""
        per_node: Dict[int, Dict[int, np.ndarray]] = {}
        attempted: Dict[int, List[int]] = {}  # m -> non-dead targets
        for m, data in blocks.items():
            attempted[m] = []
            for t in targets_of(m):
                if self._health_state(topo.nodes[t]) == "dead":
                    self._mark_dirty(topo.nodes[t], (r, channel, m))
                else:
                    attempted[m].append(t)
                    per_node.setdefault(t, {})[m] = data
        failed: Dict[int, BaseException] = {}
        for idx in sorted(per_node):
            node = topo.nodes[idx]
            try:
                node.store_cuboids(r, per_node[idx], channel)
            except Exception as e:
                self._record_error(node, e)
                failed[idx] = e
                for m in per_node[idx]:
                    self._mark_dirty(node, (r, channel, m))
            else:
                self._record_ok(node)
                # This node now holds the freshest value for these keys:
                # drop any stale repair marks so a later resync can never
                # replay an older peer copy over an acked write.
                self._clear_dirty(node,
                                  [(r, channel, m) for m in per_node[idx]])
        under: List[str] = []
        for m in blocks:
            live = attempted[m]
            acks = sum(1 for t in live if t not in failed)
            quorum = len(live) if strict_of(m) else (len(live) // 2 + 1)
            quorum = max(1, quorum)
            if acks < quorum:
                under.append(f"m={m}: {acks}/{quorum} acks "
                             f"(targets {tuple(targets_of(m))})")
        if under:
            last = next(iter(failed.values())) if failed else None
            raise WriteQuorumError(
                f"write quorum not reached at r={r}: " + "; ".join(under[:4])
            ) from last

    # -- elasticity (paper §6: dynamically redistribute data) ---------------
    def topology(self) -> Dict[str, object]:
        """Introspection snapshot served by ``GET /topology``."""
        with self._gate.op():
            topo = self._topo
            # Shards appended by a grow-in-progress (or add_node without a
            # rebalance) ride outside the router: pad their segments empty
            # so "node i owns segments[i]" holds for the whole node tuple.
            n_pad = len(topo.nodes) - topo.router.n_nodes
            segments = {}
            for r in range(self.spec.n_resolutions):
                segs = topo.router.segments(r)
                if n_pad > 0:
                    n_cells = topo.router.n_cells(r)
                    segs = segs + [(n_cells, n_cells)] * n_pad
                segments[r] = segs
            return {
                "n_nodes": len(topo.nodes),
                "elastic": True,
                "rebalancing": bool(self._moves),
                "replication": topo.router.n_replicas,
                # effective vs achievable target: a gap means segments are
                # under-replicated (ring shrank below N, or riders joined
                # outside the router) and re_replicate() can heal it
                "replication_target": min(self.replication, len(topo.nodes)),
                "segments": segments,
                "keys_per_node": self._key_counts(topo),
                "cache_nodes": sum(1 for n in topo.nodes if n.cache is not None),
                "write_behind_nodes": sum(
                    1 for n in topo.nodes if n.write_behind is not None
                ),
                "health": [self._health_state(n) for n in topo.nodes],
                "repair_pending": sum(self._repair_counts(topo)),
            }

    def add_node(
        self,
        node_factory: Optional[NodeFactory] = None,
        rebalance: bool = True,
        wait: bool = True,
    ) -> int:
        """Grow the cluster by one shard; returns the new node's index.

        With ``rebalance=True`` (default) keys migrate onto it immediately
        (occupancy-balanced); otherwise it joins owning nothing until the
        next ``rebalance()``.
        """
        if not self._admin_lock.acquire(blocking=wait):
            raise RebalanceInFlight("a topology change is already in flight")
        try:
            index = self.n_nodes
            if rebalance:
                self.rebalance(target=index + 1, node_factory=node_factory)
            else:
                self._widen(index + 1, node_factory)
            return index
        finally:
            self._admin_lock.release()

    def remove_node(self, node: int = -1, wait: bool = True) -> Dict[str, object]:
        """Shrink the cluster: drop ``node`` with zero data loss.

        With ``replication >= 2`` every range the victim holds survives on
        its other members, which are promoted in place; ranges where the
        victim is the *only* member (replication 1) are streamed off it
        first.  Either way the migration protocol keeps concurrent reads
        and writes bit-identical throughout.
        """
        if not self._admin_lock.acquire(blocking=wait):
            raise RebalanceInFlight("a topology change is already in flight")
        try:
            topo = self._topo
            n = len(topo.nodes)
            if n <= 1:
                raise ValueError("cannot remove the last node")
            idx = node if node >= 0 else n + node
            if not (0 <= idx < n):
                raise ValueError(f"node {node} out of range for {n} nodes")
            t0 = time.perf_counter()
            # The survivors re-cut by occupancy; the victim appears in no
            # final replica set.  Final-router indices j map to physical
            # node j (below the victim) or j+1 (above it).
            occupancy = self._occupancy(topo)
            final_parts = {
                r: Partition.balanced(occupancy.get(r, ()), topo.router.n_cells(r), n - 1)
                for r in range(self.spec.n_resolutions)
            }
            final_router = Router(
                self.spec, n - 1, final_parts, topo.router.replication
            )
            phys_of_final = [j if j < idx else j + 1 for j in range(n - 1)]
            final_nodes = topo.nodes[:idx] + topo.nodes[idx + 1 :]
            moved_keys, moved_bytes = self._migrate_live(
                topo,
                final_router,
                phys_of_final,
                final_nodes,
                avoid_sources=frozenset({idx}),
            )
            # _migrate_live drained every op that could still hold the old
            # snapshot; nothing references the victim now.
            topo.nodes[idx].close()
            seconds = time.perf_counter() - t0
            REGISTRY.histogram(
                "repro_migration_seconds",
                {"op": "remove_node"},
                "live topology-change duration by admin op",
            ).observe(seconds)
            return {
                "n_nodes": n - 1,
                "removed": idx,
                "moved_keys": moved_keys,
                "moved_bytes": moved_bytes,
                "seconds": seconds,
            }
        finally:
            self._admin_lock.release()

    def rebalance(
        self,
        target: Optional[int] = None,
        node_factory: Optional[NodeFactory] = None,
        batch_keys: int = 64,
        wait: bool = True,
    ) -> Dict[str, object]:
        """Re-partition by occupancy and migrate keys live.

        ``target`` is the desired node count (default: keep the current
        one and only move boundaries).  Growth appends fresh shards first
        (outside the router, owning nothing), shrink drops the trailing
        shards after their keys migrate off.  ``wait=False`` raises
        :class:`RebalanceInFlight` if another topology change holds the
        admin lock.  Returns migration stats; see the module docstring
        for the coherence protocol.
        """
        if not self._admin_lock.acquire(blocking=wait):
            raise RebalanceInFlight("a topology change is already in flight")
        try:
            t0 = time.perf_counter()
            n_old = self.n_nodes
            n_new = n_old if target is None else int(target)
            if n_new <= 0:
                raise ValueError("rebalance target must be positive")
            if n_new > n_old:
                self._widen(n_new, node_factory)
            topo = self._topo
            occupancy = self._occupancy(topo)
            final_parts = {
                r: Partition.balanced(occupancy.get(r, ()), topo.router.n_cells(r), n_new)
                for r in range(self.spec.n_resolutions)
            }
            final_router = Router(
                self.spec, n_new, final_parts, topo.router.replication
            )
            final_nodes = topo.nodes[:n_new]
            dropped = topo.nodes[n_new:]
            try:
                moved_keys, moved_bytes = self._migrate_live(
                    topo,
                    final_router,
                    list(range(n_new)),
                    final_nodes,
                    batch_keys=batch_keys,
                )
            except BaseException:
                if n_new > n_old:
                    self._unwiden(n_old)
                raise
            for node in dropped:  # shrink: every op on the old snapshot drained
                node.close()
            seconds = time.perf_counter() - t0
            REGISTRY.histogram(
                "repro_migration_seconds",
                {"op": "rebalance"},
                "live topology-change duration by admin op",
            ).observe(seconds)
            return {
                "n_nodes": n_new,
                "moved_keys": moved_keys,
                "moved_bytes": moved_bytes,
                "seconds": seconds,
            }
        finally:
            self._admin_lock.release()

    def re_replicate(self, wait: bool = True) -> Dict[str, object]:
        """Heal under-replication: bring every curve segment back up to
        ``min(replication, n_nodes)`` copies through the live-migration
        copy path.

        The gap this closes: after the ring shrinks below ``replication``
        (``remove_node`` down to fewer nodes than N) and a node later
        joins with ``add_node(rebalance=False)``, the rider sits *outside*
        the router — no successor ring includes it, so every segment stays
        under-replicated forever unless a full rebalance happens to run.
        This verb widens the router over the riders **without moving any
        partition boundary** (they own empty segments) and lets the
        replica-set diff copy each range to its new ring members — cheaper
        and less disruptive than a rebalance, and safe under the same
        coherence protocol.  Idempotent: a fully-replicated cluster
        returns ``healed=False`` with zero copies.
        """
        if not self._admin_lock.acquire(blocking=wait):
            raise RebalanceInFlight("a topology change is already in flight")
        try:
            t0 = time.perf_counter()
            topo = self._topo
            n = len(topo.nodes)
            target = min(self.replication, n)
            if topo.router.n_nodes == n and topo.router.n_replicas >= target:
                return {
                    "n_nodes": n,
                    "replication": topo.router.n_replicas,
                    "healed": False,
                    "moved_keys": 0,
                    "moved_bytes": 0,
                    "seconds": time.perf_counter() - t0,
                }
            final_parts = {}
            for r in range(self.spec.n_resolutions):
                part = topo.router.partition(r)
                extra = n - topo.router.n_nodes
                if extra > 0:
                    # widen with trailing empty segments: riders enter the
                    # successor rings but own no primary range
                    part = Partition(part.bounds + (part.n_cells,) * extra)
                final_parts[r] = part
            final_router = Router(self.spec, n, final_parts, self.replication)
            moved_keys, moved_bytes = self._migrate_live(
                topo, final_router, list(range(n)), topo.nodes
            )
            seconds = time.perf_counter() - t0
            REGISTRY.histogram(
                "repro_migration_seconds",
                {"op": "re_replicate"},
                "live topology-change duration by admin op",
            ).observe(seconds)
            return {
                "n_nodes": n,
                "replication": self._topo.router.n_replicas,
                "healed": True,
                "moved_keys": moved_keys,
                "moved_bytes": moved_bytes,
                "seconds": seconds,
            }
        finally:
            self._admin_lock.release()

    def resync_node(self, node: int, wait: bool = True) -> Dict[str, object]:
        """Anti-entropy resync: replay a node's queued repair keys from
        its replica peers, then re-admit it (recovering → alive).

        Every key the node missed (failed writes, writes skipped while it
        was dead) sits in its repair set.  Each batch is copied under the
        move lock from a serving member of the key's *current* replica
        set — writes overlapping a repair also serialize on that lock, so
        a copy can never clobber a fresher concurrent write.  Deletes
        replay too (a missing source blob ingests as ``None``).  Keys
        whose replica set no longer lists the node are discarded: the
        range moved off it, and resurrecting data it no longer owns would
        leak stale reads after a later reassignment.

        The supervisor calls this for every recovering node (and any
        alive node with a repair backlog); ``healed=False`` means dirt
        kept accumulating faster than eight replay rounds drained it —
        the node is still failing writes and stays un-readmitted."""
        if not self._admin_lock.acquire(blocking=wait):
            raise RebalanceInFlight("a topology change is already in flight")
        try:
            topo = self._topo
            n = len(topo.nodes)
            idx = node if node >= 0 else n + node
            if not (0 <= idx < n):
                raise ValueError(f"node {node} out of range for {n} nodes")
            target = topo.nodes[idx]
            copied = discarded = rounds = 0
            while rounds < 8:
                with self._repair_lock:
                    dirty = self._dirty.pop(id(target), None)
                if not dirty:
                    break
                rounds += 1
                try:
                    c, d = self._replay_dirty(topo, idx, sorted(dirty))
                except BaseException:
                    # a source failed mid-replay: the popped keys are not
                    # repaired — put them back so nothing is forgotten
                    with self._repair_lock:
                        self._dirty.setdefault(id(target), set()).update(dirty)
                    raise
                copied += c
                discarded += d
            with self._repair_lock:
                healed = not self._dirty.get(id(target))
            if healed:
                with self._health_lock:
                    h = self._health.get(id(target))
                    if h is not None:
                        h.errors = 0
                        if h.state != "alive":
                            h.set("alive")
            return {"node": idx, "resynced": copied, "discarded": discarded,
                    "rounds": rounds, "healed": healed}
        finally:
            self._admin_lock.release()

    def _replay_dirty(self, topo: _Topology, idx: int,
                      keys: List[Key]) -> Tuple[int, int]:
        """Copy the freshest value of each dirty key onto node ``idx``
        from the healthiest other member of its replica set, in run
        batches under the move lock.  Returns (copied, discarded)."""
        target = topo.nodes[idx]
        router = topo.router
        copied = discarded = 0
        by_rc: Dict[Tuple[int, int], List[int]] = {}
        for r, c, m in keys:
            if idx not in router.replica_set(r, m):
                discarded += 1  # range moved off this node; nothing to repair
                continue
            by_rc.setdefault((r, c), []).append(m)
        for (r, c), ms in sorted(by_rc.items()):
            ms.sort()
            for i in range(0, len(ms), 64):
                chunk = ms[i:i + 64]
                by_src: Dict[int, List[int]] = {}
                for m in chunk:
                    peers = [s for s in router.replica_set(r, m) if s != idx]
                    if not peers:
                        # replication=1: the node is the sole owner — the
                        # missed value exists nowhere else, and the write
                        # that missed was never acknowledged
                        discarded += 1
                        continue
                    # A peer that is itself dirty for this key missed the
                    # acked write too — replaying from it would roll the
                    # key back.  Every acked write leaves at least one
                    # clean acker, so clean-first is also freshest-first.
                    src = min(peers, key=lambda s: (
                        self._dirty_overlap(topo.nodes[s], r, c, m, m + 1),
                        _HEALTH_RANK.get(self._health_state(topo.nodes[s]), 0),
                        s))
                    by_src.setdefault(src, []).append(m)
                for src, sms in sorted(by_src.items()):
                    with self._move_lock:
                        blobs = topo.nodes[src].fetch_runs(
                            r, morton.indices_to_runs(sms), c)
                        items = [((r, c, m), blobs.get(m)) for m in sms]
                        target.ingest_blobs(items)
                    copied += len(items)
        return copied, discarded

    def _swap_topo(self, topo: _Topology) -> None:
        self._topo = topo  # atomic reference swap; ops snapshot it once
        ids = {id(n) for n in topo.nodes}
        with self._health_lock:
            for key in [k for k in self._health if k not in ids]:
                del self._health[key]
        with self._repair_lock:
            for key in [k for k in self._dirty if k not in ids]:
                del self._dirty[key]
        if self._cfg_max_workers is not None:
            return  # caller pinned the worker count; keep it
        pool = self._pool
        workers = getattr(pool, "_max_workers", 0) if pool is not None else 0
        if len(topo.nodes) > max(workers, 1):
            # Grow fan-out parallelism with the cluster.  The old pool is
            # retired, not shut down: in-flight ops hold a reference and
            # may still submit to it; close() reaps every generation.
            if pool is not None:
                self._retired_pools.append(pool)
            self._pool = cf.ThreadPoolExecutor(
                max_workers=len(topo.nodes), thread_name_prefix="ocp-node"
            )

    def _widen(self, n_new: int, node_factory: Optional[NodeFactory]) -> None:
        """Append fresh shards to the node tuple *without* touching the
        Router: they own nothing and sit in no replica set until a
        migration's final swap assigns them, so no intermediate router
        (whose successor rings would differ from the final one) is ever
        published."""
        topo = self._topo
        nodes = list(topo.nodes)
        for i in range(len(nodes), n_new):
            nodes.append(self._build_node(i, node_factory))
        self._swap_topo(_Topology(tuple(nodes), topo.router))
        self._gate.synchronize()  # all traffic now sees the widened topology

    def _unwiden(self, n_old: int) -> None:
        """Undo `_widen` after a failed grow-migration: drop the appended
        shards again — but only while the router never swapped (the failed
        migration left ownership untouched; its rollback already wiped any
        blobs landed on the new shards).  Without this, every failed
        ``POST /rebalance`` would leak a set of phantom nodes (threads,
        queues, caches) and misreport the cluster size."""
        topo = self._topo
        if topo.router.n_nodes > n_old:
            return  # the final swap happened; the widened nodes must stay
        dropped = topo.nodes[n_old:]
        if not dropped:
            return
        self._swap_topo(_Topology(topo.nodes[:n_old], topo.router))
        self._gate.synchronize()
        for node in dropped:
            try:
                node.close()
            except Exception as e:
                # the original migration failure is re-raising through the
                # caller; record this secondary one instead of losing it
                self.last_unwiden_error = repr(e)

    def _occupancy(self, topo: _Topology) -> Dict[int, List[int]]:
        """{resolution: multiset of occupied cells} — the rebalance signal
        (`keys_per_node()` is its per-node projection)."""
        occupancy: Dict[int, List[int]] = {}
        for node in topo.nodes:
            for r, _c, m in node.stored_keys():
                occupancy.setdefault(r, []).append(m)
        return occupancy

    def _replica_moves(
        self,
        topo: _Topology,
        final_router: Router,
        phys_of_final: Sequence[int],
    ) -> Dict[int, Tuple[Move, ...]]:
        """Diff replica-set membership between the current router and the
        final one: {r: ((start, stop, old_members, new_members), ...)} for
        every curve range whose set changes.  All indices are physical
        positions in ``topo`` (final-router indices mapped through
        ``phys_of_final``)."""
        moves: Dict[int, Tuple[Move, ...]] = {}
        for r in range(self.spec.n_resolutions):
            old_part = topo.router.partition(r)
            new_part = final_router.partition(r)
            cuts = sorted(set(old_part.bounds) | set(new_part.bounds))
            entries: List[Move] = []
            for a, b in zip(cuts, cuts[1:]):
                if a >= b:
                    continue
                old_m = topo.router.replicas_of(int(old_part.owner(a)))
                new_m = tuple(
                    phys_of_final[j] for j in final_router.replicas_of(int(new_part.owner(a)))
                )
                if set(old_m) == set(new_m):
                    continue
                prev = entries[-1] if entries else None
                if prev is not None and prev[1] == a and prev[2:] == (old_m, new_m):
                    entries[-1] = (prev[0], b, old_m, new_m)
                else:
                    entries.append((a, b, old_m, new_m))
            if entries:
                moves[r] = tuple(entries)
        return moves

    def _migrate_live(
        self,
        topo: _Topology,
        final_router: Router,
        phys_of_final: Sequence[int],
        final_nodes: Tuple[CuboidStore, ...],
        batch_keys: int = 64,
        avoid_sources: frozenset = frozenset(),
    ) -> Tuple[int, int]:
        """Migrate from ``topo`` to the final (nodes, router) pair with
        zero lost or stale reads (the module-docstring protocol).

        ``phys_of_final[j]`` is final-router node ``j``'s position in
        ``topo.nodes`` — the two differ when a mid-tuple node is being
        removed.  ``avoid_sources`` are nodes the copy phase should not
        stream from when any other old member holds the range (the
        decommissioning victim).  Returns (moved_keys, moved_bytes),
        counting one move per (key, added member) copy."""
        moves = self._replica_moves(topo, final_router, phys_of_final)
        final_topo = _Topology(tuple(final_nodes), final_router)
        if not moves:  # membership unchanged (or only empty ranges moved)
            self._swap_topo(final_topo)
            self._gate.synchronize()
            return 0, 0

        # 1. register: publish the move set; once every in-flight op has
        # drained, all writes to moving keys double-write to the members
        # being added.
        self._moves = moves
        self._moves_topo = topo
        moved_keys = moved_bytes = 0
        swapped = False
        try:
            self._gate.synchronize()
            keys_by_src: Dict[int, List[Key]] = {}
            # 2. copy: stream existing keys from a surviving old member to
            # each added member, in small batches under the move lock
            # (serialized with double-writes so a stale copy can never
            # overwrite a fresher concurrent write).
            for r, entries in sorted(moves.items()):
                for start, stop, old_m, new_m in entries:
                    added = [d for d in new_m if d not in old_m]
                    if not added:
                        continue
                    srcs = [s for s in old_m if s not in avoid_sources] or list(old_m)
                    src = srcs[0]
                    if src not in keys_by_src:
                        keys_by_src[src] = topo.nodes[src].stored_keys()
                    by_channel: Dict[int, List[int]] = {}
                    for kr, kc, km in keys_by_src[src]:
                        if kr == r and start <= km < stop:
                            by_channel.setdefault(kc, []).append(km)
                    for c, ms in sorted(by_channel.items()):
                        ms.sort()
                        for i in range(0, len(ms), batch_keys):
                            chunk = ms[i : i + batch_keys]
                            with self._move_lock:
                                blobs = topo.nodes[src].fetch_runs(
                                    r, morton.indices_to_runs(chunk), c
                                )
                                items = [((r, c, m), blobs.get(m)) for m in chunk]
                                for dst in added:
                                    topo.nodes[dst].ingest_blobs(items)
                            moved_keys += len(items) * len(added)
                            moved_bytes += sum(len(b) for _, b in items if b) * len(added)
            # 3. swap: the final topology becomes authoritative in ONE
            # publication — replica rings are never evaluated against an
            # intermediate node count.  The move set must stay published
            # until every op that resolved membership under the OLD router
            # has drained — such a writer still routes to the old members
            # and relies on the move entry to also hit the added ones;
            # retiring the set first would let its write miss a now-
            # authoritative member.
            self._swap_topo(final_topo)
            swapped = True
            self._gate.synchronize()
        finally:
            # 4. retire the move set, then drain writers that may still
            # be double-writing before any key is deleted.
            self._moves = {}
            self._moves_topo = None
            self._gate.synchronize()
            if not swapped:
                # A failed migration must not strand blobs on the added
                # members: the old membership stays authoritative, and
                # anything landed there (copies *and* double-writes)
                # would resurrect as stale data when a later rebalance
                # re-assigns the range.  Under the old router those nodes
                # hold nothing inside a moved range and reads never
                # routed there, so wiping the range is invisible.
                self._rollback_destinations(topo, moves)
        # cleanup: every key in a moved range (including ones double-
        # written during the move) leaves the backends and cache of each
        # member dropped from the range's set — the surviving/added
        # members absorbed them already.
        ranges_by_node: Dict[int, List[Tuple[int, int, int]]] = {}
        for r, entries in moves.items():
            for start, stop, old_m, new_m in entries:
                for node in old_m:
                    if node not in new_m:
                        ranges_by_node.setdefault(node, []).append((r, start, stop))
        self._drop_ranges(topo, ranges_by_node, best_effort=False)
        return moved_keys, moved_bytes

    @classmethod
    def _rollback_destinations(
        cls, topo: _Topology, moves: Dict[int, Tuple[Move, ...]]
    ) -> None:
        """Best-effort: delete everything a failed migration landed on the
        added members (called after the move set is retired)."""
        ranges_by_node: Dict[int, List[Tuple[int, int, int]]] = {}
        for r, entries in moves.items():
            for start, stop, old_m, new_m in entries:
                for node in new_m:
                    if node not in old_m:
                        ranges_by_node.setdefault(node, []).append((r, start, stop))
        cls._drop_ranges(topo, ranges_by_node, best_effort=True)

    @staticmethod
    def _drop_ranges(
        topo: _Topology,
        ranges_by_node: Dict[int, List[Tuple[int, int, int]]],
        best_effort: bool,
    ) -> None:
        """Delete every stored key inside (r, start, stop) ranges from the
        given nodes' backends, and drop the whole range from their caches
        (blobs *and* cached absences — after a membership change a node's
        stale cache entries for the range must not outlive its data)."""
        for idx, ranges in ranges_by_node.items():
            node = topo.nodes[idx]
            try:
                stale = [
                    k
                    for k in node.stored_keys()
                    if any(k[0] == r and a <= k[2] < b for r, a, b in ranges)
                ]
                if stale:
                    node.ingest_blobs([(k, None) for k in stale])
                if node.cache is not None:
                    for r, a, b in ranges:
                        node.cache.invalidate_range(r, a, b)
            except Exception:
                if not best_effort:
                    raise
                continue  # the original migration failure is re-raising

    # -- maintenance / introspection ---------------------------------------
    def migrate(self) -> int:
        """Flush every node's write path into its read path (SSD→DB)."""
        with self._gate.op():
            nodes = self._topo.nodes
            jobs = {i: nodes[i].migrate for i in range(len(nodes))}
            return sum(self._fan_out(jobs).values())

    def stored_keys(self) -> List[Key]:
        """Every distinct key in the cluster (replica copies dedupe)."""
        with self._gate.op():
            keys: set = set()
            for node in self._topo.nodes:
                keys.update(node.stored_keys())
            return sorted(keys)

    def storage_bytes(self) -> int:
        with self._gate.op():
            return sum(node.storage_bytes() for node in self._topo.nodes)

    def keys_per_node(self) -> List[int]:
        """Shard occupancy — the rebalancing signal.

        Counted without the flush barrier (pending write-behind writes
        are folded in from a queue snapshot): a monitoring loop polling
        occupancy must not keep draining the queues it is observing."""
        with self._gate.op():
            return self._key_counts(self._topo)

    def _key_counts(self, topo: _Topology) -> List[int]:
        nodes = topo.nodes
        jobs = {i: nodes[i].key_count for i in range(len(nodes))}
        counts = self._fan_out(jobs)
        return [counts[i] for i in range(len(nodes))]

    @property
    def read_stats(self) -> PathStats:
        """Cluster-aggregate read-path stats (per-node stats on `nodes`)."""
        return _sum_stats([n.read_stats for n in self._topo.nodes])

    @property
    def write_stats(self) -> PathStats:
        return _sum_stats([n.write_stats for n in self._topo.nodes])

    def cache_counters(self) -> Dict[str, int]:
        """Aggregate hot-cuboid cache counters across node shards."""
        total: Dict[str, int] = {}
        for node in self._topo.nodes:
            if node.cache is None:
                continue
            for k, v in node.cache.counters().items():
                total[k] = total.get(k, 0) + v
        return total

    def queue_counters(self) -> Dict[str, int]:
        """Aggregate write-behind queue counters across node shards."""
        total: Dict[str, int] = {}
        for node in self._topo.nodes:
            if node.write_behind is None:
                continue
            for k, v in node.write_behind.counters().items():
                total[k] = total.get(k, 0) + v
        return total
