"""Per-request deadline budgets for degraded-mode data paths.

A budget is a thread-local wall-clock allowance opened at the edge of a
request (the HTTP front door wraps every data-plane verb in one, sized by
``REPRO_OP_DEADLINE_MS``) and consulted deep inside the cluster's
replicated read paths: each replica attempt is waited on for at most the
*remaining* budget, so a hung node can delay a request by its deadline —
never stall it indefinitely — before the read fails over to the next
surviving member.

Library callers that open no budget are unaffected: ``remaining()``
returns ``None`` and the cluster waits on nodes exactly as before.  The
budget only ever *shrinks* when nested, so an inner stage can tighten but
not extend the caller's allowance.
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import Optional

from ..analysis import knobs

_local = threading.local()


def default_budget_s() -> Optional[float]:
    """The front door's per-request allowance in seconds, from the
    ``REPRO_OP_DEADLINE_MS`` knob; ``None`` when deadlines are disabled
    (a zero or negative value)."""
    ms = knobs.get_float("REPRO_OP_DEADLINE_MS", 2000.0)
    if ms is None or ms <= 0:
        return None
    return ms / 1000.0


@contextlib.contextmanager
def budget(seconds: Optional[float] = None):
    """Open a deadline budget for the calling thread.

    ``seconds=None`` uses the knob default.  Nested budgets never extend
    an enclosing one — the tighter deadline wins.
    """
    if seconds is None:
        seconds = default_budget_s()
    prev = getattr(_local, "expires", None)
    if seconds is None:
        expires = prev  # disabled: inherit whatever is already active
    else:
        expires = time.monotonic() + float(seconds)
        if prev is not None:
            expires = min(expires, prev)
    _local.expires = expires
    try:
        yield
    finally:
        _local.expires = prev


def remaining() -> Optional[float]:
    """Seconds left in the active budget (clamped at 0), or ``None`` when
    the calling thread has no budget open — unbounded, the pre-deadline
    behaviour."""
    expires = getattr(_local, "expires", None)
    if expires is None:
        return None
    return max(0.0, expires - time.monotonic())
