"""RESTful-style data-service verbs over the cluster (paper §4.2).

The paper's Web services expose cutout / annotation queries as stateless
HTTP verbs; any front-end can serve any request because state lives in the
data cluster.  We reproduce that contract transport-free: every handler is
a pure ``(service, request dict) -> response dict`` function — no sockets,
no framework — so it composes with `repro.serve` (or any HTTP shim) and is
trivially testable.  Verb strings mirror the paper's URL forms
(``GET /cutout``, ``objects/.../boundingbox``, ...).

Requests name a dataset or annotation project by key; volumes travel as
numpy arrays by default or zlib blobs with ``{"encode": "zlib"}`` (the
paper returns compressed volumes on the wire).  Responses always carry an
integer ``status`` using HTTP conventions.

Consistency contract (the cache tier + write-behind queue, paper §6):

* ``PUT /cutout`` returning 200 guarantees **read-your-writes**: every
  subsequent ``GET /cutout`` through the service sees the write, even
  while it is still pending in a node's write-behind queue.  Pass
  ``{"sync": true}`` to additionally force durability before the response.
* ``POST /flush`` is the explicit **durability barrier**: when it returns,
  every previously accepted write has been applied to the node backends.
* ``GET /stats`` exposes the path/cache/queue counters (hits, misses,
  queue depth) a deployment monitors to size the tiers.

Elasticity contract (paper §6 "dynamically redistribute data"):

* ``GET /topology`` reports the cluster layout — node count, the explicit
  per-resolution curve partitions, and per-node key occupancy.
* ``POST /rebalance`` re-cuts the partitions by occupancy (optionally
  growing/shrinking to ``target`` nodes) and migrates keys *live*:
  cutout reads and writes issued concurrently through the service remain
  bit-identical before, during, and after the move.
"""

from __future__ import annotations

import dataclasses
import warnings
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.annotations import AnnotationProject
from ..core.cutout import CutoutStats, batch_cutout, cutout, project, write_cutout
from ..obs import trace
from ..obs.hist import Histogram
from ..obs.registry import REGISTRY, Metric, metric
from .store import RebalanceInFlight

Request = Dict[str, Any]
Response = Dict[str, Any]

# Malformed client input must come back as a 4xx dict, never an exception:
# missing keys, bad ints/shapes, corrupt zlib payloads, non-iterable boxes.
_BAD_REQUEST = (KeyError, ValueError, IndexError, TypeError, zlib.error)


class VolumeService:
    """Registry of datasets and annotation projects behind the verbs.

    The service itself is stateless routing glue: all durable state lives
    in the registered stores (single-node `CuboidStore` or sharded
    `ClusterStore` — the verbs do not care which, that is C3).
    """

    def __init__(self):
        self.datasets: Dict[str, Any] = {}
        self.projects: Dict[str, AnnotationProject] = {}

    def add_dataset(self, name: str, store) -> None:
        self.datasets[name] = store

    def add_project(self, name: str, proj: AnnotationProject) -> None:
        self.projects[name] = proj


def _error(status: int, message: str) -> Response:
    """The uniform error envelope: ``{"status": 4xx/5xx, "error": msg}``.

    Every handler returns ``{"status": 200, ...}`` on success and this
    shape otherwise — 404 unknown dataset/project/object, 400 malformed
    request, 409 topology change in flight, 405 unknown verb (503 is the
    transport layer's: the HTTP front door sheds with it when the
    admission limit is exceeded)."""
    return {"status": status, "error": message}


def _zlib_level(request: Request, store=None) -> int:
    """Negotiated zlib level: explicit request ``level`` wins, else the
    dataset's ``DatasetSpec.compress_level``, else 1 (wire default)."""
    level = request.get("level")
    if level is None:
        spec = getattr(store, "spec", None)
        level = getattr(spec, "compress_level", 1)
    level = int(level)
    if not (0 <= level <= 9):
        raise ValueError(f"zlib level {level} outside [0, 9]")
    return level


def _encode_volume(vol: np.ndarray, request: Request, store=None) -> Response:
    body: Response = {"status": 200, "shape": tuple(vol.shape), "dtype": str(vol.dtype)}
    if request.get("encode") == "zlib":
        level = _zlib_level(request, store)
        body["data"] = zlib.compress(np.ascontiguousarray(vol).tobytes(), level)
        body["encode"] = "zlib"
        body["level"] = level
    else:
        body["data"] = vol
    return body


def _decode_volume(request: Request) -> np.ndarray:
    data = request["data"]
    if request.get("encode") == "zlib":
        raw = zlib.decompress(data)
        vol = np.frombuffer(raw, dtype=np.dtype(request["dtype"])).reshape(request["shape"])
        # frombuffer over bytes yields a read-only view; write paths that
        # normalize/pad the block in place would raise "assignment
        # destination is read-only", so hand over a writable copy.
        return vol.copy()
    return np.asarray(data)


def _box(request: Request):
    lo = [int(x) for x in request["lo"]]
    hi = [int(x) for x in request["hi"]]
    return lo, hi


# -- observability ----------------------------------------------------------

# PathStats fields that are occupancy gauges, not monotone counters.
_GAUGE_FIELDS = {"inflight", "queue_depth", "queue_peak"}
# /metrics truncates each heat direction to its N hottest buckets so the
# exposition stays bounded on large volumes (GET /stats has the full map).
_HEAT_TOP = 16


def _request_hist(path: str, dataset: object) -> Histogram:
    """The request-latency series for one ``(path, dataset)``.

    Observed at the handler layer — not the HTTP front door — so the
    benches and the batch pool (which call handlers directly, transport
    free) populate the same p50/p99 the ``/metrics`` scrape exports."""
    return REGISTRY.histogram(
        "repro_request_seconds",
        {"path": path, "dataset": str(dataset)},
        "end-to-end handler latency by request path",
    )


def _collect_store_metrics(service: VolumeService, targets: List[str]) -> List[Metric]:
    """Scrape-time translation of live store counters into metric families.

    Nothing here double-counts: the stores already own their counters
    (`PathStats`, cache/queue aggregates, heat maps, topology), so a
    scrape reads them and renders samples — there is no second counter
    store to drift out of sync."""
    # {name: (mtype, help, [(label dict, value), ...])}
    families: Dict[str, Tuple[str, str, List[Tuple[Dict[str, object], float]]]] = {}

    def add(name: str, mtype: str, help_text: str, labels: Dict[str, object], value) -> None:
        fam = families.setdefault(name, (mtype, help_text, []))
        fam[2].append((labels, float(value)))

    for n in targets:
        store = service.datasets[n]
        for path, stats in (("read", store.read_stats), ("write", store.write_stats)):
            for field, value in dataclasses.asdict(stats).items():
                labels = {"dataset": n, "path": path}
                if field in _GAUGE_FIELDS:
                    add(f"repro_{field}", "gauge", f"PathStats gauge {field}", labels, value)
                elif field.endswith("_s"):
                    add(
                        f"repro_{field[:-2]}_seconds_total",
                        "counter",
                        f"PathStats accumulated seconds {field}",
                        labels,
                        value,
                    )
                else:
                    add(
                        f"repro_{field}_total",
                        "counter",
                        f"PathStats counter {field}",
                        labels,
                        value,
                    )
        if hasattr(store, "cache_counters"):
            for k, v in store.cache_counters().items():
                add(
                    "repro_cluster_cache_total",
                    "counter",
                    "aggregate hot-cuboid cache counters across node shards",
                    {"dataset": n, "counter": k},
                    v,
                )
            for k, v in store.queue_counters().items():
                add(
                    "repro_cluster_queue",
                    "gauge",
                    "aggregate write-behind queue counters across node shards",
                    {"dataset": n, "counter": k},
                    v,
                )
        if hasattr(store, "topology"):
            topo = store.topology()
            add("repro_nodes", "gauge", "cluster shard count", {"dataset": n}, topo["n_nodes"])
            if "replication" in topo:
                add(
                    "repro_replication",
                    "gauge",
                    "effective replication factor",
                    {"dataset": n},
                    topo["replication"],
                )
            add(
                "repro_rebalancing",
                "gauge",
                "1 while a live migration is in flight",
                {"dataset": n},
                int(bool(topo["rebalancing"])),
            )
            for i, keys in enumerate(topo["keys_per_node"]):
                add(
                    "repro_node_keys",
                    "gauge",
                    "key occupancy per node shard",
                    {"dataset": n, "node": i},
                    keys,
                )
        if hasattr(store, "node_health"):
            for h in store.node_health():
                add(
                    "repro_node_health",
                    "gauge",
                    "per-node health state (1 = the labelled state is current)",
                    {"dataset": n, "node": h["node"], "state": h["state"]},
                    1,
                )
                add(
                    "repro_node_repair_pending",
                    "gauge",
                    "write repairs queued for the node (anti-entropy backlog)",
                    {"dataset": n, "node": h["node"]},
                    h["repair_pending"],
                )
        if hasattr(store, "access_heat"):
            heat = store.access_heat(top=_HEAT_TOP)
            add(
                "repro_segment_heat_bits",
                "gauge",
                "morton shift aggregating cells into heat buckets",
                {"dataset": n},
                heat["bits"],
            )
            for direction in ("read", "write"):
                for r, bucket, count in heat[direction]:
                    add(
                        "repro_segment_heat_total",
                        "counter",
                        "per-segment access-heat touch counts (hottest buckets)",
                        {"dataset": n, "direction": direction, "resolution": r, "bucket": bucket},
                        count,
                    )
    for k, v in trace.RING.counters().items():
        add("repro_trace_ring", "gauge", "span ring occupancy counters", {"counter": k}, v)
    return [
        metric(name, mtype, help_text, samples)
        for name, (mtype, help_text, samples) in sorted(families.items())
    ]


def get_metrics(service: VolumeService, request: Request) -> Response:
    """``GET /metrics`` (or ``GET /<dataset>/metrics``) — Prometheus text.

    Histogram families (request / migration / flush latency) render from
    the process-global :data:`~repro.obs.registry.REGISTRY`; counters and
    gauges are collected from the live stores at scrape time.  The
    envelope carries ``text`` + ``content_type`` and the HTTP front door
    serves it verbatim (exposition format version 0.0.4)."""
    name = request.get("dataset")
    if name is not None and name not in service.datasets:
        return _error(404, f"unknown dataset {name!r}")
    targets = [name] if name is not None else sorted(service.datasets)
    text = REGISTRY.prometheus_text(extra=_collect_store_metrics(service, targets))
    return {
        "status": 200,
        "text": text,
        "content_type": "text/plain; version=0.0.4; charset=utf-8",
    }


def get_trace(service: VolumeService, request: Request) -> Response:
    """``GET /trace/<id>`` — the span tree of one sampled request.

    404 means the id was never sampled (send ``X-Trace-Id`` to force a
    trace) or its spans have been evicted from the ring."""
    tid = request.get("trace")
    if not tid:
        return _error(400, "missing trace id")
    tid = str(tid)
    spans = trace.trace_spans(tid)
    if not spans:
        return _error(404, f"no spans retained for trace {tid!r}")
    return {"status": 200, "trace": tid, "n_spans": len(spans), "spans": trace.trace_tree(tid)}


def get_cutout(service: VolumeService, request: Request) -> Response:
    """``GET /<dataset>/cutout/<r>/<lo>/<hi>`` — dense sub-volume read."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    with _request_hist("cutout", request.get("dataset")).time():
        try:
            r = int(request.get("resolution", 0))
            lo, hi = _box(request)
            stats = CutoutStats()
            vol = cutout(store, r, lo, hi, channel=int(request.get("channel", 0)), stats=stats)
            body = _encode_volume(vol, request, store)
        except _BAD_REQUEST as e:
            return _error(400, f"bad cutout request: {e}")
        body["cuboids_read"] = stats.cuboids_read
        body["runs"] = stats.runs
        body["zero_copy"] = bool(stats.zero_copy)  # aligned: no trim copy made
        return body


def put_cutout(service: VolumeService, request: Request) -> Response:
    """``PUT /<dataset>/cutout/<r>/<lo>`` — dense sub-volume write."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    with _request_hist("put", request.get("dataset")).time():
        try:
            r = int(request.get("resolution", 0))
            lo = [int(x) for x in request["lo"]]
            data = _decode_volume(request)
            write_cutout(
                store,
                r,
                lo,
                data,
                channel=int(request.get("channel", 0)),
                discipline=request.get("discipline", "overwrite"),
            )
        except _BAD_REQUEST as e:
            return _error(400, f"bad write request: {e}")
        body: Response = {"status": 200, "written_shape": tuple(data.shape)}
        if request.get("sync") and hasattr(store, "flush"):
            body["flushed"] = store.flush()  # durability barrier before reply
        return body


def get_projection(service: VolumeService, request: Request) -> Response:
    """``GET /<dataset>/xy/...`` — tile/MIP: a cutout with one axis reduced."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    with _request_hist("projection", request.get("dataset")).time():
        try:
            r = int(request.get("resolution", 0))
            lo, hi = _box(request)
            tile = project(
                store,
                r,
                lo,
                hi,
                axis=int(request.get("axis", 2)),
                reduce=request.get("reduce", "slice"),
                channel=int(request.get("channel", 0)),
            )
            return _encode_volume(tile, request, store)
        except _BAD_REQUEST as e:
            return _error(400, f"bad projection request: {e}")


def get_annotation_bbox(service: VolumeService, request: Request) -> Response:
    """``GET /objects/<id>/boundingbox`` — index-only, no voxel I/O."""
    proj = service.projects.get(request.get("project"))
    if proj is None:
        return _error(404, f"unknown project {request.get('project')!r}")
    try:
        ann_id = int(request["id"])
        r = int(request.get("resolution", 0))
    except _BAD_REQUEST as e:
        return _error(400, f"bad boundingbox request: {e}")
    bbox = proj.bounding_box(ann_id, r)
    if bbox is None:
        return _error(404, f"object {ann_id} has no voxels")
    lo, hi = bbox
    return {"status": 200, "id": ann_id, "lo": list(lo), "hi": list(hi)}


def get_object_cutout(service: VolumeService, request: Request) -> Response:
    """``GET /objects/<id>/cutout`` — one object's voxels, others masked."""
    proj = service.projects.get(request.get("project"))
    if proj is None:
        return _error(404, f"unknown project {request.get('project')!r}")
    try:
        ann_id = int(request["id"])
        r = int(request.get("resolution", 0))
        box = None
        if "lo" in request and "hi" in request:
            box = _box(request)
        lo, vol = proj.object_cutout(ann_id, r, box)
        body = _encode_volume(vol, request)
    except _BAD_REQUEST as e:
        return _error(400, f"bad object cutout request: {e}")
    body["id"] = ann_id
    body["lo"] = list(lo)
    return body


def post_flush(service: VolumeService, request: Request) -> Response:
    """``POST /flush`` — durability barrier for the write-behind tier.

    Drains the named dataset's pending writes (or every dataset's when no
    ``dataset`` key is given) into the node backends before responding.
    Stores without a write-behind queue flush trivially (0 drained).
    """
    name = request.get("dataset")
    if name is not None and name not in service.datasets:
        return _error(404, f"unknown dataset {name!r}")
    targets = [name] if name is not None else list(service.datasets)
    flushed = {}
    for n in targets:
        store = service.datasets[n]
        flushed[n] = int(store.flush()) if hasattr(store, "flush") else 0
    return {"status": 200, "flushed": flushed, "total": sum(flushed.values())}


def post_compact(service: VolumeService, request: Request) -> Response:
    """``POST /compact`` — merge flushed log segments into the read tier.

    Targets the named dataset (or every dataset without a ``dataset``
    key).  Each store compacts every node shard whose write tier is an
    append log; stores without one compact trivially (all-zero stats).
    ``{"max_segments": n}`` bounds the work per node — the trickle shape
    the background compactor uses, versus this verb's default full drain.
    """
    name = request.get("dataset")
    if name is not None and name not in service.datasets:
        return _error(404, f"unknown dataset {name!r}")
    targets = [name] if name is not None else list(service.datasets)
    try:
        max_segments = request.get("max_segments")
        max_segments = None if max_segments is None else int(max_segments)
    except (TypeError, ValueError):
        return _error(400, f"bad max_segments {request.get('max_segments')!r}")
    compacted = {}
    for n in targets:
        store = service.datasets[n]
        if not hasattr(store, "compact"):
            compacted[n] = {"segments": 0, "keys": 0, "tombstones": 0, "bytes": 0, "seconds": 0.0}
            continue
        stats = store.compact(max_segments)
        compacted[n] = stats if isinstance(stats, dict) else stats.asdict()
    return {
        "status": 200,
        "compacted": compacted,
        "total_keys": sum(int(c["keys"]) for c in compacted.values()),
    }


def get_stats(service: VolumeService, request: Request) -> Response:
    """``GET /stats`` — path/cache/queue counters for one dataset.

    Returns the read/write `PathStats` (including cache hit/miss,
    queue-depth gauges, and the cold-read pipeline's decode/prefetch
    counters) plus, for cluster stores, the aggregate cache and
    write-behind queue counters, the effective `DecodePolicy` knobs, the
    per-node `PathStats` breakdown (``nodes``), the effective replication
    factor, and the per-resolution partition boundaries.
    """
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    body: Response = {
        "status": 200,
        "read": dataclasses.asdict(store.read_stats),
        "write": dataclasses.asdict(store.write_stats),
    }
    if hasattr(store, "cache_counters"):
        body["cache"] = store.cache_counters()
        body["queue"] = store.queue_counters()
    if hasattr(store, "nodes") and hasattr(store, "router"):
        # The aggregate above hides skew; the per-node breakdown is what a
        # deployment reads to spot a hot shard (then POST /rebalance).
        body["nodes"] = [
            {
                "read": dataclasses.asdict(node.read_stats),
                "write": dataclasses.asdict(node.write_stats),
            }
            for node in store.nodes
        ]
        router = store.router
        body["replication"] = router.n_replicas
        body["partitions"] = {
            r: [int(b) for b in router.partition(r).bounds]
            for r in range(store.spec.n_resolutions)
        }
    if hasattr(store, "access_heat"):
        body["heat"] = store.access_heat(top=_HEAT_TOP)
    if hasattr(store, "node_health"):
        # The health machine's live view: per-node state, consecutive
        # error count, and anti-entropy repair backlog.
        body["health"] = store.node_health()
    # Storage-tier gauges: cluster aggregate when available, else the
    # single store's own tier report (log segment/index sizes, lifetime
    # compaction totals) — the signal the supervisor's compaction trigger
    # and a capacity dashboard read.
    if hasattr(store, "tier_counters"):
        body["tiers"] = store.tier_counters()
    elif hasattr(store, "tier_stats"):
        body["tiers"] = store.tier_stats()
    pol = getattr(store, "decode_policy", None)
    if pol is None and hasattr(store, "nodes"):  # cluster on node defaults
        nodes = store.nodes
        pol = nodes[0].decode_policy if nodes else None
    if pol is not None:
        body["decode"] = {
            "workers": pol.workers,
            "chunk": pol.chunk,
            "prefetch_segments": pol.prefetch_segments,
        }
    return body


def get_topology(service: VolumeService, request: Request) -> Response:
    """``GET /topology`` — the dataset's cluster layout (paper §6).

    For an elastic `ClusterStore`: node count, per-resolution curve
    segments, per-node key occupancy (the rebalance signal), and whether a
    migration is in flight.  Single-node stores report a degenerate
    one-node topology with ``elastic: false``.
    """
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    if hasattr(store, "topology"):
        return {"status": 200, **store.topology()}
    # key_count (not stored_keys) so a monitoring poll never drains the
    # write-behind queue it is observing
    occupancy = (store.key_count() if hasattr(store, "key_count")
                 else len(store.stored_keys()))
    return {
        "status": 200,
        "n_nodes": 1,
        "elastic": False,
        "rebalancing": False,
        "keys_per_node": [occupancy],
    }


def post_rebalance(service: VolumeService, request: Request) -> Response:
    """``POST /rebalance`` — re-partition by occupancy, migrating live.

    ``{"target": n}`` grows/shrinks the cluster to ``n`` nodes; without a
    target, boundaries move but the node count stays.  Reads and writes
    issued concurrently through the service stay bit-identical during the
    move.  Responds with the migration stats and the resulting topology.
    """
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    if not hasattr(store, "rebalance"):
        return _error(400, "dataset is not elastic (single-node store)")
    try:
        target = request.get("target")
        stats = store.rebalance(target=None if target is None else int(target), wait=False)
    except RebalanceInFlight as e:
        return _error(409, str(e))
    except _BAD_REQUEST as e:
        return _error(400, f"bad rebalance request: {e}")
    return {"status": 200, **stats, "topology": store.topology()}


def post_batch_cutout(service: VolumeService, request: Request) -> Response:
    """``POST /batch/cutout`` — many boxes in one request (paper §4.2's
    batch interface on the wire).

    ``{"boxes": [[lo, hi], ...]}`` at one resolution/channel; boxes
    overlap on the cluster's request-level pool.  The response carries one
    result envelope per box, in request order, each shaped exactly like a
    ``GET /cutout`` body (``encode``/``level`` negotiate zlib per the
    whole batch)."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    with _request_hist("batch", request.get("dataset")).time():
        try:
            r = int(request.get("resolution", 0))
            channel = int(request.get("channel", 0))
            boxes = []
            for box in request["boxes"]:
                lo, hi = box
                boxes.append(([int(x) for x in lo], [int(x) for x in hi]))
            if not boxes:
                raise ValueError("empty boxes list")
            vols = batch_cutout(store, r, boxes, channel)
            results = [_encode_volume(vol, request, store) for vol in vols]
        except _BAD_REQUEST as e:
            return _error(400, f"bad batch cutout request: {e}")
        return {"status": 200, "n": len(results), "results": results}


def post_add_node(service: VolumeService, request: Request) -> Response:
    """``POST /nodes`` — grow the cluster by one shard (keys migrate onto
    it immediately unless ``{"rebalance": false}``)."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    if not hasattr(store, "add_node"):
        return _error(400, "dataset is not elastic (single-node store)")
    try:
        index = store.add_node(rebalance=bool(request.get("rebalance", True)), wait=False)
    except RebalanceInFlight as e:
        return _error(409, str(e))
    except _BAD_REQUEST as e:
        return _error(400, f"bad add-node request: {e}")
    return {"status": 200, "node": index, "topology": store.topology()}


def post_remove_node(service: VolumeService, request: Request) -> Response:
    """``DELETE /<dataset>/nodes/<i>`` — decommission a live shard.

    Its ranges are promoted onto surviving replicas (replicated cluster)
    or streamed off first (replication 1); zero keys are lost either
    way."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    if not hasattr(store, "remove_node"):
        return _error(400, "dataset is not elastic (single-node store)")
    try:
        stats = store.remove_node(int(request["node"]), wait=False)
    except RebalanceInFlight as e:
        return _error(409, str(e))
    except _BAD_REQUEST as e:
        return _error(400, f"bad remove-node request: {e}")
    return {"status": 200, **stats, "topology": store.topology()}


HANDLERS: Dict[str, Callable[[VolumeService, Request], Response]] = {
    "GET /cutout": get_cutout,
    "PUT /cutout": put_cutout,
    "GET /projection": get_projection,
    "GET /objects/boundingbox": get_annotation_bbox,
    "GET /objects/cutout": get_object_cutout,
    "POST /batch/cutout": post_batch_cutout,
    "POST /flush": post_flush,
    "POST /compact": post_compact,
    "GET /stats": get_stats,
    "GET /metrics": get_metrics,
    "GET /trace": get_trace,
    "GET /topology": get_topology,
    "POST /rebalance": post_rebalance,
    "POST /nodes/add": post_add_node,
    "POST /nodes/remove": post_remove_node,
}


def dispatch(service: VolumeService, request: Request, verb: Optional[str] = None) -> Response:
    """Route one request dict by its ``verb`` key.

    .. deprecated::
        This flat verb-string table predates the URL router; new callers
        should parse paper-style paths with :func:`repro.cluster.api.url_dispatch`
        (which resolves to these same handlers).  Kept as a thin shim so
        existing request-dict callers keep working unchanged.
    """
    warnings.warn(
        "dispatch() is deprecated; route paper-style URL paths with "
        "repro.cluster.api.url_dispatch (same handlers, same envelopes)",
        DeprecationWarning,
        stacklevel=2,
    )
    verb = verb or request.get("verb")
    handler = HANDLERS.get(verb)
    if handler is None:
        return _error(405, f"unknown verb {verb!r}")
    return handler(service, request)
