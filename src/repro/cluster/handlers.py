"""RESTful-style data-service verbs over the cluster (paper §4.2).

The paper's Web services expose cutout / annotation queries as stateless
HTTP verbs; any front-end can serve any request because state lives in the
data cluster.  We reproduce that contract transport-free: every handler is
a pure ``(service, request dict) -> response dict`` function — no sockets,
no framework — so it composes with `repro.serve` (or any HTTP shim) and is
trivially testable.  Verb strings mirror the paper's URL forms
(``GET /cutout``, ``objects/.../boundingbox``, ...).

Requests name a dataset or annotation project by key; volumes travel as
numpy arrays by default or zlib blobs with ``{"encode": "zlib"}`` (the
paper returns compressed volumes on the wire).  Responses always carry an
integer ``status`` using HTTP conventions.

Consistency contract (the cache tier + write-behind queue, paper §6):

* ``PUT /cutout`` returning 200 guarantees **read-your-writes**: every
  subsequent ``GET /cutout`` through the service sees the write, even
  while it is still pending in a node's write-behind queue.  Pass
  ``{"sync": true}`` to additionally force durability before the response.
* ``POST /flush`` is the explicit **durability barrier**: when it returns,
  every previously accepted write has been applied to the node backends.
* ``GET /stats`` exposes the path/cache/queue counters (hits, misses,
  queue depth) a deployment monitors to size the tiers.

Elasticity contract (paper §6 "dynamically redistribute data"):

* ``GET /topology`` reports the cluster layout — node count, the explicit
  per-resolution curve partitions, and per-node key occupancy.
* ``POST /rebalance`` re-cuts the partitions by occupancy (optionally
  growing/shrinking to ``target`` nodes) and migrates keys *live*:
  cutout reads and writes issued concurrently through the service remain
  bit-identical before, during, and after the move.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable, Dict, Optional

import numpy as np

from ..core.annotations import AnnotationProject
from ..core.cutout import CutoutStats, batch_cutout, cutout, project, write_cutout
from .store import RebalanceInFlight

Request = Dict[str, Any]
Response = Dict[str, Any]

# Malformed client input must come back as a 4xx dict, never an exception:
# missing keys, bad ints/shapes, corrupt zlib payloads, non-iterable boxes.
_BAD_REQUEST = (KeyError, ValueError, IndexError, TypeError, zlib.error)


class VolumeService:
    """Registry of datasets and annotation projects behind the verbs.

    The service itself is stateless routing glue: all durable state lives
    in the registered stores (single-node `CuboidStore` or sharded
    `ClusterStore` — the verbs do not care which, that is C3).
    """

    def __init__(self):
        self.datasets: Dict[str, Any] = {}
        self.projects: Dict[str, AnnotationProject] = {}

    def add_dataset(self, name: str, store) -> None:
        self.datasets[name] = store

    def add_project(self, name: str, proj: AnnotationProject) -> None:
        self.projects[name] = proj


def _error(status: int, message: str) -> Response:
    """The uniform error envelope: ``{"status": 4xx/5xx, "error": msg}``.

    Every handler returns ``{"status": 200, ...}`` on success and this
    shape otherwise — 404 unknown dataset/project/object, 400 malformed
    request, 409 topology change in flight, 405 unknown verb (503 is the
    transport layer's: the HTTP front door sheds with it when the
    admission limit is exceeded)."""
    return {"status": status, "error": message}


def _zlib_level(request: Request, store=None) -> int:
    """Negotiated zlib level: explicit request ``level`` wins, else the
    dataset's ``DatasetSpec.compress_level``, else 1 (wire default)."""
    level = request.get("level")
    if level is None:
        spec = getattr(store, "spec", None)
        level = getattr(spec, "compress_level", 1)
    level = int(level)
    if not (0 <= level <= 9):
        raise ValueError(f"zlib level {level} outside [0, 9]")
    return level


def _encode_volume(vol: np.ndarray, request: Request, store=None) -> Response:
    body: Response = {"status": 200, "shape": tuple(vol.shape), "dtype": str(vol.dtype)}
    if request.get("encode") == "zlib":
        level = _zlib_level(request, store)
        body["data"] = zlib.compress(np.ascontiguousarray(vol).tobytes(), level)
        body["encode"] = "zlib"
        body["level"] = level
    else:
        body["data"] = vol
    return body


def _decode_volume(request: Request) -> np.ndarray:
    data = request["data"]
    if request.get("encode") == "zlib":
        raw = zlib.decompress(data)
        vol = np.frombuffer(raw, dtype=np.dtype(request["dtype"])).reshape(request["shape"])
        # frombuffer over bytes yields a read-only view; write paths that
        # normalize/pad the block in place would raise "assignment
        # destination is read-only", so hand over a writable copy.
        return vol.copy()
    return np.asarray(data)


def _box(request: Request):
    lo = [int(x) for x in request["lo"]]
    hi = [int(x) for x in request["hi"]]
    return lo, hi


def get_cutout(service: VolumeService, request: Request) -> Response:
    """``GET /<dataset>/cutout/<r>/<lo>/<hi>`` — dense sub-volume read."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    try:
        r = int(request.get("resolution", 0))
        lo, hi = _box(request)
        stats = CutoutStats()
        vol = cutout(store, r, lo, hi, channel=int(request.get("channel", 0)), stats=stats)
        body = _encode_volume(vol, request, store)
    except _BAD_REQUEST as e:
        return _error(400, f"bad cutout request: {e}")
    body["cuboids_read"] = stats.cuboids_read
    body["runs"] = stats.runs
    body["zero_copy"] = bool(stats.zero_copy)  # aligned: no trim copy made
    return body


def put_cutout(service: VolumeService, request: Request) -> Response:
    """``PUT /<dataset>/cutout/<r>/<lo>`` — dense sub-volume write."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    try:
        r = int(request.get("resolution", 0))
        lo = [int(x) for x in request["lo"]]
        data = _decode_volume(request)
        write_cutout(
            store,
            r,
            lo,
            data,
            channel=int(request.get("channel", 0)),
            discipline=request.get("discipline", "overwrite"),
        )
    except _BAD_REQUEST as e:
        return _error(400, f"bad write request: {e}")
    body: Response = {"status": 200, "written_shape": tuple(data.shape)}
    if request.get("sync") and hasattr(store, "flush"):
        body["flushed"] = store.flush()  # durability barrier before reply
    return body


def get_projection(service: VolumeService, request: Request) -> Response:
    """``GET /<dataset>/xy/...`` — tile/MIP: a cutout with one axis reduced."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    try:
        r = int(request.get("resolution", 0))
        lo, hi = _box(request)
        tile = project(
            store,
            r,
            lo,
            hi,
            axis=int(request.get("axis", 2)),
            reduce=request.get("reduce", "slice"),
            channel=int(request.get("channel", 0)),
        )
        return _encode_volume(tile, request, store)
    except _BAD_REQUEST as e:
        return _error(400, f"bad projection request: {e}")


def get_annotation_bbox(service: VolumeService, request: Request) -> Response:
    """``GET /objects/<id>/boundingbox`` — index-only, no voxel I/O."""
    proj = service.projects.get(request.get("project"))
    if proj is None:
        return _error(404, f"unknown project {request.get('project')!r}")
    try:
        ann_id = int(request["id"])
        r = int(request.get("resolution", 0))
    except _BAD_REQUEST as e:
        return _error(400, f"bad boundingbox request: {e}")
    bbox = proj.bounding_box(ann_id, r)
    if bbox is None:
        return _error(404, f"object {ann_id} has no voxels")
    lo, hi = bbox
    return {"status": 200, "id": ann_id, "lo": list(lo), "hi": list(hi)}


def get_object_cutout(service: VolumeService, request: Request) -> Response:
    """``GET /objects/<id>/cutout`` — one object's voxels, others masked."""
    proj = service.projects.get(request.get("project"))
    if proj is None:
        return _error(404, f"unknown project {request.get('project')!r}")
    try:
        ann_id = int(request["id"])
        r = int(request.get("resolution", 0))
        box = None
        if "lo" in request and "hi" in request:
            box = _box(request)
        lo, vol = proj.object_cutout(ann_id, r, box)
        body = _encode_volume(vol, request)
    except _BAD_REQUEST as e:
        return _error(400, f"bad object cutout request: {e}")
    body["id"] = ann_id
    body["lo"] = list(lo)
    return body


def post_flush(service: VolumeService, request: Request) -> Response:
    """``POST /flush`` — durability barrier for the write-behind tier.

    Drains the named dataset's pending writes (or every dataset's when no
    ``dataset`` key is given) into the node backends before responding.
    Stores without a write-behind queue flush trivially (0 drained).
    """
    name = request.get("dataset")
    if name is not None and name not in service.datasets:
        return _error(404, f"unknown dataset {name!r}")
    targets = [name] if name is not None else list(service.datasets)
    flushed = {}
    for n in targets:
        store = service.datasets[n]
        flushed[n] = int(store.flush()) if hasattr(store, "flush") else 0
    return {"status": 200, "flushed": flushed, "total": sum(flushed.values())}


def get_stats(service: VolumeService, request: Request) -> Response:
    """``GET /stats`` — path/cache/queue counters for one dataset.

    Returns the read/write `PathStats` (including cache hit/miss,
    queue-depth gauges, and the cold-read pipeline's decode/prefetch
    counters) plus, for cluster stores, the aggregate cache and
    write-behind queue counters, and the effective `DecodePolicy` knobs.
    """
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    body: Response = {
        "status": 200,
        "read": dataclasses.asdict(store.read_stats),
        "write": dataclasses.asdict(store.write_stats),
    }
    if hasattr(store, "cache_counters"):
        body["cache"] = store.cache_counters()
        body["queue"] = store.queue_counters()
    pol = getattr(store, "decode_policy", None)
    if pol is None and hasattr(store, "nodes"):  # cluster on node defaults
        nodes = store.nodes
        pol = nodes[0].decode_policy if nodes else None
    if pol is not None:
        body["decode"] = {
            "workers": pol.workers,
            "chunk": pol.chunk,
            "prefetch_segments": pol.prefetch_segments,
        }
    return body


def get_topology(service: VolumeService, request: Request) -> Response:
    """``GET /topology`` — the dataset's cluster layout (paper §6).

    For an elastic `ClusterStore`: node count, per-resolution curve
    segments, per-node key occupancy (the rebalance signal), and whether a
    migration is in flight.  Single-node stores report a degenerate
    one-node topology with ``elastic: false``.
    """
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    if hasattr(store, "topology"):
        return {"status": 200, **store.topology()}
    # key_count (not stored_keys) so a monitoring poll never drains the
    # write-behind queue it is observing
    occupancy = (store.key_count() if hasattr(store, "key_count")
                 else len(store.stored_keys()))
    return {
        "status": 200,
        "n_nodes": 1,
        "elastic": False,
        "rebalancing": False,
        "keys_per_node": [occupancy],
    }


def post_rebalance(service: VolumeService, request: Request) -> Response:
    """``POST /rebalance`` — re-partition by occupancy, migrating live.

    ``{"target": n}`` grows/shrinks the cluster to ``n`` nodes; without a
    target, boundaries move but the node count stays.  Reads and writes
    issued concurrently through the service stay bit-identical during the
    move.  Responds with the migration stats and the resulting topology.
    """
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    if not hasattr(store, "rebalance"):
        return _error(400, "dataset is not elastic (single-node store)")
    try:
        target = request.get("target")
        stats = store.rebalance(target=None if target is None else int(target), wait=False)
    except RebalanceInFlight as e:
        return _error(409, str(e))
    except _BAD_REQUEST as e:
        return _error(400, f"bad rebalance request: {e}")
    return {"status": 200, **stats, "topology": store.topology()}


def post_batch_cutout(service: VolumeService, request: Request) -> Response:
    """``POST /batch/cutout`` — many boxes in one request (paper §4.2's
    batch interface on the wire).

    ``{"boxes": [[lo, hi], ...]}`` at one resolution/channel; boxes
    overlap on the cluster's request-level pool.  The response carries one
    result envelope per box, in request order, each shaped exactly like a
    ``GET /cutout`` body (``encode``/``level`` negotiate zlib per the
    whole batch)."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    try:
        r = int(request.get("resolution", 0))
        channel = int(request.get("channel", 0))
        boxes = []
        for box in request["boxes"]:
            lo, hi = box
            boxes.append(([int(x) for x in lo], [int(x) for x in hi]))
        if not boxes:
            raise ValueError("empty boxes list")
        vols = batch_cutout(store, r, boxes, channel)
        results = [_encode_volume(vol, request, store) for vol in vols]
    except _BAD_REQUEST as e:
        return _error(400, f"bad batch cutout request: {e}")
    return {"status": 200, "n": len(results), "results": results}


def post_add_node(service: VolumeService, request: Request) -> Response:
    """``POST /nodes`` — grow the cluster by one shard (keys migrate onto
    it immediately unless ``{"rebalance": false}``)."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    if not hasattr(store, "add_node"):
        return _error(400, "dataset is not elastic (single-node store)")
    try:
        index = store.add_node(rebalance=bool(request.get("rebalance", True)), wait=False)
    except RebalanceInFlight as e:
        return _error(409, str(e))
    except _BAD_REQUEST as e:
        return _error(400, f"bad add-node request: {e}")
    return {"status": 200, "node": index, "topology": store.topology()}


def post_remove_node(service: VolumeService, request: Request) -> Response:
    """``DELETE /<dataset>/nodes/<i>`` — decommission a live shard.

    Its ranges are promoted onto surviving replicas (replicated cluster)
    or streamed off first (replication 1); zero keys are lost either
    way."""
    store = service.datasets.get(request.get("dataset"))
    if store is None:
        return _error(404, f"unknown dataset {request.get('dataset')!r}")
    if not hasattr(store, "remove_node"):
        return _error(400, "dataset is not elastic (single-node store)")
    try:
        stats = store.remove_node(int(request["node"]), wait=False)
    except RebalanceInFlight as e:
        return _error(409, str(e))
    except _BAD_REQUEST as e:
        return _error(400, f"bad remove-node request: {e}")
    return {"status": 200, **stats, "topology": store.topology()}


HANDLERS: Dict[str, Callable[[VolumeService, Request], Response]] = {
    "GET /cutout": get_cutout,
    "PUT /cutout": put_cutout,
    "GET /projection": get_projection,
    "GET /objects/boundingbox": get_annotation_bbox,
    "GET /objects/cutout": get_object_cutout,
    "POST /batch/cutout": post_batch_cutout,
    "POST /flush": post_flush,
    "GET /stats": get_stats,
    "GET /topology": get_topology,
    "POST /rebalance": post_rebalance,
    "POST /nodes/add": post_add_node,
    "POST /nodes/remove": post_remove_node,
}


def dispatch(service: VolumeService, request: Request, verb: Optional[str] = None) -> Response:
    """Route one request dict by its ``verb`` key.

    .. deprecated::
        This flat verb-string table predates the URL router; new callers
        should parse paper-style paths with :func:`repro.cluster.api.url_dispatch`
        (which resolves to these same handlers).  Kept as a thin shim so
        existing request-dict callers keep working unchanged.
    """
    verb = verb or request.get("verb")
    handler = HANDLERS.get(verb)
    if handler is None:
        return _error(405, f"unknown verb {verb!r}")
    return handler(service, request)
