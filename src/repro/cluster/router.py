"""Stateless request routing over a Morton-curve partition (paper §4.1 C3).

The paper shards a dataset across database nodes by partitioning the Morton
curve into contiguous segments; any front-end web server can route any
request because ownership is a pure function of (dataset spec, partition,
morton index) — no routing table, no directory service.  :class:`Router` is
that pure function made explicit: it owns no sockets and no state, so a
`ClusterStore` holds one and so could a fleet of stateless web front-ends.

Partitioning is per resolution level (each level has its own curve length);
every node therefore owns a spatially compact region at *every* level, and
runs within one node stay sequential (paper: reads on a node are few long
sequential I/Os even after sharding).

Ownership is evaluated against an explicit per-resolution
:class:`repro.core.morton.Partition` (a curve boundary list), so boundaries
can *move*: rebalancing builds a new Router with shifted bounds and swaps
it in atomically (paper §6 "dynamically redistribute data").  Resolutions
without an explicit partition fall back to the even `partition_curve`
split, which is what a freshly-built cluster uses everywhere.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Tuple

import numpy as np

from ..core import morton
from ..core.cuboid import DatasetSpec

Runs = morton.Runs
Partition = morton.Partition


@dataclasses.dataclass(frozen=True)
class Router:
    """Pure ownership function for a curve-partitioned dataset.

    ``partitions`` maps resolution -> explicit :class:`Partition` override;
    missing resolutions use the even split over ``n_nodes``.  Routers are
    immutable — rebalancing derives a new one via :meth:`with_partitions`
    and publishes it atomically, so every request evaluates one consistent
    boundary set end to end.

    ``replication`` is the requested copies-per-segment: segment ``i`` is
    held by the replica set ``(i, i+1, ..., i+R-1) mod n_nodes`` (a
    successor ring over the node indices, capped at ``n_nodes``).  The
    first member is the *primary* — the segment's partition owner — and
    write fan-out targets every member while reads may pick any.  Like
    ownership itself, the replica set is a pure function of the router, so
    any stateless front-end resolves it identically.
    """

    spec: DatasetSpec
    n_nodes: int
    partitions: Mapping[int, Partition] = dataclasses.field(default_factory=dict)
    replication: int = 1

    def __post_init__(self):
        if self.n_nodes <= 0:
            raise ValueError("n_nodes must be positive")
        if self.replication <= 0:
            raise ValueError("replication must be positive")
        for r, part in self.partitions.items():
            if part.n_parts != self.n_nodes:
                raise ValueError(
                    f"partition at r={r} has {part.n_parts} parts, expected {self.n_nodes}"
                )

    def n_cells(self, r: int) -> int:
        return self.spec.grid(r).n_cells

    @property
    def n_replicas(self) -> int:
        """Effective copies per segment (capped: a 2-node cluster can hold
        at most 2 distinct copies however large the requested factor)."""
        return min(self.replication, self.n_nodes)

    def replicas_of(self, primary: int) -> Tuple[int, ...]:
        """The replica set of the segment owned by ``primary``: the
        successor ring starting at the primary."""
        return tuple((primary + k) % self.n_nodes for k in range(self.n_replicas))

    def replica_set(self, r: int, m: int) -> Tuple[int, ...]:
        """Every node holding morton index ``m`` (primary first)."""
        return self.replicas_of(self.owner(r, m))

    def partition(self, r: int) -> Partition:
        """The explicit curve partition at resolution ``r``."""
        part = self.partitions.get(r)
        if part is not None:
            return part
        return Partition.even(self.n_cells(r), self.n_nodes)

    def with_partitions(
        self, partitions: Mapping[int, Partition], n_nodes: int | None = None
    ) -> "Router":
        """A new Router with updated boundaries (rebalance publishes this)."""
        merged = dict(self.partitions)
        merged.update(partitions)
        return Router(
            self.spec,
            self.n_nodes if n_nodes is None else n_nodes,
            merged,
            self.replication,
        )

    def segments(self, r: int) -> List[Tuple[int, int]]:
        """The curve partition at resolution ``r``: node i owns segment i."""
        return self.partition(r).segments()

    def owner(self, r: int, m: int) -> int:
        """Owning node of one morton index."""
        return int(self.partition(r).owner(m))

    def owners(self, r: int, cells) -> np.ndarray:
        """Vectorized owner lookup for an array of morton indexes."""
        return self.partition(r).owner(np.asarray(cells, dtype=np.int64))

    def split_run(self, r: int, start: int, stop: int) -> List[Tuple[int, int, int]]:
        """Split one curve run at partition boundaries.

        Returns [(node, start, stop), ...] in curve order — each piece is
        non-empty and wholly owned by one node, so node-local I/O stays
        sequential.  Empty segments (a node owning nothing at this
        resolution) are skipped.
        """
        return self.partition(r).split(start, stop)

    def split_runs(self, r: int, runs: Runs) -> Dict[int, Runs]:
        """Group a run schedule by owning node: {node: runs on that node}."""
        part = self.partition(r)
        by_node: Dict[int, Runs] = {}
        for start, stop in runs:
            for node, a, b in part.split(start, stop):
                by_node.setdefault(node, []).append((a, b))
        return by_node

    def split_run_replicas(
        self, r: int, start: int, stop: int
    ) -> List[Tuple[Tuple[int, ...], int, int]]:
        """Like :meth:`split_run`, but each piece carries its full replica
        set: [(members, start, stop), ...] in curve order.  A replicated
        read picks any one member per piece; pieces stay whole so
        node-local I/O stays sequential whichever member serves them."""
        return [
            (self.replicas_of(node), a, b) for node, a, b in self.partition(r).split(start, stop)
        ]

    def group_cells(self, r: int, cells) -> Dict[int, np.ndarray]:
        """Group loose morton indexes by owning node (write routing)."""
        cells = np.asarray(cells, dtype=np.int64)
        owners = self.owners(r, cells)
        return {int(n): cells[owners == n] for n in np.unique(owners)}
