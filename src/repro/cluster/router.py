"""Stateless request routing over a Morton-curve partition (paper §4.1 C3).

The paper shards a dataset across database nodes by partitioning the Morton
curve into contiguous segments; any front-end web server can route any
request because ownership is a pure function of (dataset spec, node count,
morton index) — no routing table, no directory service.  :class:`Router` is
that pure function made explicit: it owns no sockets and no state, so a
`ClusterStore` holds one and so could a fleet of stateless web front-ends.

Partitioning is per resolution level (each level has its own curve length);
every node therefore owns a spatially compact region at *every* level, and
runs within one node stay sequential (paper: reads on a node are few long
sequential I/Os even after sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from ..core import morton
from ..core.cuboid import DatasetSpec

Runs = morton.Runs


@dataclasses.dataclass(frozen=True)
class Router:
    """Pure ownership function for a curve-partitioned dataset."""

    spec: DatasetSpec
    n_nodes: int

    def __post_init__(self):
        if self.n_nodes <= 0:
            raise ValueError("n_nodes must be positive")

    def n_cells(self, r: int) -> int:
        return self.spec.grid(r).n_cells

    def segments(self, r: int) -> List[Tuple[int, int]]:
        """The curve partition at resolution ``r``: node i owns segment i."""
        return morton.partition_curve(self.n_cells(r), self.n_nodes)

    def owner(self, r: int, m: int) -> int:
        """Owning node of one morton index."""
        return int(morton.owner_of(m, self.n_cells(r), self.n_nodes))

    def owners(self, r: int, cells) -> np.ndarray:
        """Vectorized owner lookup for an array of morton indexes."""
        cells = np.asarray(cells, dtype=np.int64)
        return morton.owner_of(cells, self.n_cells(r), self.n_nodes)

    def split_run(self, r: int, start: int, stop: int) -> List[Tuple[int, int, int]]:
        """Split one curve run at partition boundaries.

        Returns [(node, start, stop), ...] in curve order — each piece is
        wholly owned by one node, so node-local I/O stays sequential.
        """
        pieces = []
        segments = self.segments(r)
        node = self.owner(r, start)
        while start < stop:
            piece_stop = min(stop, segments[node][1])
            pieces.append((node, start, piece_stop))
            start = piece_stop
            node += 1
        return pieces

    def split_runs(self, r: int, runs: Runs) -> Dict[int, Runs]:
        """Group a run schedule by owning node: {node: runs on that node}."""
        by_node: Dict[int, Runs] = {}
        for start, stop in runs:
            for node, a, b in self.split_run(r, start, stop):
                by_node.setdefault(node, []).append((a, b))
        return by_node

    def group_cells(self, r: int, cells) -> Dict[int, np.ndarray]:
        """Group loose morton indexes by owning node (write routing)."""
        cells = np.asarray(cells, dtype=np.int64)
        owners = self.owners(r, cells)
        return {int(n): cells[owners == n] for n in np.unique(owners)}
