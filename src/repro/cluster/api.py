"""URL-routed v1 API: the paper's RESTful paths over the verb handlers.

The paper's Web services address data by *path* —

    /<dataset>/cutout/<r>/<x0>,<x1>/<y0>,<y1>/<z0>,<z1>
    /<project>/objects/<id>/boundingbox

— while `repro.cluster.handlers` speaks flat verb strings over request
dicts.  This module is the translation layer, still transport-free: it
parses a ``(method, path)`` pair into ``(verb, params)`` and
:func:`url_dispatch` merges the params into the request dict and routes
through the same ``HANDLERS`` table, so an HTTP shim needs no routing
logic of its own and the old verb-dict :func:`~.handlers.dispatch` shim
and this router can never disagree about behaviour.

Routes (``[/v1]`` prefix optional everywhere; ``<box>`` is one
``<lo>,<hi>`` path segment per axis):

====== ============================================== ======================
method path                                           verb
====== ============================================== ======================
GET    /<dataset>/cutout/<r>/<box...>                 GET /cutout
PUT    /<dataset>/cutout/<r>/<box...>                 PUT /cutout
GET    /<dataset>/(xy|xz|yz)/<r>/<box...>             GET /projection
GET    /<project>/objects/<id>/boundingbox[/<r>]      GET /objects/boundingbox
GET    /<project>/objects/<id>/cutout[/<r>[/<box...>]] GET /objects/cutout
POST   /<dataset>/batch/cutout                        POST /batch/cutout
POST   /<dataset>/flush  (or bare /flush)             POST /flush
POST   /<dataset>/compact  (or bare /compact)         POST /compact
GET    /<dataset>/stats                               GET /stats
GET    /<dataset>/metrics  (or bare /metrics)         GET /metrics
GET    /trace/<id>                                    GET /trace
GET    /<dataset>/topology                            GET /topology
POST   /<dataset>/rebalance                           POST /rebalance
POST   /<dataset>/nodes                               POST /nodes/add
DELETE /<dataset>/nodes/<i>                           POST /nodes/remove
====== ============================================== ======================

Errors follow the uniform envelope: 404 for an unroutable path (or an
unknown dataset, from the handler), 400 for a malformed resolution/box,
405 for a known resource with the wrong method.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from .handlers import HANDLERS, Request, Response, VolumeService, _error

# Planes are named from the paper's tile service; the projected axis is
# the one *missing* from the plane name (axes ordered x=0, y=1, z=2).
_PLANE_AXIS = {"xy": 2, "xz": 1, "yz": 0}


class ApiError(Exception):
    """A path that cannot be routed; carries the envelope status."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


def _int(text: str, what: str) -> int:
    try:
        return int(text)
    except ValueError:
        raise ApiError(400, f"bad {what} {text!r} (expected an integer)") from None


def _parse_box(parts: List[str]) -> Tuple[List[int], List[int]]:
    """``["x0,x1", "y0,y1", ...]`` -> (lo, hi), one segment per axis."""
    if not parts:
        raise ApiError(400, "missing box (expected <lo>,<hi> per axis)")
    lo, hi = [], []
    for seg in parts:
        pieces = seg.split(",")
        if len(pieces) != 2:
            raise ApiError(400, f"bad box segment {seg!r} (expected <lo>,<hi>)")
        a, b = (_int(p, "box bound") for p in pieces)
        if a > b:
            raise ApiError(400, f"bad box segment {seg!r} (lo > hi)")
        lo.append(a)
        hi.append(b)
    return lo, hi


def parse_url(method: str, path: str) -> Tuple[str, Request]:
    """Parse a ``(method, path)`` pair into ``(verb, params)``.

    Raises :class:`ApiError` with 404 (no such route) or 400 (malformed
    resolution / box / id).  The query string is the caller's problem —
    strip it first and merge its values into the request dict.
    """
    method = method.upper()
    parts = [p for p in path.split("/") if p]
    if parts and parts[0] == "v1":
        parts = parts[1:]
    if not parts:
        raise ApiError(404, "no route for /")

    if parts in (["flush"], ["compact"]):
        if method != "POST":
            raise ApiError(405, f"{method} not allowed on /{parts[0]}")
        return f"POST /{parts[0]}", {}

    # Observability surface.  Bare /metrics scrapes every dataset (the
    # Prometheus convention); /trace is cluster-wide by construction —
    # the span ring is per-process, not per-dataset.
    if parts[0] == "metrics" and len(parts) == 1:
        if method != "GET":
            raise ApiError(405, f"{method} not allowed on /metrics")
        return "GET /metrics", {}
    if parts[0] == "trace":
        if method != "GET":
            raise ApiError(405, f"{method} not allowed on /trace")
        if len(parts) != 2:
            raise ApiError(404, "trace needs /trace/<id>")
        return "GET /trace", {"trace": parts[1]}

    name, rest = parts[0], parts[1:]
    if not rest:
        raise ApiError(404, f"no route for /{name}")
    head = rest[0]

    if head == "cutout":
        if method not in ("GET", "PUT"):
            raise ApiError(405, f"{method} not allowed on cutout")
        if len(rest) < 2:
            raise ApiError(400, "cutout needs /<resolution>/<box...>")
        lo, hi = _parse_box(rest[2:])
        return (
            f"{method} /cutout",
            {"dataset": name, "resolution": _int(rest[1], "resolution"), "lo": lo, "hi": hi},
        )

    if head in _PLANE_AXIS:
        if method != "GET":
            raise ApiError(405, f"{method} not allowed on {head} projection")
        if len(rest) < 2:
            raise ApiError(400, f"{head} projection needs /<resolution>/<box...>")
        lo, hi = _parse_box(rest[2:])
        return (
            "GET /projection",
            {
                "dataset": name,
                "resolution": _int(rest[1], "resolution"),
                "lo": lo,
                "hi": hi,
                "axis": _PLANE_AXIS[head],
            },
        )

    if head == "objects":
        if len(rest) < 3:
            raise ApiError(404, f"no route for /{name}/objects (need /<id>/<query>)")
        if method != "GET":
            raise ApiError(405, f"{method} not allowed on objects")
        params: Request = {"project": name, "id": _int(rest[1], "object id")}
        query = rest[2]
        if query == "boundingbox":
            if len(rest) > 4:
                raise ApiError(404, f"no route for trailing {'/'.join(rest[4:])!r}")
            if len(rest) == 4:
                params["resolution"] = _int(rest[3], "resolution")
            return "GET /objects/boundingbox", params
        if query == "cutout":
            if len(rest) >= 4:
                params["resolution"] = _int(rest[3], "resolution")
            if len(rest) >= 5:
                params["lo"], params["hi"] = _parse_box(rest[4:])
            return "GET /objects/cutout", params
        raise ApiError(404, f"no route for objects query {query!r}")

    if head == "batch":
        if rest[1:] != ["cutout"]:
            raise ApiError(404, f"no route for /{name}/batch/{'/'.join(rest[1:])}")
        if method != "POST":
            raise ApiError(405, f"{method} not allowed on batch/cutout")
        return "POST /batch/cutout", {"dataset": name}

    if head == "nodes":
        if method == "POST" and len(rest) == 1:
            return "POST /nodes/add", {"dataset": name}
        if method == "DELETE" and len(rest) == 2:
            return "POST /nodes/remove", {"dataset": name, "node": _int(rest[1], "node index")}
        raise ApiError(405, f"{method} /{'/'.join(parts)} not allowed on nodes")

    if head in ("stats", "metrics", "topology", "flush", "compact", "rebalance") and len(rest) == 1:
        expected = "POST" if head in ("flush", "compact", "rebalance") else "GET"
        if method != expected:
            raise ApiError(405, f"{method} not allowed on {head} (use {expected})")
        return f"{expected} /{head}", {"dataset": name}

    raise ApiError(404, f"no route for {method} /{'/'.join(parts)}")


def url_dispatch(
    service: VolumeService,
    method: str,
    path: str,
    request: Optional[Request] = None,
) -> Response:
    """Route one request by URL path (the v1 contract).

    Path-derived params override the request dict (the path *is* the
    address); everything else — payload, ``encode``, ``level``,
    ``channel``, ``sync`` — rides in ``request``.  Always returns the
    uniform ``{status, error?, ...}`` envelope, never raises for a bad
    route or bad input.
    """
    try:
        verb, params = parse_url(method, path)
    except ApiError as e:
        return _error(e.status, e.message)
    merged: Dict[str, Any] = dict(request or {})
    merged.update(params)
    return HANDLERS[verb](service, merged)
