"""Hot-cuboid caching tier + write-behind ingest queue (paper §6 vision).

The paper's §6 roadmap puts a memcached-style memory tier in front of the
disk read path and lets SSD nodes absorb bursty small writes.  This module
reproduces both halves as composable objects a `CuboidStore` (and therefore
a `ClusterStore` shard) attaches:

* :class:`CuboidCache` — a read-through LRU of *compressed* cuboid blobs
  (plus lazily-memoized decoded blocks) in front of ``fetch_runs``.  The
  LRU is keyed for **Morton-curve locality**: keys are grouped into curve
  segments of ``2**segment_bits`` consecutive cuboids and eviction drops
  whole segments, never single keys — a cutout that re-touches a region
  finds the entire neighbourhood resident or absent together.  A byte
  budget bounds resident blob + block bytes.  Absence is cached too
  (``blob is None`` entries), so a fully warm cutout performs zero backend
  I/O even over lazily-allocated volumes.  The cache is also the landing
  zone for the cold path's plan-driven segment prefetcher
  (``put_prefetched``): prefetched blobs are admitted only into spare
  budget and queue at the LRU end until a real read touches them, so one
  giant scan's lookahead can never evict the hot set.

* :class:`WriteBehindQueue` — a bounded per-node queue that absorbs cuboid
  writes and applies them to the backing store from a background flusher
  thread in batches (the SSD write path absorbing bursts while reads
  proceed uninterfered).  ``peek``/``peek_many`` give readers the pending
  (freshest) value, so the store keeps **read-your-writes** without
  waiting for the flush.  ``flush()`` is the durability barrier: when it
  returns, every previously enqueued write has been applied to the
  backend.  ``close()`` flushes and stops the flusher.

Consistency contract (what `cluster/handlers.py` exposes):

1. A write is *readable* through the owning store the moment the write
   call returns (cache absorbs it, the queue holds it pending).
2. A write is *durable in the backend* only after ``flush()`` — the
   ``POST /flush`` verb, ``migrate()``, ``stored_keys()``, and ``close()``
   all force this barrier.
3. Eviction is invisible: an evicted segment re-reads from pending writes
   first, then the backends, bit-identically.

`attach_cache` / `enable_write_behind` wire either tier onto an existing
`CuboidStore`; `ClusterStore(cache_bytes=..., write_behind=True)` wires
every node shard (also switchable via the ``REPRO_CACHE_BYTES`` /
``REPRO_WRITE_BEHIND`` environment knobs, which the CI cache matrix leg
uses to run tier-1 with the tier enabled).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..analysis import knobs
from ..analysis.witness import ordered_lock
from ..core.store import Key, decompress
from ..obs import trace
from ..obs.registry import REGISTRY

# Accounting overhead charged per cache entry (key tuple, links, and the
# negative entries whose blob is None but which still occupy the table).
ENTRY_OVERHEAD = 64

SegKey = Tuple[int, int, int]  # (resolution, channel, morton >> segment_bits)


@dataclasses.dataclass
class _Entry:
    """One cached cuboid: compressed blob (None = cached absence) and an
    optionally memoized decoded block (read-only ndarray)."""

    blob: Optional[bytes]
    block: Optional[np.ndarray] = None

    @property
    def nbytes(self) -> int:
        n = ENTRY_OVERHEAD
        if self.blob is not None:
            n += len(self.blob)
        if self.block is not None:
            n += self.block.nbytes
        return n


class _Segment:
    """One curve segment's entries (the eviction unit)."""

    __slots__ = ("entries", "nbytes")

    def __init__(self):
        self.entries: Dict[Key, _Entry] = {}
        self.nbytes = 0


class CuboidCache:
    """Segment-LRU read-through cache of compressed cuboid blobs.

    ``segment_bits`` sets the locality granule: morton indexes ``m`` with
    equal ``m >> segment_bits`` (same resolution/channel) live and die
    together.  ``max_bytes`` bounds total resident bytes; when exceeded,
    least-recently-*touched* segments are dropped wholesale until the
    budget holds (the most recent segment always survives, even if it
    alone exceeds the budget — it is the working set).

    Thread-safe; all counters are monotonic except ``bytes``.
    """

    # Per-entry accounting overhead, exposed so the store's prefetch
    # admission precheck stays in sync with put_prefetched's arithmetic.
    entry_overhead = ENTRY_OVERHEAD

    def __init__(self, max_bytes: int = 64 << 20, segment_bits: int = 3):
        if max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        if segment_bits < 0:
            raise ValueError("segment_bits must be >= 0")
        self.max_bytes = int(max_bytes)
        self.segment_bits = int(segment_bits)
        self._segments: "collections.OrderedDict[SegKey, _Segment]" = collections.OrderedDict()
        self._lock = ordered_lock("cache.segments", 60)
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0  # segments dropped
        self.insertions = 0
        # Prefetch admission bookkeeping: which resident keys arrived via
        # put_prefetched and have not been touched by a real read yet.
        self._prefetched: set = set()
        self.prefetch_insertions = 0
        self.prefetch_hits = 0      # reads served by a prefetched entry
        self.prefetch_rejected = 0  # admissions refused (budget guard)

    # -- internals ---------------------------------------------------------
    def _seg_key(self, key: Key) -> SegKey:
        r, c, m = key
        return (r, c, m >> self.segment_bits)

    def _touch(self, sk: SegKey) -> Optional[_Segment]:
        seg = self._segments.get(sk)
        if seg is not None:
            self._segments.move_to_end(sk)
        return seg

    def _evict_to_budget(self) -> None:
        # Evict whole LRU segments; keep at least the most recent one.
        while self.bytes > self.max_bytes and len(self._segments) > 1:
            _, seg = self._segments.popitem(last=False)
            self.bytes -= seg.nbytes
            self.evictions += 1
            if self._prefetched:
                self._prefetched.difference_update(seg.entries)

    def _store(self, key: Key, entry: _Entry) -> None:
        sk = self._seg_key(key)
        seg = self._segments.get(sk)
        if seg is None:
            seg = self._segments[sk] = _Segment()
        else:
            self._segments.move_to_end(sk)
        old = seg.entries.get(key)
        if old is not None:
            seg.nbytes -= old.nbytes
            self.bytes -= old.nbytes
        seg.entries[key] = entry
        seg.nbytes += entry.nbytes
        self.bytes += entry.nbytes
        self.insertions += 1
        self._prefetched.discard(key)  # a real write/read supersedes it
        self._evict_to_budget()

    # -- lookups -----------------------------------------------------------
    def get_blob(self, key: Key) -> Tuple[bool, Optional[bytes]]:
        """Return ``(hit, blob)``.  ``hit`` and ``blob is None`` together
        mean *cached absence* (the cuboid is a lazy zero)."""
        with self._lock:
            seg = self._touch(self._seg_key(key))
            entry = seg.entries.get(key) if seg is not None else None
            if entry is None:
                self.misses += 1
                return False, None
            self.hits += 1
            self._count_prefetch_hit(key)
            return True, entry.blob

    def _count_prefetch_hit(self, key: Key) -> None:
        """Called under the lock on every hit: a prefetched entry's first
        real read counts once and promotes it to a normal resident."""
        if key in self._prefetched:
            self._prefetched.discard(key)
            self.prefetch_hits += 1

    def probe(self, key: Key) -> Tuple[bool, Optional[bytes]]:
        """`get_blob` without touching the hit/miss counters or the LRU —
        for presence checks (``has_cuboid``) that are not reads."""
        with self._lock:
            seg = self._segments.get(self._seg_key(key))
            entry = seg.entries.get(key) if seg is not None else None
            if entry is None:
                return False, None
            return True, entry.blob

    def peek_block(self, key: Key) -> Tuple[bool, Optional[bytes], Optional[np.ndarray]]:
        """Hit lookup returning ``(hit, blob, block)`` WITHOUT decoding.

        The pipelined cold path uses this instead of :meth:`get_block` so
        blob-only hits (e.g. freshly prefetched segments) can decompress
        in parallel chunks on the decode pool rather than one-by-one in
        the calling thread; callers memoize the result via
        :meth:`attach_block`.  Counts as a normal read (hit/miss + LRU
        touch).
        """
        with self._lock:
            seg = self._touch(self._seg_key(key))
            entry = seg.entries.get(key) if seg is not None else None
            if entry is None:
                self.misses += 1
                return False, None, None
            self.hits += 1
            self._count_prefetch_hit(key)
            return True, entry.blob, entry.block

    def attach_block(self, key: Key, blob: bytes, block: np.ndarray) -> None:
        """Memoize a block decoded *outside* the cache (decode workers).

        Same guard as :meth:`get_block`'s lazy memoization: attach only if
        the entry still holds the identical blob and no block — a racing
        write or eviction silently drops the memo.  Marks ``block``
        read-only (it becomes cache-owned and shared)."""
        block.flags.writeable = False
        with self._lock:
            seg = self._segments.get(self._seg_key(key))
            entry = seg.entries.get(key) if seg is not None else None
            if entry is not None and entry.blob is blob and entry.block is None:
                entry.block = block
                seg.nbytes += block.nbytes
                self.bytes += block.nbytes
                self._evict_to_budget()

    def get_block(self, key: Key, shape, dtype) -> Tuple[bool, Optional[np.ndarray]]:
        """Blob lookup that also memoizes the decoded block on first use.

        Returned arrays are read-only views owned by the cache — callers
        copy before mutating.  The cutout engine's pipelined path uses
        :meth:`peek_block` + :meth:`attach_block` directly so blob-only
        hits decode in parallel; this is the convenience form for
        single-key callers.
        """
        hit, blob, block = self.peek_block(key)
        if not hit:
            return False, None
        if blob is None or block is not None:
            return True, block
        # decompress OUTSIDE the lock (a first-touch decode must not
        # serialize every other cache operation), then memoize — only if
        # the entry still holds the same blob (a racing write or eviction
        # drops the memo; a racing decode of the same blob is benign).
        block = decompress(blob, shape, dtype)
        self.attach_block(key, blob, block)
        return True, block

    # -- population / coherence -------------------------------------------
    def put(self, key: Key, blob: Optional[bytes]) -> None:
        """Absorb a freshly read or written blob (None = known absent)."""
        with self._lock:
            self._store(key, _Entry(blob=blob))

    def put_many(self, items: Sequence[Tuple[Key, Optional[bytes]]]) -> None:
        with self._lock:
            for key, blob in items:
                self._store(key, _Entry(blob=blob))

    def put_prefetched(self, items: Sequence[Tuple[Key, Optional[bytes]]]) -> Tuple[int, int]:
        """Admission-guarded population for the plan-driven prefetcher.

        Unlike :meth:`put_many`, prefetched blobs may **never evict**
        resident data: an item is admitted only while it fits in the spare
        budget, and a freshly created segment enters at the *LRU* end — a
        giant scan's lookahead queues behind the hot set and is the first
        thing dropped if it is never touched (first real read promotes it
        via the normal LRU touch).  Keys already resident are left alone
        (a racing read/write beat us and is at least as fresh).

        Returns ``(admitted, rejected)``.
        """
        admitted = rejected = 0
        with self._lock:
            for key, blob in items:
                sk = self._seg_key(key)
                seg = self._segments.get(sk)
                if seg is not None and key in seg.entries:
                    continue
                entry = _Entry(blob=blob)
                if self.bytes + entry.nbytes > self.max_bytes:
                    rejected += 1
                    continue
                if seg is None:
                    seg = self._segments[sk] = _Segment()
                    self._segments.move_to_end(sk, last=False)
                seg.entries[key] = entry
                seg.nbytes += entry.nbytes
                self.bytes += entry.nbytes
                self.insertions += 1
                self._prefetched.add(key)
                admitted += 1
            self.prefetch_insertions += admitted
            self.prefetch_rejected += rejected
        if admitted or rejected:
            trace.event("cache.prefetch", admitted=admitted, rejected=rejected)
        return admitted, rejected

    def put_block(self, key: Key, blob: bytes, block: np.ndarray) -> None:
        """Absorb a blob together with its decoded block."""
        if not block.flags.c_contiguous or block.flags.writeable:
            block = np.ascontiguousarray(block).copy()
        block.flags.writeable = False
        with self._lock:
            self._store(key, _Entry(blob=blob, block=block))

    def _invalidate_locked(self, key: Key) -> None:
        sk = self._seg_key(key)
        seg = self._segments.get(sk)
        entry = seg.entries.pop(key, None) if seg is not None else None
        self._prefetched.discard(key)
        if entry is not None:
            seg.nbytes -= entry.nbytes
            self.bytes -= entry.nbytes
            if not seg.entries:
                del self._segments[sk]

    def invalidate(self, key: Key) -> None:
        with self._lock:
            self._invalidate_locked(key)

    def invalidate_many(self, keys: Sequence[Key]) -> None:
        """Drop entries wholesale (segment migration moved them away);
        unlike a cached absence this frees the bytes immediately."""
        with self._lock:
            for key in keys:
                self._invalidate_locked(key)

    def invalidate_range(self, r: int, start: int, stop: int) -> None:
        """Drop every cached entry — blobs *and* cached absences, every
        channel — for morton indexes in ``[start, stop)`` at resolution
        ``r``.  This is the replica-membership invalidation: when a node
        leaves a range's replica set, any entry it cached for the range
        (including "known absent" markers) describes data it no longer
        holds, so the whole range must go, not just the keys currently
        stored."""
        if start >= stop:
            return
        with self._lock:
            span = 1 << self.segment_bits
            for sk in list(self._segments):
                seg_r, _c, seg_m = sk
                if seg_r != r:
                    continue
                base = seg_m << self.segment_bits
                if base >= stop or base + span <= start:
                    continue
                seg = self._segments[sk]
                for key in [k for k in seg.entries if start <= k[2] < stop]:
                    self._invalidate_locked(key)

    def clear(self) -> None:
        with self._lock:
            self._segments.clear()
            self._prefetched.clear()
            self.bytes = 0

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return sum(len(s.entries) for s in self._segments.values())

    @property
    def n_segments(self) -> int:
        return len(self._segments)

    def counters(self) -> Dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "insertions": self.insertions,
            "bytes": self.bytes,
            "max_bytes": self.max_bytes,
            "segments": len(self._segments),
            "prefetch_insertions": self.prefetch_insertions,
            "prefetch_hits": self.prefetch_hits,
            "prefetch_rejected": self.prefetch_rejected,
        }


class WriteBehindQueue:
    """Bounded write-behind queue with a background batch flusher.

    ``put_many(items)`` / ``delete(key)`` are the apply callbacks (bound to
    the owning store's backends); both run under ``apply_lock`` so flushes
    serialize with per-key ``migrate()`` and direct writes.  Enqueued
    values overwrite older pending values for the same key (last write
    wins, exactly as the backend would resolve them); ``blob=None`` means
    *delete* (lazy-zero write).

    Backpressure: ``enqueue`` blocks while ``max_items`` distinct keys are
    pending (bursts are absorbed up to the bound, then writers throttle to
    the flusher's pace — the paper's SSD saturating behaviour).

    A flush failure never stops the queue: the failed batch is retried
    per-key with capped exponential backoff while fresh writes keep
    flowing.  A key that keeps failing past ``REPRO_WB_POISON_AFTER``
    attempts is quarantined into a poison list (surfaced via
    ``poison_keys()`` / ``counters()`` / ``PathStats``) so one broken key
    can never wedge the barrier for everyone else; re-enqueueing a
    poisoned key gives it a fresh chance.
    """

    def __init__(
        self,
        put_many: Callable[[Sequence[Tuple[Key, bytes]]], None],
        delete: Callable[[Key], None],
        apply_lock=None,  # a Lock-shaped object (ordered or plain)
        max_items: int = 512,
        batch_items: int = 64,
        retry_backoff: float = 0.01,
        retry_cap: float = 0.25,
    ):
        if max_items <= 0 or batch_items <= 0:
            raise ValueError("max_items and batch_items must be positive")
        self._put_many = put_many
        self._delete = delete
        self._apply_lock = apply_lock if apply_lock is not None \
            else ordered_lock("wb.apply", 40)
        self.max_items = int(max_items)
        self.batch_items = int(batch_items)
        self.retry_backoff = float(retry_backoff)
        self.retry_cap = float(retry_cap)
        self.poison_after = max(1, knobs.get_int("REPRO_WB_POISON_AFTER", 8))
        self._mu = threading.Condition()
        self._pending: Dict[Key, Tuple[int, Optional[bytes]]] = {}
        self._order: Deque[Key] = collections.deque()
        self._fail_counts: Dict[Key, int] = {}
        self._poison: Dict[Key, str] = {}
        self._seq = 0
        self._closed = False
        self.enqueued = 0
        self.applied = 0
        self.batches = 0
        self.depth_peak = 0
        self.flush_errors = 0
        self.retried = 0
        self.poisoned = 0
        self.last_flush_error: Optional[str] = None
        self._thread = threading.Thread(target=self._run, name="ocp-write-behind", daemon=True)
        self._thread.start()

    # -- producer side -----------------------------------------------------
    def enqueue(self, key: Key, blob: Optional[bytes]) -> None:
        with self._mu:
            if self._closed:
                raise RuntimeError("write-behind queue is closed")
            # Backpressure on *distinct* keys: rewriting a pending key never
            # blocks (it replaces in place).
            while len(self._pending) >= self.max_items and key not in self._pending:
                self._mu.notify_all()
                self._mu.wait(0.05)
                if self._closed:  # closed while we waited for room
                    raise RuntimeError("write-behind queue is closed")
            # A rewrite of a quarantined key is a fresh chance: the new
            # value may well apply (the poison may have been transient).
            self._poison.pop(key, None)
            self._fail_counts.pop(key, None)
            self._seq += 1
            self._pending[key] = (self._seq, blob)
            self._order.append(key)
            self.enqueued += 1
            self.depth_peak = max(self.depth_peak, len(self._pending))
            self._mu.notify_all()

    def enqueue_many(self, items: Sequence[Tuple[Key, Optional[bytes]]]) -> None:
        for key, blob in items:
            self.enqueue(key, blob)

    # -- reader side (read-your-writes) ------------------------------------
    def peek(self, key: Key) -> Tuple[bool, Optional[bytes]]:
        """Freshest pending value: ``(True, blob_or_None_for_delete)``."""
        with self._mu:
            ent = self._pending.get(key)
            if ent is None:
                return False, None
            return True, ent[1]

    def peek_many(self, keys: Sequence[Key]) -> List[Tuple[bool, Optional[bytes]]]:
        with self._mu:
            out = []
            for key in keys:
                ent = self._pending.get(key)
                out.append((False, None) if ent is None else (True, ent[1]))
            return out

    @property
    def depth(self) -> int:
        return len(self._pending)

    def pending_keys(self) -> Tuple[set, set]:
        """Snapshot of pending ``(put_keys, delete_keys)`` (last write
        wins) — lets occupancy be counted without forcing a flush."""
        with self._mu:
            puts = {k for k, (_, b) in self._pending.items() if b is not None}
            dels = {k for k, (_, b) in self._pending.items() if b is None}
        return puts, dels

    # -- flusher -----------------------------------------------------------
    def _apply(self, items: List[Tuple[Key, int, Optional[bytes]]]) -> bool:
        """Apply one batch under the apply lock.  Returns False on failure
        (recorded for the poison machinery) instead of raising — the
        flusher retries; only a ``BaseException`` (interpreter teardown)
        still kills the thread, and ``flush()``'s liveness check turns
        that into a loud error."""
        try:
            t0 = time.perf_counter()
            with self._apply_lock:
                puts = [(k, b) for k, _, b in items if b is not None]
                if puts:
                    self._put_many(puts)
                for k, _, b in items:
                    if b is None:
                        self._delete(k)
            # The flusher runs outside any request's trace, so its
            # visibility is a histogram, not spans: batch apply
            # latency by size is what diagnoses a saturated queue.
            REGISTRY.histogram(
                "repro_flush_batch_seconds",
                None,
                "write-behind flusher batch apply duration",
            ).observe(time.perf_counter() - t0)
            return True
        except Exception as e:
            with self._mu:
                self.flush_errors += 1
                self.last_flush_error = repr(e)
            return False

    def _ack_locked(self, items: List[Tuple[Key, int, Optional[bytes]]]) -> None:
        for key, seq, _ in items:
            ent = self._pending.get(key)
            if ent is not None and ent[0] == seq:
                del self._pending[key]
        self.applied += len(items)
        self.batches += 1

    def _run(self) -> None:
        backoff = self.retry_backoff
        while True:
            with self._mu:
                while not self._order and not self._closed:
                    self._mu.wait(0.1)
                if not self._order and self._closed:
                    return
                batch: List[Tuple[Key, int, Optional[bytes]]] = []
                seen = set()
                while self._order and len(batch) < self.batch_items:
                    key = self._order.popleft()
                    if key in seen:
                        continue
                    ent = self._pending.get(key)
                    if ent is None:  # a later pop already applied it
                        continue
                    seen.add(key)
                    batch.append((key, ent[0], ent[1]))
            if not batch:
                continue
            if self._apply(batch):
                backoff = self.retry_backoff
                with self._mu:
                    self._ack_locked(batch)
                    self._mu.notify_all()
                continue
            # The batch failed as a unit.  Retry each entry individually so
            # one bad key can't hold the rest of the batch hostage; a key
            # that keeps failing past the threshold is quarantined.
            acked: List[Tuple[Key, int, Optional[bytes]]] = []
            for key, seq, blob in batch:
                with self._mu:
                    ent = self._pending.get(key)
                    if ent is None or ent[0] != seq:
                        continue  # superseded: the newer write has its own order entry
                if self._apply([(key, seq, blob)]):
                    acked.append((key, seq, blob))
                    with self._mu:
                        self.retried += 1
                        self._fail_counts.pop(key, None)
                    continue
                with self._mu:
                    n = self._fail_counts.get(key, 0) + 1
                    self._fail_counts[key] = n
                    if n >= self.poison_after:
                        ent = self._pending.get(key)
                        if ent is not None and ent[0] == seq:
                            del self._pending[key]
                        self._poison[key] = self.last_flush_error or "flush failed"
                        self._fail_counts.pop(key, None)
                        self.poisoned += 1
                        self._mu.notify_all()  # quarantine unblocks flush()
                    else:
                        self._order.append(key)  # requeue for the next pass
            with self._mu:
                if acked:
                    self._ack_locked(acked)
                    self._mu.notify_all()
                    backoff = self.retry_backoff
                else:
                    # Every entry in the pass failed: back off (capped
                    # exponential; a close() notify wakes the wait early).
                    if not self._closed:
                        self._mu.wait(backoff)
                    backoff = min(backoff * 2, self.retry_cap)

    # -- barriers ----------------------------------------------------------
    def flush(self, timeout: Optional[float] = None) -> int:
        """Block until every write enqueued *before this call* is applied
        (or superseded by a newer write to the same key).

        The barrier is a sequence snapshot, not queue emptiness, so it
        stays live under sustained concurrent writers: writes enqueued
        after the flush began do not extend the wait.  A write whose key
        is quarantined as poison counts as settled (it will never apply;
        the quarantine is surfaced via ``poison_keys()``/``counters()``).
        Returns the number of writes that were pending at call time.
        """
        with self._mu:
            target = self._seq
            drained = sum(1 for seq, _ in self._pending.values() if seq <= target)
            self._mu.notify_all()
            waited = 0.0
            while any(seq <= target for seq, _ in self._pending.values()):
                if not self._thread.is_alive():
                    raise RuntimeError("write-behind flusher died")
                self._mu.wait(0.05)
                waited += 0.05
                if timeout is not None and waited >= timeout:
                    raise TimeoutError(f"flush timed out with {len(self._pending)} pending")
        return drained

    def close(self) -> None:
        """Flush, then stop the flusher thread.  Idempotent."""
        with self._mu:
            if self._closed and not self._thread.is_alive():
                return
            self._closed = True
            self._mu.notify_all()
        self._thread.join(timeout=30.0)
        with self._mu:
            if self._pending:
                raise RuntimeError(f"write-behind queue closed with {len(self._pending)} pending")

    def poison_keys(self) -> Dict[Key, str]:
        """Snapshot of quarantined keys -> the error that poisoned them."""
        with self._mu:
            return dict(self._poison)

    def counters(self) -> Dict[str, int]:
        return {
            "enqueued": self.enqueued,
            "applied": self.applied,
            "batches": self.batches,
            "depth": len(self._pending),
            "depth_peak": self.depth_peak,
            "flush_errors": self.flush_errors,
            "retried": self.retried,
            "poisoned": self.poisoned,
        }


# -- store wiring ----------------------------------------------------------


def attach_cache(store, cache_or_bytes) -> CuboidCache:
    """Attach a :class:`CuboidCache` to a `CuboidStore` (read-through +
    write-absorb from then on).  Accepts a cache instance or a byte budget."""
    cache = (
        cache_or_bytes
        if isinstance(cache_or_bytes, CuboidCache)
        else CuboidCache(max_bytes=int(cache_or_bytes))
    )
    store.cache = cache
    return cache


def enable_write_behind(store, max_items: int = 512, batch_items: int = 64) -> WriteBehindQueue:
    """Attach a :class:`WriteBehindQueue` to a `CuboidStore`.

    Puts land on the store's write path (the SSD-node analogue when a
    write backend is attached); deletes clear *both* paths so a lazy-zero
    write can never resurrect stale read-path data after the flush —
    except on a tombstone-capable write tier (the append log), where the
    delete is one durable tombstone that *shadows* the read path until
    compaction applies it.  Applies run under the store lock, serializing
    with ``migrate()``.
    """
    target = store.write_backend or store.read_backend

    if target.supports_tombstones:
        _delete = target.delete
    else:
        def _delete(key: Key) -> None:
            target.delete(key)
            store.read_backend.delete(key)

    queue = WriteBehindQueue(
        put_many=target.put_many,
        delete=_delete,
        apply_lock=store._lock,
        max_items=max_items,
        batch_items=batch_items,
    )
    store.write_behind = queue
    return queue
