"""Encoder-decoder transformer (SeamlessM4T backbone, arXiv:2308.11596).

The audio frontend is a STUB per the assignment: `input_specs()` supplies
precomputed frame embeddings to the encoder. The decoder is a standard
causal transformer with cross-attention; decode uses a self-attention KV
cache plus precomputed cross-attention K/V.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .attention import attention, attention_specs
from .config import ModelConfig
from .layers import (blockwise_attention, decode_attention, mlp, mlp_specs,
                     rms_norm, rms_norm_spec, rotary)
from .lm import stack_specs
from .params import ParamSpec

F32 = jnp.float32


def _maybe_scan(cfg, body, carry, xs):
    """lax.scan, or an unrolled loop when cfg.scan_layers is False (the
    dry-run's cost-extrapolation variants need unrolled layers)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        layer = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, layer)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "encdec"
        self.cfg = cfg

    # ------------------------------------------------------------ specs ----
    def _enc_block(self):
        cfg = self.cfg
        return {"ln1": rms_norm_spec(cfg.d_model),
                "attn": attention_specs(cfg),
                "ln2": rms_norm_spec(cfg.d_model),
                "mlp": mlp_specs(cfg)}

    def _dec_block(self):
        cfg = self.cfg
        return {"ln1": rms_norm_spec(cfg.d_model),
                "self_attn": attention_specs(cfg),
                "ln_x": rms_norm_spec(cfg.d_model),
                "cross_attn": attention_specs(cfg),
                "ln2": rms_norm_spec(cfg.d_model),
                "mlp": mlp_specs(cfg)}

    def specs(self) -> dict:
        cfg = self.cfg
        out = {
            "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                               dtype=cfg.dtype),
            "enc_final_norm": rms_norm_spec(cfg.d_model),
            "final_norm": rms_norm_spec(cfg.d_model),
            "encoder": stack_specs(self._enc_block(), cfg.n_enc_layers),
            "decoder": stack_specs(self._dec_block(), cfg.n_dec_layers),
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab),
                                       ("embed", "vocab"), dtype=cfg.dtype)
        return out

    # ---------------------------------------------------------- encoder ----
    def encode(self, params, frame_embeds):
        """frame_embeds: (B, T, d) stub frontend output -> encoder memory."""
        from ..train.sharding import constrain
        cfg = self.cfg
        x = constrain(frame_embeds, ("act_batch", "act_seq", "act_embed"))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(x, p):
            h = attention(p["attn"], cfg, rms_norm(x, p["ln1"],
                                                   cfg.norm_eps),
                          positions, causal=False)
            x = x + h
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                        cfg.act)
            return x, None

        from .layers import maybe_remat
        body = maybe_remat(body, cfg.remat)
        x, _ = _maybe_scan(cfg, body, x, params["encoder"])
        return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)

    # ------------------------------------------------------- cross attn ----
    def _cross(self, p, cfg, x, memory, positions_q):
        B, Sq, _ = x.shape
        H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        Sm = memory.shape[1]
        q = (x @ p["w_q"]).reshape(B, Sq, H, hd)
        k = (memory @ p["w_k"]).reshape(B, Sm, K, hd)
        v = (memory @ p["w_v"]).reshape(B, Sm, K, hd)
        out = blockwise_attention(q, k, v, causal=False,
                                  scale=hd ** -0.5,
                                  block_q=cfg.attn_block_q,
                                  block_kv=cfg.attn_block_kv,
                                  unroll=cfg.attn_unroll)
        return out.reshape(B, Sq, -1) @ p["w_o"]

    # ---------------------------------------------------------- decoder ----
    def forward(self, params, tokens, frame_embeds,
                skip_masked_blocks=True) -> Tuple[jax.Array, jax.Array]:
        """Teacher-forced training step. Returns (logits, aux=0)."""
        from ..train.sharding import constrain
        cfg = self.cfg
        memory = self.encode(params, frame_embeds)
        x = jnp.take(params["embed"], tokens, axis=0)
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(x, p):
            h = attention(p["self_attn"], cfg,
                          rms_norm(x, p["ln1"], cfg.norm_eps), positions,
                          causal=True,
                          skip_masked_blocks=skip_masked_blocks)
            x = x + h
            x = x + self._cross(p["cross_attn"], cfg,
                                rms_norm(x, p["ln_x"], cfg.norm_eps),
                                memory, positions)
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                        cfg.act)
            return x, None

        from .layers import maybe_remat
        body = maybe_remat(body, cfg.remat)
        x, _ = _maybe_scan(cfg, body, x, params["decoder"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ params["embed"].T if cfg.tie_embeddings
                  else x @ params["lm_head"])
        return logits, jnp.zeros((), F32)

    # ----------------------------------------------------------- decode ----
    def cache_specs(self, B: int, cache_len: int, enc_len: int) -> dict:
        cfg = self.cfg
        K, hd = cfg.n_kv_heads, cfg.head_dim
        self_kv = {
            "k": ParamSpec((B, cache_len, K, hd),
                           ("batch", "kv_len", "kv_heads_cache", None),
                           dtype=cfg.dtype, init="zeros"),
            "v": ParamSpec((B, cache_len, K, hd),
                           ("batch", "kv_len", "kv_heads_cache", None),
                           dtype=cfg.dtype, init="zeros"),
            # cross-attention K/V precomputed from encoder memory
            "xk": ParamSpec((B, enc_len, K, hd),
                            ("batch", "kv_len", "kv_heads_cache", None),
                            dtype=cfg.dtype, init="zeros"),
            "xv": ParamSpec((B, enc_len, K, hd),
                            ("batch", "kv_len", "kv_heads_cache", None),
                            dtype=cfg.dtype, init="zeros"),
        }
        return {"decoder": stack_specs(self_kv, cfg.n_dec_layers)}

    def decode_step(self, params, cache, token, index):
        """One decoder token against self cache + fixed cross K/V."""
        cfg = self.cfg
        H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        x = jnp.take(params["embed"], token, axis=0)
        B = x.shape[0]

        def body(x, pc):
            p, c = pc
            from .layers import cache_insert, per_seq_positions
            xin = rms_norm(x, p["ln1"], cfg.norm_eps)
            positions = per_seq_positions(index, B)
            q = rotary((xin @ p["self_attn"]["w_q"]).reshape(B, 1, H, hd),
                       positions, cfg.rope_theta)
            k = rotary((xin @ p["self_attn"]["w_k"]).reshape(B, 1, K, hd),
                       positions, cfg.rope_theta)
            v = (xin @ p["self_attn"]["w_v"]).reshape(B, 1, K, hd)
            ck = cache_insert(c["k"], k, index)
            cv = cache_insert(c["v"], v, index)
            h = decode_attention(q, ck, cv,
                                 jnp.asarray(index, jnp.int32) + 1,
                                 scale=hd ** -0.5)
            x = x + h.reshape(B, 1, -1) @ p["self_attn"]["w_o"]
            # cross attention against precomputed enc K/V (always valid)
            xq = rms_norm(x, p["ln_x"], cfg.norm_eps)
            q2 = (xq @ p["cross_attn"]["w_q"]).reshape(B, 1, H, hd)
            h2 = decode_attention(q2, c["xk"], c["xv"],
                                  c["xk"].shape[1], scale=hd ** -0.5)
            x = x + h2.reshape(B, 1, -1) @ p["cross_attn"]["w_o"]
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                        cfg.act)
            return x, {"k": ck, "v": cv, "xk": c["xk"], "xv": c["xv"]}

        x, new_dec = _maybe_scan(cfg, body, x,
                                 (params["decoder"], cache["decoder"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ params["embed"].T if cfg.tie_embeddings
                  else x @ params["lm_head"])
        return logits, {"decoder": new_dec}

    def build_cross_cache(self, params, memory):
        """Precompute per-layer cross K/V from encoder memory."""
        cfg = self.cfg
        B, Sm, _ = memory.shape
        K, hd = cfg.n_kv_heads, cfg.head_dim

        def body(_, p):
            xk = (memory @ p["cross_attn"]["w_k"]).reshape(B, Sm, K, hd)
            xv = (memory @ p["cross_attn"]["w_v"]).reshape(B, Sm, K, hd)
            return None, (xk, xv)

        _, (xk, xv) = _maybe_scan(cfg, body, None, params["decoder"])
        return xk, xv
