"""Top-k Mixture-of-Experts with sort-based (Megablocks-style) dispatch.

No (T, E, C) one-hot dispatch tensor is ever materialized: tokens are
argsorted by expert id, placed into a capacity-bounded (E, C, d) buffer by
scatter, run through a grouped expert GEMM, and gathered back weighted by
router gates. Tokens over capacity are dropped (standard GShard semantics).

Sharding: expert buffers carry the logical "experts" axis -> mesh `model`
(expert parallelism); the scatter/gather across the token-sharded and
expert-sharded layouts lowers to all-to-all — the EP dispatch collective.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec

F32 = jnp.float32


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "w_router": ParamSpec((d, E), ("embed", None), dtype="float32"),
        "w_gate": ParamSpec((E, d, f), ("experts", "embed", "ff"),
                            dtype=cfg.dtype),
        "w_up": ParamSpec((E, d, f), ("experts", "embed", "ff"),
                          dtype=cfg.dtype),
        "w_down": ParamSpec((E, f, d), ("experts", "ff", "embed"),
                            dtype=cfg.dtype),
    }


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(cfg.top_k, min(c, n_tokens))


def moe(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    ``cfg.moe_dispatch``:
      gspmd — one global dispatch; GSPMD lowers the token→expert scatter
              across the expert-sharded buffer to all-to-all (EP), or —
              when experts are replicated — replicates the whole (E·C, d)
              buffer on every device (pathological; see §Perf Cell C).
      local — vmap over a dp-sharded leading dim: every device dispatches
              only its own tokens into a LOCAL capacity buffer and runs
              the expert GEMMs there; no cross-device scatter exists at
              all. Expert weights are all-gathered over DP (ordinary FSDP
              traffic) and stay TP-sharded over `model` (the ff
              contraction psums activation-sized partials).
    """
    if cfg.moe_dispatch == "local":
        out = _moe_local(p, cfg, x)
        if out is not None:
            return out
    return _moe_core(p, cfg, x)


def _moe_local(p, cfg: ModelConfig, x):
    """Local dispatch via vmap over a dp-sharded leading dim.

    Tokens reshape to (dp_size, T/dp_size, d) with dim 0 sharded over the
    DP axes; the whole dispatch/GEMM/return is vmapped over dim 0. Every
    scatter/sort/gather then carries a *parallel batch dim aligned with
    the sharding*, which GSPMD partitions without any cross-device
    communication — each device dispatches exactly its own tokens into
    its own (E, C_local, d) buffer. (A shard_map formulation is
    semantically identical but XLA:CPU miscompiles grad-of-shard_map on
    region-boundary collectives — "Invalid binary instruction opcode
    copy" — so the vmap encoding is used.)

    Returns None when no plan/divisible DP axis is available
    (single-device tests, batch=1 cells) — caller falls back to gspmd.
    """
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..train.sharding import _ACTIVE_PLAN
    plan = _ACTIVE_PLAN[0]
    if plan is None:
        return None
    dp = tuple(a for a in plan.dp_axes if plan.mesh.shape[a] > 1)
    if not dp:
        return None
    dp_size = int(np.prod([plan.mesh.shape[a] for a in dp]))
    B, S, d = x.shape
    if B % dp_size != 0:
        return None
    T = B * S
    xt = x.reshape(dp_size, T // dp_size, d)
    xt = jax.lax.with_sharding_constraint(
        xt, NamedSharding(plan.mesh, P(dp, None, None)))
    out, aux = jax.vmap(lambda xl: _moe_tokens(p, cfg, xl))(xt)
    out = out.reshape(B, S, d)
    return out, aux.mean()


def _moe_core(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    out, aux = _moe_tokens(p, cfg, x.reshape(B * S, d))
    return out.reshape(B, S, d), aux


def _moe_tokens(p, cfg: ModelConfig, xt) -> Tuple[jax.Array, jax.Array]:
    """Dispatch + expert GEMMs over a flat (T, d) token block."""
    T, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, T)

    logits = (xt.astype(F32) @ p["w_router"].astype(F32))        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)                  # renorm

    # --- load-balancing auxiliary loss (Switch/GShard) ---
    me = probs.mean(axis=0)                                       # (E,)
    ce = jnp.zeros((E,), F32).at[expert_ids.reshape(-1)].add(
        1.0 / (T * k))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # --- sort-based dispatch ---
    flat_ids = expert_ids.reshape(T * k)                          # (Tk,)
    order = jnp.argsort(flat_ids)                                 # stable
    sorted_ids = flat_ids[order]
    token_of = order // k                                         # (Tk,)
    counts = jnp.zeros((E,), jnp.int32).at[sorted_ids].add(1)
    offsets = jnp.cumsum(counts) - counts                         # excl cumsum
    pos_in_expert = jnp.arange(T * k) - offsets[sorted_ids]
    keep = pos_in_expert < C                                      # drop excess
    slot = sorted_ids * C + jnp.clip(pos_in_expert, 0, C - 1)     # (Tk,)

    buf = jnp.zeros((E * C, d), xt.dtype)
    src = jnp.take(xt, token_of, axis=0) * keep[:, None].astype(xt.dtype)
    buf = buf.at[slot].add(src, mode="drop")                      # (EC, d)
    grouped = buf.reshape(E, C, d)

    # --- grouped expert GEMMs (SwiGLU experts) ---
    g = jnp.einsum("ecd,edf->ecf", grouped, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", grouped, p["w_up"])
    h = jax.nn.silu(g.astype(F32)).astype(xt.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, d)

    # --- gather back, weighted by gates ---
    picked = jnp.take(y, slot, axis=0)                            # (Tk, d)
    w = (gate_vals.reshape(T * k)[order] * keep).astype(xt.dtype)
    out = jnp.zeros((T, d), xt.dtype).at[token_of].add(picked * w[:, None])
    return out, aux
