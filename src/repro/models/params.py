"""Abstract parameter specs: shapes + logical sharding axes.

Models declare parameters as ``ParamSpec`` trees, so the SAME declaration
serves (a) real initialization for smoke tests/examples, (b) allocation-free
``jax.ShapeDtypeStruct`` trees for the multi-pod dry-run, and (c)
PartitionSpec derivation via logical-axis rules (train/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LogicalAxes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: LogicalAxes                      # logical name per dim (or None)
    dtype: str = "bfloat16"
    init: str = "normal"                   # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes rank mismatch {self.shape} {self.axes}")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], object], specs):
    return jax.tree.map(fn, specs, is_leaf=is_spec)


def abstract_params(specs):
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)), specs)


def init_params(specs, rng: jax.Array, dtype_override: Optional[str] = None):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        dt = jnp.dtype(dtype_override or s.dtype)
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dt))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dt))
        else:
            out.append((jax.random.normal(k, s.shape, jnp.float32)
                        * s.scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def partition_specs(specs, rules: Dict[str, object]):
    """Logical axes -> jax PartitionSpec via a rules dict.

    rules maps logical axis name -> mesh axis (str), tuple of mesh axes, or
    None (replicate). Unknown logical names replicate.
    """
    from jax.sharding import PartitionSpec as P

    def one(s: ParamSpec):
        return P(*[rules.get(a) if a is not None else None for a in s.axes])

    return tree_map_specs(one, specs)


def named_shardings(specs, mesh, rules):
    from jax.sharding import NamedSharding
    pspecs = partition_specs(specs, rules)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))
