"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Train path uses `jax.lax.associative_scan` over the sequence (the gated
linear recurrence is associative), decode is an O(1) state update. Combined
with a sliding local-attention block at a 1:2 ratio this gives the hybrid
family its bounded-state long-context decode.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec

F32 = jnp.float32
_C = 8.0  # Griffin's fixed recurrence sharpness constant


def rglru_specs(cfg: ModelConfig) -> dict:
    d, dr = cfg.d_model, cfg.d_rnn
    return {
        "w_x": ParamSpec((d, dr), ("embed", "rnn"), dtype=cfg.dtype),
        "w_y": ParamSpec((d, dr), ("embed", "rnn"), dtype=cfg.dtype),
        "conv_w": ParamSpec((cfg.conv_width, dr), (None, "rnn"),
                            dtype=cfg.dtype),
        "conv_b": ParamSpec((dr,), ("rnn",), init="zeros", dtype=cfg.dtype),
        "w_a": ParamSpec((dr, dr), ("rnn", None), dtype=cfg.dtype),
        "b_a": ParamSpec((dr,), (None,), init="zeros", dtype="float32"),
        "w_i": ParamSpec((dr, dr), ("rnn", None), dtype=cfg.dtype),
        "b_i": ParamSpec((dr,), (None,), init="zeros", dtype="float32"),
        "lam": ParamSpec((dr,), ("rnn",), init="ones", dtype="float32"),
        "w_o": ParamSpec((dr, d), ("rnn", "embed"), dtype=cfg.dtype),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b


def _gates(p, u):
    r = jax.nn.sigmoid((u @ p["w_a"]).astype(F32) + p["b_a"])
    i = jax.nn.sigmoid((u @ p["w_i"]).astype(F32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (B,S,dr) <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0))
    b = beta * i * u.astype(F32)
    return a, b


def rglru_block(p, cfg: ModelConfig, x) -> jax.Array:
    """Full-sequence recurrent mixer. x: (B,S,d)."""
    u = _causal_conv(x @ p["w_x"], p["conv_w"], p["conv_b"])
    y = jax.nn.gelu((x @ p["w_y"]).astype(F32), approximate=True)
    a, b = _gates(p, u)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h * y).astype(x.dtype)
    return out @ p["w_o"]


def rglru_decode_step(p, cfg: ModelConfig, x, h_state, conv_state
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,1,d); h_state: (B,dr) f32; conv_state: (B,W-1,dr)."""
    u_t = (x @ p["w_x"])[:, 0]                            # (B,dr)
    hist = jnp.concatenate([conv_state, u_t[:, None]], axis=1)
    u = ((hist * p["conv_w"][None]).sum(axis=1) + p["conv_b"])[:, None]
    y = jax.nn.gelu((x @ p["w_y"]).astype(F32), approximate=True)[:, 0]
    a, b = _gates(p, u)
    h = a[:, 0] * h_state + b[:, 0]                       # (B,dr)
    out = (h * y).astype(x.dtype)[:, None]                # (B,1,dr)
    return out @ p["w_o"], h, hist[:, 1:]
