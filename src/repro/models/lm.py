"""Decoder-only LM assembly for all architecture families.

Families: dense (GQA/MQA), moe (top-k, optional dense residual — Arctic),
hybrid (Griffin RG-LRU + local attention), ssm (Mamba-2), and dense+VLM
(patch-embedding frontend stub). Layers are stacked and scanned
(`jax.lax.scan`) to keep HLO size O(1) in depth; per-block remat is a config
knob. The encoder-decoder family lives in `encdec.py`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, attention_decode, attention_specs
from .config import ModelConfig
from .layers import decode_attention, mlp, mlp_specs, rms_norm, rms_norm_spec, rotary
from .moe import moe, moe_specs
from .params import ParamSpec, tree_map_specs
from .rglru import rglru_block, rglru_decode_step, rglru_specs
from .ssm import ssm_block, ssm_decode_step, ssm_specs

F32 = jnp.float32


def stack_specs(tree, n: int):
    return tree_map_specs(
        lambda s: dataclasses.replace(s, shape=(n,) + s.shape,
                                      axes=("layers",) + s.axes), tree)


class LM:
    """Decoder-only language model over a ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        if cfg.family not in ("dense", "moe", "hybrid", "ssm"):
            raise ValueError(f"LM does not handle family {cfg.family}")
        self.cfg = cfg

    # ---------------------------------------------------------- specs ----
    def _block_specs(self) -> dict:
        cfg = self.cfg
        if cfg.family == "dense":
            return {"ln1": rms_norm_spec(cfg.d_model),
                    "attn": attention_specs(cfg),
                    "ln2": rms_norm_spec(cfg.d_model),
                    "mlp": mlp_specs(cfg)}
        if cfg.family == "moe":
            out = {"ln1": rms_norm_spec(cfg.d_model),
                   "attn": attention_specs(cfg),
                   "ln2": rms_norm_spec(cfg.d_model),
                   "moe": moe_specs(cfg)}
            if cfg.moe_dense_residual:
                out["mlp"] = mlp_specs(cfg)
            return out
        if cfg.family == "ssm":
            return {"ln": rms_norm_spec(cfg.d_model),
                    "ssm": ssm_specs(cfg)}
        raise AssertionError

    def _hybrid_unit_specs(self, kind: str) -> dict:
        cfg = self.cfg
        temporal = (rglru_specs(cfg) if kind == "R"
                    else attention_specs(cfg))
        return {"ln1": rms_norm_spec(cfg.d_model), "temporal": temporal,
                "ln2": rms_norm_spec(cfg.d_model), "mlp": mlp_specs(cfg)}

    def _hybrid_layout(self) -> Tuple[int, int]:
        """(#full pattern repeats, #leftover layers)."""
        cfg = self.cfg
        plen = len(cfg.hybrid_pattern)
        return cfg.n_layers // plen, cfg.n_layers % plen

    def specs(self) -> dict:
        cfg = self.cfg
        out: Dict = {
            "embed": ParamSpec((cfg.vocab, cfg.d_model),
                               ("vocab", "embed"), dtype=cfg.dtype),
            "final_norm": rms_norm_spec(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab),
                                       ("embed", "vocab"), dtype=cfg.dtype)
        if cfg.family == "hybrid":
            n_rep, n_left = self._hybrid_layout()
            rep = {k: self._hybrid_unit_specs(k2)
                   for k, k2 in zip("abcdefgh", cfg.hybrid_pattern)}
            out["blocks"] = stack_specs(rep, n_rep)
            if n_left:
                left = {k: self._hybrid_unit_specs(k2)
                        for k, k2 in zip(
                            "abcdefgh", cfg.hybrid_pattern[:n_left])}
                out["tail"] = stack_specs(left, 1)
        else:
            out["blocks"] = stack_specs(self._block_specs(), cfg.n_layers)
        return out

    # -------------------------------------------------------- forward ----
    def _apply_unit(self, p, x, positions, kind: str,
                    skip_masked_blocks=True):
        """One hybrid unit: temporal mixer + MLP, both pre-norm residual."""
        cfg = self.cfg
        if kind == "R":
            h = rglru_block(p["temporal"], cfg, rms_norm(x, p["ln1"],
                                                         cfg.norm_eps))
        else:
            h = attention(p["temporal"], cfg,
                          rms_norm(x, p["ln1"], cfg.norm_eps), positions,
                          window=cfg.local_window,
                          skip_masked_blocks=skip_masked_blocks)
        x = x + h
        x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
        return x

    def _block_fwd(self, p, x, positions, skip_masked_blocks=True,
                   pattern=None):
        cfg = self.cfg
        aux = jnp.zeros((), F32)
        if cfg.family == "dense":
            h = attention(p["attn"], cfg, rms_norm(x, p["ln1"],
                                                   cfg.norm_eps),
                          positions, skip_masked_blocks=skip_masked_blocks)
            x = x + h
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                        cfg.act)
        elif cfg.family == "moe":
            h = attention(p["attn"], cfg, rms_norm(x, p["ln1"],
                                                   cfg.norm_eps),
                          positions, skip_masked_blocks=skip_masked_blocks)
            x = x + h
            xin = rms_norm(x, p["ln2"], cfg.norm_eps)
            y, aux = moe(p["moe"], cfg, xin)
            if cfg.moe_dense_residual:
                y = y + mlp(p["mlp"], xin, cfg.act)
            x = x + y
        elif cfg.family == "ssm":
            x = x + ssm_block(p["ssm"], cfg, rms_norm(x, p["ln"],
                                                      cfg.norm_eps))
        else:  # hybrid repeat unit
            for key, kind in zip("abcdefgh", pattern or cfg.hybrid_pattern):
                x = self._apply_unit(p[key], x, positions, kind,
                                     skip_masked_blocks)
        return x, aux

    def embed_tokens(self, params, tokens):
        from ..train.sharding import constrain
        x = jnp.take(params["embed"], tokens, axis=0)
        # pin batch sharding: the gather would otherwise inherit the
        # FSDP-sharded table layout and drop it (see sharding.constrain)
        return constrain(x, ("act_batch", "act_seq", "act_embed"))

    def logits(self, params, x):
        from ..train.sharding import constrain
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        out = (x @ params["embed"].T if cfg.tie_embeddings
               else x @ params["lm_head"])
        return constrain(out, ("act_batch", "act_seq", "act_vocab"))

    def forward(self, params, tokens, *, embeds=None,
                skip_masked_blocks=True):
        """tokens: (B, S_text) int32. embeds: optional (B, S_img, d) stub
        frontend output, prepended to the sequence (VLM/audio backbones).
        Returns (logits, aux_loss)."""
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)
        if embeds is not None:
            x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        from ..train.sharding import constrain

        def body(carry, layer_p):
            x, aux = carry
            x, a = self._block_fwd(layer_p, x, positions,
                                   skip_masked_blocks=skip_masked_blocks)
            x = constrain(x, ("act_batch", "act_seq", "act_embed"))
            return (x, aux + a), None

        from .layers import maybe_remat
        body = maybe_remat(body, cfg.remat)
        aux0 = jnp.zeros((), F32)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
        else:
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            carry = (x, aux0)
            for i in range(n):
                layer = jax.tree.map(lambda a: a[i], params["blocks"])
                carry, _ = body(carry, layer)
            x, aux = carry
        if "tail" in params:
            _, n_left = self._hybrid_layout()
            tail_pat = cfg.hybrid_pattern[:n_left]

            def tail_body(carry, layer_p):
                x, aux = carry
                x, a = self._block_fwd(
                    layer_p, x, positions, pattern=tail_pat,
                    skip_masked_blocks=skip_masked_blocks)
                return (x, aux + a), None

            tail_body = maybe_remat(tail_body, cfg.remat)
            (x, aux), _ = jax.lax.scan(tail_body, (x, aux), params["tail"])
        return self.logits(params, x), aux

    # ---------------------------------------------------------- decode ----
    def _unit_cache_spec(self, kind: str, B: int, cache_len: int) -> dict:
        cfg = self.cfg
        if kind == "R":
            return {
                "h": ParamSpec((B, cfg.d_rnn), ("batch", "rnn"),
                               dtype="float32", init="zeros"),
                "conv": ParamSpec((B, cfg.conv_width - 1, cfg.d_rnn),
                                  ("batch", None, "rnn"),
                                  dtype=cfg.dtype, init="zeros"),
            }
        wlen = min(cfg.local_window, cache_len)
        return {
            "k": ParamSpec((B, wlen, cfg.n_kv_heads, cfg.head_dim),
                           ("batch", "kv_len", "kv_heads_cache", None),
                           dtype=cfg.dtype, init="zeros"),
            "v": ParamSpec((B, wlen, cfg.n_kv_heads, cfg.head_dim),
                           ("batch", "kv_len", "kv_heads_cache", None),
                           dtype=cfg.dtype, init="zeros"),
        }

    def cache_specs(self, B: int, cache_len: int) -> dict:
        """Decode-cache ParamSpec tree (dry-run uses abstract version).

        Full-attention families allocate (L, B, S, K, hd) KV caches; the
        hybrid family a bounded local window + O(1) recurrent states; the
        ssm family only O(1) states — the sub-quadratic story of DESIGN §5.
        """
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            kv = {
                "k": ParamSpec((B, cache_len, cfg.n_kv_heads, cfg.head_dim),
                               ("batch", "kv_len", "kv_heads_cache", None),
                               dtype=cfg.dtype, init="zeros"),
                "v": ParamSpec((B, cache_len, cfg.n_kv_heads, cfg.head_dim),
                               ("batch", "kv_len", "kv_heads_cache", None),
                               dtype=cfg.dtype, init="zeros"),
            }
            return {"blocks": stack_specs(kv, cfg.n_layers)}
        if cfg.family == "ssm":
            st = {
                "state": ParamSpec(
                    (B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    ("batch", "heads_cache", None, None),
                    dtype="float32", init="zeros"),
                "conv": ParamSpec(
                    (B, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state),
                    ("batch", None, "inner"), dtype=cfg.dtype,
                    init="zeros"),
            }
            return {"blocks": stack_specs(st, cfg.n_layers)}
        # hybrid
        n_rep, n_left = self._hybrid_layout()
        rep = {k: self._unit_cache_spec(k2, B, cache_len)
               for k, k2 in zip("abcdefgh", cfg.hybrid_pattern)}
        out = {"blocks": stack_specs(rep, n_rep)}
        if n_left:
            left = {k: self._unit_cache_spec(k2, B, cache_len)
                    for k, k2 in zip("abcdefgh",
                                     cfg.hybrid_pattern[:n_left])}
            out["tail"] = stack_specs(left, 1)
        return out

    def _unit_decode(self, p, c, x, index, kind: str):
        cfg = self.cfg
        if kind == "R":
            h, hs, conv = rglru_decode_step(
                p["temporal"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                c["h"], c["conv"])
            c = {"h": hs, "conv": conv}
        else:
            # rotating window cache: slot = index mod window
            wlen = c["k"].shape[1]
            xin = rms_norm(x, p["ln1"], cfg.norm_eps)
            B = x.shape[0]
            H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            from .layers import cache_insert, per_seq_positions
            positions = per_seq_positions(index, B)
            q = rotary((xin @ p["temporal"]["w_q"]).reshape(B, 1, H, hd),
                       positions, cfg.rope_theta)
            k = rotary((xin @ p["temporal"]["w_k"]).reshape(B, 1, K, hd),
                       positions, cfg.rope_theta)
            v = (xin @ p["temporal"]["w_v"]).reshape(B, 1, K, hd)
            slot = jnp.mod(jnp.asarray(index, jnp.int32), wlen)
            ck = cache_insert(c["k"], k, slot)
            cv = cache_insert(c["v"], v, slot)
            # valid slots: all < min(index+1, wlen)
            n_valid = jnp.minimum(index + 1, wlen)
            out = decode_attention(q, ck, cv, n_valid, scale=hd ** -0.5)
            h = out.reshape(B, 1, -1) @ p["temporal"]["w_o"]
            c = {"k": ck, "v": cv}
        x = x + h
        x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
        return x, c

    def _block_decode(self, p, c, x, index, pattern=None):
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            xin = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, ck, cv = attention_decode(p["attn"], cfg, xin, c["k"],
                                         c["v"], index)
            x = x + h
            xin2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            if cfg.family == "dense":
                x = x + mlp(p["mlp"], xin2, cfg.act)
            else:
                y, _ = moe(p["moe"], cfg, xin2)
                if cfg.moe_dense_residual:
                    y = y + mlp(p["mlp"], xin2, cfg.act)
                x = x + y
            return x, {"k": ck, "v": cv}
        if cfg.family == "ssm":
            h, st, conv = ssm_decode_step(
                p["ssm"], cfg, rms_norm(x, p["ln"], cfg.norm_eps),
                c["state"], c["conv"])
            return x + h, {"state": st, "conv": conv}
        # hybrid repeat unit
        new_c = {}
        for key, kind in zip("abcdefgh", pattern or cfg.hybrid_pattern):
            x, new_c[key] = self._unit_decode(p[key], c[key], x, index, kind)
        return x, new_c

    def decode_step(self, params, cache, token, index):
        """token: (B, 1) int32; index: scalar int32 position, or (B,)
        per-sequence positions (continuous batching).
        Returns (logits, new_cache)."""
        cfg = self.cfg
        x = self.embed_tokens(params, token)

        def body(x, pc):
            p, c = pc
            x, c_new = self._block_decode(p, c, x, index)
            return x, c_new

        if cfg.scan_layers:
            x, new_blocks = jax.lax.scan(
                body, x, (params["blocks"], cache["blocks"]))
        else:
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            outs = []
            for i in range(n):
                p = jax.tree.map(lambda a: a[i], params["blocks"])
                c = jax.tree.map(lambda a: a[i], cache["blocks"])
                x, cn = body(x, (p, c))
                outs.append(cn)
            new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache = {"blocks": new_blocks}
        if "tail" in params:
            _, n_left = self._hybrid_layout()
            tail_pat = cfg.hybrid_pattern[:n_left]

            def tail_body(x, pc):
                p, c = pc
                return self._block_decode(p, c, x, index, pattern=tail_pat)

            x, new_tail = jax.lax.scan(
                tail_body, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail
        return self.logits(params, x), new_cache

    # --------------------------------------------------------- prefill ----
    def prefill(self, params, tokens, cache_len: int):
        """Run the prompt, build a decode cache. Used by examples/serve.

        Implemented for dense/moe (full KV) and ssm (final state); hybrid
        prefill processes the prompt token-by-token through decode_step
        (simple, correct; optimized hybrid prefill is future work).
        """
        cfg = self.cfg
        B, S = tokens.shape
        if cfg.family in ("dense", "moe"):
            from .attention import prefill_kv
            x = self.embed_tokens(params, tokens)
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            pad = cache_len - S

            def body(carry, layer_p):
                """Baseline: K/V projected twice (once for the cache, once
                inside _block_fwd's attention)."""
                x, = carry
                xin = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
                k, v = prefill_kv(layer_p["attn"], cfg, xin, positions,
                                  cache_len)
                x, _ = self._block_fwd(layer_p, x, positions)
                return (x,), {"k": k, "v": v}

            def fused_body(carry, layer_p):
                """§Perf fused path: the forward pass's K/V feed the cache
                directly — one projection pass instead of two."""
                x, = carry
                xin = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
                h, (k, v) = attention(layer_p["attn"], cfg, xin, positions,
                                      return_kv=True)
                x = x + h
                xin2 = rms_norm(x, layer_p["ln2"], cfg.norm_eps)
                if cfg.family == "dense":
                    x = x + mlp(layer_p["mlp"], xin2, cfg.act)
                else:
                    y, _ = moe(layer_p["moe"], cfg, xin2)
                    if cfg.moe_dense_residual:
                        y = y + mlp(layer_p["mlp"], xin2, cfg.act)
                    x = x + y
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                return (x,), {"k": k, "v": v}

            from .encdec import _maybe_scan
            (x,), kv = _maybe_scan(
                cfg, fused_body if cfg.fused_prefill_kv else body,
                (x,), params["blocks"])
            return self.logits(params, x[:, -1:]), {"blocks": kv}
        # ssm / hybrid: token-by-token through decode (reference path)
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
            self.cache_specs(B, cache_len),
            is_leaf=lambda x: isinstance(x, ParamSpec))
        logits = None
        for i in range(S):
            logits, cache = self.decode_step(params, cache, tokens[:, i:i+1],
                                             jnp.int32(i))
        return logits, cache
