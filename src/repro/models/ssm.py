"""Mamba-2 (SSD, state-space duality) layer — arXiv:2405.21060.

Training/prefill uses the *chunked* SSD algorithm: intra-chunk attention-like
matmuls (MXU-friendly) plus an inter-chunk scan over per-chunk states —
exactly the quadratic<->recurrent duality of the paper. Decode is the O(1)
recurrent state update, which is what makes `long_500k` feasible for this
family (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rms_norm
from .params import ParamSpec

F32 = jnp.float32


def ssm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    dconv = di + 2 * N
    return {
        "w_z": ParamSpec((d, di), ("embed", "inner"), dtype=cfg.dtype),
        "w_xBC": ParamSpec((d, dconv), ("embed", "inner"), dtype=cfg.dtype),
        "w_dt": ParamSpec((d, H), ("embed", None), dtype=cfg.dtype),
        "conv_w": ParamSpec((cfg.conv_width, dconv), (None, "inner"),
                            dtype=cfg.dtype),
        "conv_b": ParamSpec((dconv,), ("inner",), init="zeros",
                            dtype=cfg.dtype),
        "A_log": ParamSpec((H,), (None,), init="zeros", dtype="float32"),
        "dt_bias": ParamSpec((H,), (None,), init="zeros", dtype="float32"),
        "D": ParamSpec((H,), (None,), init="ones", dtype="float32"),
        "norm": ParamSpec((di,), ("inner",), init="ones", dtype="float32"),
        "w_out": ParamSpec((di, d), ("inner", "embed"), dtype=cfg.dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out + b


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD.  x:(B,S,H,P) dt:(B,S,H) A:(H,)<0  B,C:(B,S,N).

    Returns y:(B,S,H,P) and the final state (B,H,P,N).
    """
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    a = dt * A  # (B,S,H) log-decay per step (negative)
    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    ac = a.reshape(Bsz, nc, chunk, H)
    Bc = B.reshape(Bsz, nc, chunk, N)
    Cc = C.reshape(Bsz, nc, chunk, N)

    cum = jnp.cumsum(ac, axis=2)                        # (B,nc,Q,H)
    # --- intra-chunk (quadratic/attention form) ---
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,Q,Q,H)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc.astype(F32), Bc.astype(F32))
    M = scores[..., None] * L                                # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", M,
                         dtc.astype(F32), xc.astype(F32))

    # --- per-chunk states ---
    seg = jnp.exp(cum[:, :, -1:, :] - cum)                   # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                        Bc.astype(F32), (seg * dtc).astype(F32),
                        xc.astype(F32))                       # (B,nc,H,P,N)

    # --- inter-chunk recurrence (scan over chunks) ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)

    def step(s, inp):
        dec, st = inp                                         # (B,H),(B,H,P,N)
        s_new = s * dec[..., None, None] + st
        return s_new, s                                       # emit incoming

    s0 = jnp.zeros((Bsz, H, P, N), F32)
    final, incoming = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    incoming = jnp.moveaxis(incoming, 0, 1)                   # (B,nc,H,P,N)

    # --- inter-chunk output ---
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         Cc.astype(F32), incoming, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, final


def ssm_block(p, cfg: ModelConfig, x) -> jax.Array:
    """Full-sequence Mamba-2 mixer. x: (B,S,d) -> (B,S,d)."""
    Bsz, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = cfg.d_inner
    z = x @ p["w_z"]                                       # (B,S,di)
    xBC = _causal_conv(x @ p["w_xBC"], p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC.astype(F32)).astype(x.dtype)
    xs = xBC[..., :di].reshape(Bsz, S, H, P)
    Bmat = xBC[..., di:di + N]
    Cmat = xBC[..., di + N:]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(F32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                               # (H,) < 0
    chunk = min(cfg.ssm_chunk, S)
    if cfg.use_ssd_kernel:
        from ..kernels.ssd_scan import ops as ssd_ops
        y, _ = ssd_ops.ssd_scan(xs, dt, A, Bmat, Cmat, chunk=chunk)
    else:
        # pad S to multiple of chunk
        pad = (-S) % chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
            Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        y, _ = _ssd_chunked(xs, dt, A, Bmat, Cmat, chunk)
        y = y[:, :S]
    y = y + p["D"][:, None] * xs[:, :S].astype(F32)
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["norm"],
                 cfg.norm_eps)
    return y @ p["w_out"]


def ssm_decode_step(p, cfg: ModelConfig, x, state, conv_state
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """O(1) recurrent decode. x: (B,1,d); state: (B,H,P,N);
    conv_state: (B, conv_width-1, d_conv). Returns (y, state, conv_state)."""
    Bsz = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = cfg.d_inner
    z = x @ p["w_z"]
    xBC_t = (x @ p["w_xBC"])[:, 0]                        # (B, d_conv)
    hist = jnp.concatenate([conv_state, xBC_t[:, None]], axis=1)
    conv = (hist * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
    conv = jax.nn.silu(conv.astype(F32)).astype(x.dtype)  # (B, d_conv)
    xs = conv[:, :di].reshape(Bsz, H, P)
    Bv = conv[:, di:di + N]
    Cv = conv[:, di + N:]
    dt = jax.nn.softplus((x[:, 0] @ p["w_dt"]).astype(F32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                # (B,H)
    state = (state * decay[..., None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dt, xs.astype(F32),
                          Bv.astype(F32)))
    y = jnp.einsum("bn,bhpn->bhp", Cv.astype(F32), state)
    y = y + p["D"][:, None] * xs.astype(F32)
    y = y.reshape(Bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["norm"],
                 cfg.norm_eps)
    return y @ p["w_out"], state, hist[:, 1:]
