"""GQA/MQA attention block with RoPE, blockwise train path and cached decode."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (blockwise_attention, cache_insert, decode_attention, per_seq_positions, rotary)
from .params import ParamSpec


def attention_specs(cfg: ModelConfig) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "w_q": ParamSpec((d, H * hd), ("embed", "heads"), dtype=cfg.dtype),
        "w_k": ParamSpec((d, K * hd), ("embed", "kv_heads"),
                         dtype=cfg.dtype),
        "w_v": ParamSpec((d, K * hd), ("embed", "kv_heads"),
                         dtype=cfg.dtype),
        "w_o": ParamSpec((H * hd, d), ("heads", "embed"), dtype=cfg.dtype),
    }


def qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["w_q"]).reshape(B, S, H, hd)
    k = (x @ p["w_k"]).reshape(B, S, K, hd)
    v = (x @ p["w_v"]).reshape(B, S, K, hd)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    return q, k, v


def attention(p, cfg: ModelConfig, x, positions,
              window: Optional[int] = None,
              causal: bool = True,
              skip_masked_blocks: bool = True,
              return_kv: bool = False):
    """Full-sequence (train/prefill) attention. x: (B, S, d).

    ``return_kv=True`` additionally returns the (k, v) projections so a
    prefill caller can build the decode cache WITHOUT a second pass of
    K/V projections (the fused-prefill §Perf optimization)."""
    B, S, _ = x.shape
    q, k, v = qkv(p, cfg, x, positions)
    scale = cfg.head_dim ** -0.5
    if cfg.use_flash_kernel:
        from ..kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, causal=causal,
                                        scale=scale, window=window)
    else:
        out = blockwise_attention(q, k, v, causal=causal, scale=scale,
                                  block_q=cfg.attn_block_q,
                                  block_kv=cfg.attn_block_kv,
                                  window=window,
                                  skip_masked_blocks=skip_masked_blocks,
                                  unroll=cfg.attn_unroll)
    out = out.reshape(B, S, -1) @ p["w_o"]
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(p, cfg: ModelConfig, x, cache_k, cache_v, index,
                     window: Optional[int] = None
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, d); caches: (B, S, K, hd); index:
    scalar position or (B,) per-sequence positions (continuous batching).
    Returns (out, new_k_cache, new_v_cache)."""
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = per_seq_positions(index, B)
    q = (x @ p["w_q"]).reshape(B, 1, H, hd)
    k = (x @ p["w_k"]).reshape(B, 1, K, hd)
    v = (x @ p["w_v"]).reshape(B, 1, K, hd)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    cache_k = cache_insert(cache_k, k, index)
    cache_v = cache_insert(cache_v, v, index)
    if cfg.use_flash_decode and window is None:
        from ..kernels.flash_decode import ops as fd_ops
        out = fd_ops.flash_decode(q, cache_k, cache_v,
                                  jnp.asarray(index, jnp.int32) + 1,
                                  scale=hd ** -0.5)
    else:
        out = decode_attention(q, cache_k, cache_v, index + 1,
                               scale=hd ** -0.5, window=window)
    return out.reshape(B, 1, -1) @ p["w_o"], cache_k, cache_v


def prefill_kv(p, cfg: ModelConfig, x, positions, cache_len: int):
    """Compute K/V for the prompt and place into a fresh cache."""
    B, S, _ = x.shape
    K, hd = cfg.n_kv_heads, cfg.head_dim
    k = rotary((x @ p["w_k"]).reshape(B, S, K, hd), positions,
               cfg.rope_theta)
    v = (x @ p["w_v"]).reshape(B, S, K, hd)
    pad = cache_len - S
    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k, v
