"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free (ssm)
    n_kv_heads: int = 0              # GQA groups; == n_heads → MHA; 1 → MQA
    head_dim: int = 0                # 0 → d_model // n_heads
    d_ff: int = 0
    vocab: int = 0
    act: str = "swiglu"              # swiglu | geglu
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel w/ MoE
    router_aux_coef: float = 0.01
    moe_dispatch: str = "gspmd"       # gspmd | local (shard_map per-host
                                      # dispatch, no cross-device scatter)

    # --- hybrid (recurrentgemma / griffin) ---
    # layer pattern: 'R'=RG-LRU recurrent block, 'A'=local attention
    hybrid_pattern: str = "RRA"
    local_window: int = 2048
    d_rnn: int = 0                   # RG-LRU width (griffin: ~4/3 d_model)
    conv_width: int = 4

    # --- ssm (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # --- encoder-decoder (seamless) ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # --- modality frontend stubs ---
    frontend: str = "none"           # none | patch_stub | frame_stub
    n_frontend_tokens: int = 256     # patches/frames provided by the stub

    # --- attention implementation ---
    attn_block_q: int = 512          # blockwise (flash-style) chunk sizes
    attn_block_kv: int = 1024
    attn_unroll: bool = False        # unroll blocks (dry-run cost variants)
    use_flash_kernel: bool = False   # Pallas path (TPU); jnp blockwise else
    use_ssd_kernel: bool = False     # Pallas SSD scan (TPU); jnp chunked else
    use_flash_decode: bool = False   # Pallas decode-attention (TPU)
    # perf knobs (hillclimbing)
    remat: str = "block"             # none | block | dots
    scan_layers: bool = True
    fused_prefill_kv: bool = False   # build decode cache from the forward
                                     # pass's K/V (no second projection)

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)
        if self.family == "hybrid" and not self.d_rnn:
            object.__setattr__(self, "d_rnn", self.d_model)
        if self.family == "encdec" and not self.n_enc_layers:
            object.__setattr__(self, "n_enc_layers", self.n_layers)
            object.__setattr__(self, "n_dec_layers", self.n_layers)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:        # ssm
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (DESIGN.md §5)."""
        return self.family in ("ssm", "hybrid")

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
