"""Model zoo: registry mapping config family -> model class."""
from .config import ModelConfig, ShapeConfig, SHAPES
from .encdec import EncDecLM
from .lm import LM
from .params import (ParamSpec, abstract_params, count_params, init_params,
                     partition_specs)


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return LM(cfg)


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "LM", "EncDecLM",
           "build_model", "ParamSpec", "abstract_params", "count_params",
           "init_params", "partition_specs"]
