"""Shared neural building blocks: norms, rotary, MLPs, blockwise attention.

Attention is implemented *blockwise* (flash-style online softmax in pure
jnp, `lax.map` over query blocks x `lax.scan` over KV blocks) so that memory
stays sub-O(S^2) on every backend; the Pallas kernel in
``repro.kernels.flash_attention`` computes the same math with explicit VMEM
tiling for TPU and is validated against the jnp oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec

F32 = jnp.float32
NEG = -1e30  # large-negative mask value (avoids -inf - -inf = nan)

# ---------------------------------------------------------------- norms ----


def maybe_remat(body, remat: str):
    """Apply the configured activation-checkpoint policy to a scan body.

    none  — no rematerialization: lowest FLOPs, highest activation HBM.
    block — jax.checkpoint on the whole block: bwd recomputes everything,
            activations O(1) per layer (the FSDP-at-405B default).
    dots  — checkpoint_dots_with_no_batch_dims: matmul OUTPUTS are saved,
            elementwise ops recompute. Cuts the bwd recompute FLOPs of
            `block` while keeping activation memory far below `none`
            (the §Perf hillclimb variant).
    """
    if remat == "block":
        return jax.checkpoint(body)
    if remat == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if remat == "none":
        return body
    raise ValueError(f"unknown remat policy {remat!r}")


def rms_norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones", dtype="float32")


def rms_norm(x, w, eps=1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


# --------------------------------------------------------------- rotary ----


def rotary(x, positions, theta: float):
    """x: (..., S, H, D). positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))
    angles = positions[..., :, None].astype(F32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]   # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLP ----


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "ff"), dtype=cfg.dtype),
        "w_up": ParamSpec((d, f), ("embed", "ff"), dtype=cfg.dtype),
        "w_down": ParamSpec((f, d), ("ff", "embed"), dtype=cfg.dtype),
    }


def mlp(p, x, act: str):
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    if act == "swiglu":
        h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    elif act == "geglu":
        h = jax.nn.gelu(g.astype(F32), approximate=True).astype(x.dtype) * u
    else:
        raise ValueError(act)
    return h @ p["w_down"]


# ---------------------------------------------------- blockwise attention ----


def _block_attn_update(q, k, v, m, l, acc, mask):
    """One online-softmax update. q:(...,Bq,D) k/v:(...,Bkv,D)
    mask:(...,Bq,Bkv) additive; m,l:(...,Bq); acc:(...,Bq,Dv)."""
    s = jnp.einsum("...qd,...kd->...qk", q, k).astype(F32) + mask
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    correction = jnp.exp(m - m_new)
    l_new = l * correction + p.sum(axis=-1)
    acc_new = acc * correction[..., None] + jnp.einsum(
        "...qk,...kd->...qd", p.astype(v.dtype), v).astype(F32)
    return m_new, l_new, acc_new


def _unrolled_attention(q, k, v, *, causal, scale, block_q, block_kv,
                        window):
    """Python-unrolled flash-style attention with STATIC causal/window
    skipping (dead tiles never traced). Exactly the work a TPU flash
    kernel performs — used by the dry-run cost variants because XLA's
    cost_analysis counts scan/map bodies once regardless of trip count."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = -(-Sq // block_q)
    nkv = -(-Skv // block_kv)
    pad_q = nq * block_q - Sq
    pad_kv = nkv * block_kv - Skv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    qb = qp.reshape(B, nq, block_q, K, G, D) * scale
    kb = kp.reshape(B, nkv, block_kv, K, D)
    vb = vp.reshape(B, nkv, block_kv, K, D)
    q_start = Skv - Sq
    outs = []
    for qi in range(nq):
        qt = jnp.moveaxis(qb[:, qi], 1, 3)              # (B,K,G,Bq,D)
        m = jnp.full((B, K, G, block_q), NEG, F32)
        l = jnp.zeros((B, K, G, block_q), F32)
        acc = jnp.zeros((B, K, G, block_q, D), F32)
        q_lo = q_start + qi * block_q
        q_hi = q_lo + block_q - 1
        for kj in range(nkv):
            k_lo, k_hi = kj * block_kv, (kj + 1) * block_kv - 1
            if causal and q_hi < k_lo:
                continue                                 # static skip
            if window is not None and q_lo - k_hi >= window:
                continue
            q_pos = q_lo + jnp.arange(block_q)
            k_pos = k_lo + jnp.arange(block_kv)
            msk = jnp.zeros((block_q, block_kv), F32)
            if causal:
                msk = jnp.where(q_pos[:, None] >= k_pos[None, :], msk, NEG)
            if window is not None:
                msk = jnp.where(q_pos[:, None] - k_pos[None, :] < window,
                                msk, NEG)
            msk = jnp.where(k_pos[None, :] < Skv, msk, NEG)
            kt = jnp.moveaxis(kb[:, kj], 1, 2)[:, :, None]
            vt = jnp.moveaxis(vb[:, kj], 1, 2)[:, :, None]
            m, l, acc = _block_attn_update(qt, kt, vt, m, l, acc,
                                           msk[None, None, None])
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(jnp.moveaxis(out, 3, 1))             # (B,Bq,K,G,D)
    out = jnp.concatenate(outs, axis=1)
    return out[:, :Sq].reshape(B, Sq, H, D).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool, scale: float,
                        block_q: int = 512, block_kv: int = 1024,
                        window: Optional[int] = None,
                        skip_masked_blocks: bool = True,
                        unroll: bool = False):
    if unroll:
        return _unrolled_attention(q, k, v, causal=causal, scale=scale,
                                   block_q=block_q, block_kv=block_kv,
                                   window=window)
    """Flash-style attention, GQA-aware.

    q: (B, Sq, H, D); k, v: (B, Skv, K, D) with H = K * G.
    Returns (B, Sq, H, D). ``window`` = sliding local attention width.

    ``skip_masked_blocks``: wrap each KV block in ``lax.cond`` so blocks
    fully outside the causal/window band are never computed. This is the
    §Perf iteration documented in EXPERIMENTS.md (baseline computes all
    blocks and masks — 2x FLOP waste for causal).
    """
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = -(-Sq // block_q)
    nkv = -(-Skv // block_kv)
    pad_q = nq * block_q - Sq
    pad_kv = nkv * block_kv - Skv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    # (B, nq, Bq, K, G, D) — group GQA heads with their KV head
    qb = qp.reshape(B, nq, block_q, K, G, D) * scale
    kb = kp.reshape(B, nkv, block_kv, K, D)
    vb = vp.reshape(B, nkv, block_kv, K, D)
    # offset of query positions relative to the END of kv (decode: q at end)
    q_start = Skv - Sq

    def per_q_block(args):
        qi, qblk = args           # qblk: (B, Bq, K, G, D)
        q_pos = q_start + qi * block_q + jnp.arange(block_q)
        qt = jnp.moveaxis(qblk, 1, 3)                   # (B,K,G,Bq,D)

        def kv_step(carry, args2):
            m, l, acc = carry
            kj, kblk, vblk = args2
            k_pos = kj * block_kv + jnp.arange(block_kv)
            # additive mask: causal, window, kv padding
            msk = jnp.zeros((block_q, block_kv), F32)
            if causal:
                msk = jnp.where(q_pos[:, None] >= k_pos[None, :], msk, NEG)
            if window is not None:
                msk = jnp.where(q_pos[:, None] - k_pos[None, :] < window,
                                msk, NEG)
            msk = jnp.where(k_pos[None, :] < Skv, msk, NEG)

            def compute(operands):
                m_, l_, a_, kb_, vb_, msk_ = operands
                kt = jnp.moveaxis(kb_, 1, 2)[:, :, None]  # (B,K,1,Bkv,D)
                vt = jnp.moveaxis(vb_, 1, 2)[:, :, None]
                return _block_attn_update(qt, kt, vt, m_, l_, a_,
                                          msk_[None, None, None])

            def skip(operands):
                m_, l_, a_, *_ = operands
                return m_, l_, a_

            operands = (m, l, acc, kblk, vblk, msk)
            if skip_masked_blocks and (causal or window is not None):
                block_live = jnp.any(msk > NEG / 2)
                m, l, acc = jax.lax.cond(block_live, compute, skip, operands)
            else:
                m, l, acc = compute(operands)
            return (m, l, acc), None

        m0 = jnp.full((B, K, G, block_q), NEG, F32)
        l0 = jnp.zeros((B, K, G, block_q), F32)
        a0 = jnp.zeros((B, K, G, block_q, D), F32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nkv), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,K,G,Bq,D)
        return jnp.moveaxis(out, 3, 1)                  # (B,Bq,K,G,D)

    outs = jax.lax.map(per_q_block,
                       (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * block_q, K, G, D)
    return out[:, :Sq].reshape(B, Sq, H, D).astype(q.dtype)


def per_seq_positions(index, B: int):
    """Decode position(s) -> (B, 1) int32. ``index`` may be a scalar (all
    sequences at the same position) or (B,) (continuous batching: every
    slot at its own position)."""
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        return jnp.full((B, 1), idx, jnp.int32)
    return idx.reshape(B, 1)


def cache_insert(cache, new, index):
    """Insert one token of K or V at per-sequence positions.

    cache: (B, S, K, D); new: (B, 1, K, D); index scalar or (B,).
    Scalar keeps the cheap dynamic_update_slice; per-sequence uses a
    batched scatter (one row per sequence).
    """
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), idx, axis=1)
    B = cache.shape[0]
    return cache.at[jnp.arange(B), idx].set(new[:, 0].astype(cache.dtype))


def decode_attention(q, k_cache, v_cache, cache_len, *, scale: float,
                     window: Optional[int] = None):
    """Single-token attention against a KV cache.

    q: (B, 1, H, D); caches: (B, S, K, D); cache_len: scalar or (B,) current
    length (positions >= cache_len masked out).
    """
    B, _, H, D = q.shape
    _, S, K, _ = k_cache.shape
    G = H // K
    qg = (q.reshape(B, K, G, D) * scale)
    # preferred_element_type: f32 MXU accumulation WITHOUT materializing an
    # f32 copy of the (B,S,K,D) cache (decode is cache-read-bound)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=F32)
    pos = jnp.arange(S)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))  # (B,S) or (1,S)
    if window is not None:
        valid = valid & (pos[None, :] >=
                         jnp.reshape(cache_len, (-1, 1)) - window)
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=F32)
    return out.reshape(B, 1, H, D).astype(q.dtype)
