from .pipeline import DataPipeline, TokenStore, PipelineConfig

__all__ = ["DataPipeline", "TokenStore", "PipelineConfig"]
