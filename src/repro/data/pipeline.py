"""Morton-sharded training-data pipeline (the paper's cluster as an input
pipeline for LM training).

The corpus is a 2-d token grid (documents x positions) stored as Morton-
indexed cuboids (C1). Hosts own contiguous curve segments (C3), so each
host's reads are sequential (C7) while any global batch samples uniformly
from the corpus. Batch addressing is STATELESS (C2's REST analogue):
``batch_cuboids(step)`` is a pure function of (seed, step), so a restarted
or replacement host reproduces exactly its share of any batch — this is
what makes checkpoint/restart and elastic rescale trivial for the input
pipeline (no iterator state to persist).

Straggler mitigation: the curve is over-decomposed into work units; a
work-stealing queue lets fast workers absorb slow ones' units (the paper's
parallel-request doctrine, C8).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import queue
import threading
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..core import morton
from ..core.cuboid import DatasetSpec
from ..core.cutout import cutout, ingest
from ..core.store import CuboidStore


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    prefetch: int = 2
    # over-decomposition factor for work stealing (units per worker)
    overdecompose: int = 4


class TokenStore:
    """Token corpus as a (docs, positions) uint32 grid over a CuboidStore."""

    def __init__(self, n_docs: int, doc_len: int,
                 cuboid: Tuple[int, int] = (64, 4096),
                 backend=None):
        self.spec = DatasetSpec(name="tokens",
                                volume_shape=(n_docs, doc_len),
                                dtype="uint32", base_cuboid=cuboid,
                                scaled_dims=())
        self.store = CuboidStore(self.spec, backend=backend)
        self.n_docs = n_docs
        self.doc_len = doc_len

    def ingest_corpus(self, tokens: np.ndarray, offset=(0, 0)) -> None:
        ingest(self.store, 0, tokens.astype(np.uint32), offset=offset)

    def read_rows(self, doc_lo: int, doc_hi: int, pos_lo: int,
                  pos_hi: int) -> np.ndarray:
        return cutout(self.store, 0, (doc_lo, pos_lo), (doc_hi, pos_hi))

    @property
    def grid(self):
        return self.spec.grid(0)


class DataPipeline:
    """Deterministic, stateless-addressed, prefetching batch pipeline."""

    def __init__(self, store: TokenStore, cfg: PipelineConfig):
        self.store = store
        self.cfg = cfg
        if store.doc_len < cfg.seq_len + 1:
            raise ValueError("doc_len must exceed seq_len (need labels)")
        self._rows_per_batch = cfg.global_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # instrumentation
        self.steals = 0
        self.units_processed = 0

    # ---- stateless batch addressing ------------------------------------
    def batch_rows(self, step: int) -> np.ndarray:
        """Document rows of global batch ``step`` — pure f(seed, step)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))
        return rng.choice(self.store.n_docs, size=self._rows_per_batch,
                          replace=self.store.n_docs < self._rows_per_batch)

    def host_slice(self, step: int) -> np.ndarray:
        """The rows THIS host must produce (contiguous shard of the batch)."""
        rows = self.batch_rows(step)
        parts = morton.partition_curve(len(rows), self.cfg.n_hosts)
        lo, hi = parts[self.cfg.host_id]
        return rows[lo:hi]

    # ---- assembly with work stealing ------------------------------------
    def _assemble(self, rows: np.ndarray, n_workers: int = 2) -> np.ndarray:
        S = self.cfg.seq_len + 1  # +1: labels are next-token shifted
        out = np.zeros((len(rows), S), dtype=np.uint32)
        n_units = max(1, n_workers * self.cfg.overdecompose)
        units = np.array_split(np.arange(len(rows)), n_units)
        work: "queue.Queue" = queue.Queue()
        for u in units:
            if len(u):
                work.put(u)

        def worker(wid: int):
            local = 0
            while True:
                try:
                    u = work.get_nowait()
                except queue.Empty:
                    return local
                # visit docs in sorted order -> longer cutout runs (C7)
                order = np.argsort(rows[u], kind="stable")
                for k in order:
                    doc = int(rows[u[k]])
                    out[u[k]] = self.store.read_rows(doc, doc + 1, 0, S)[0]
                local += 1
                self.units_processed += 1

        with cf.ThreadPoolExecutor(max_workers=n_workers) as ex:
            counts = list(ex.map(worker, range(n_workers)))
        # steal count: units processed beyond an even share
        even = n_units // n_workers
        self.steals += sum(max(0, c - even) for c in counts if c)
        return out

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = self.host_slice(step)
        data = self._assemble(rows)
        return {"tokens": data[:, :-1].astype(np.int32),
                "labels": data[:, 1:].astype(np.int32)}

    # ---- prefetch (read path decoupled from the training loop, C4) ------
    def start(self, first_step: int = 0) -> None:
        def run():
            step = first_step
            while not self._stop.is_set():
                batch = self.get_batch(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def next(self) -> Tuple[int, Dict[str, np.ndarray]]:
        return self._q.get()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
