"""Public jit'd wrapper: model layout (B,S,H,P) in, kernel layout inside."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_kernel

F32 = jnp.float32


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256,
             interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (Mamba-2).  Matches models.ssm._ssd_chunked.

    x: (B,S,H,P); dt: (B,S,H) fp32; A: (H,) fp32 (<0); B, C: (B,S,N).
    Returns (y (B,S,H,P) fp32, final_state (B,H,P,N) fp32).
    """
    interpret = _interpret_default() if interpret is None else interpret
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    dtf = dt.astype(F32)
    a = dtf * A.astype(F32)[None, None, :]                  # (B,Sp,H)
    # model layout -> kernel layout
    xk = x.reshape(Bsz, nc, Q, H, P).transpose(0, 3, 1, 2, 4)
    dtk = dtf.reshape(Bsz, nc, Q, H).transpose(0, 3, 1, 2)
    ak = a.reshape(Bsz, nc, Q, H).transpose(0, 3, 1, 2)
    Bk = B.reshape(Bsz, nc, Q, N)
    Ck = C.reshape(Bsz, nc, Q, N)
    y, s = ssd_scan_kernel(xk, dtk, ak, Bk, Ck, interpret=interpret)
    y = y.transpose(0, 2, 3, 1, 4).reshape(Bsz, Sp, H, P)[:, :S]
    return y.astype(F32), s.transpose(0, 1, 3, 2)           # (B,H,P,N)
