"""Pure-jnp oracle for the SSD scan: the *fully quadratic* dual form.

Deliberately NOT the chunked algorithm (that lives in models/ssm.py and in
the kernel): materializes the full (S, S) decay-weighted attention matrix,
so it is an independent check on both.  fp32, O(S^2) memory — test scale.
"""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def ssd_ref(x, dt, A, B, C):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,)<0; B,C: (B,S,N).

    Returns y: (B,S,H,P) fp32 and final state (B,H,P,N) fp32, where
        y_i   = sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
        state = sum_j exp(cum_last - cum_j) dt_j B_j^T x_j
    """
    xf = x.astype(F32)
    dtf = dt.astype(F32)
    Bf = B.astype(F32)
    Cf = C.astype(F32)
    a = dtf * A.astype(F32)                       # (B,S,H)
    cum = jnp.cumsum(a, axis=1)                   # (B,S,H)
    S = x.shape[1]
    diff = cum[:, :, None, :] - cum[:, None, :, :]          # (B,S,S,H)
    causal = (jnp.arange(S)[:, None] >= jnp.arange(S)[None, :])
    L = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bin,bjn->bij", Cf, Bf)             # (B,S,S)
    y = jnp.einsum("bij,bijh,bjh,bjhp->bihp",
                   scores, L, dtf, xf)                      # (B,S,H,P)
    seg = jnp.exp(cum[:, -1:, :] - cum)                     # (B,S,H)
    state = jnp.einsum("bjh,bjhp,bjn->bhpn", seg * dtf, xf, Bf)
    return y, state
