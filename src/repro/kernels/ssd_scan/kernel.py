"""Pallas TPU Mamba-2 SSD (state-space duality) chunked scan.

Grid: (B, H, nc) — chunk index innermost and *sequential*, so the running
inter-chunk state (N, P) lives in VMEM scratch across chunk steps, exactly
like the online-softmax state of flash attention. Each grid step does the
intra-chunk quadratic form on the MXU ((Q,N)x(N,Q), (Q,Q)x(Q,P)) and one
rank-N state update — the duality's "attention-like matmuls + tiny
recurrence" made explicit at the VMEM level.

This is the Morton-locality doctrine (paper C1/C8) one level down: the
sequential chunk walk touches each HBM block exactly once, and all
reuse (the carried state) stays resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, s_sc, *,
            n_chunks: int):
    ci = pl.program_id(2)
    Q = x_ref.shape[-2]

    @pl.when(ci == 0)
    def _init():
        s_sc[...] = jnp.zeros_like(s_sc)

    xb = x_ref[0, 0, 0].astype(F32)                    # (Q, P)
    dtv = dt_ref[0, 0, 0].astype(F32).reshape(Q, 1)    # (Q, 1)
    av = a_ref[0, 0, 0].astype(F32).reshape(Q, 1)      # (Q, 1) log-decay
    Bn = b_ref[0, 0].astype(F32)                       # (Q, N)
    Cn = c_ref[0, 0].astype(F32)                       # (Q, N)

    cum = jnp.cumsum(av, axis=0)                       # (Q, 1)
    # --- intra-chunk quadratic (attention) form ---
    # L[i,j] = exp(cum_i - cum_j) for i >= j else 0
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(cum - cum.reshape(1, Q)), 0.0)
    scores = jax.lax.dot_general(Cn, Bn, (((1,), (1,)), ((), ())))  # (Q,Q)
    xdt = xb * dtv                                     # (Q, P)
    y_intra = jax.lax.dot_general(scores * L, xdt,
                                  (((1,), (0,)), ((), ())))         # (Q,P)

    # --- inter-chunk: apply carried state, then update it ---
    s_prev = s_sc[...]                                 # (N, P)
    y_inter = jax.lax.dot_general(Cn * jnp.exp(cum), s_prev,
                                  (((1,), (0,)), ((), ())))         # (Q,P)
    a_total = cum[Q - 1, 0]                            # chunk log-decay
    seg = jnp.exp(a_total - cum)                       # (Q, 1)
    s_new = (s_prev * jnp.exp(a_total)
             + jax.lax.dot_general(Bn, xdt * seg,
                                   (((0,), (0,)), ((), ()))))       # (N,P)
    s_sc[...] = s_new

    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)
    s_ref[0, 0] = s_new  # final chunk's write persists


def ssd_scan_kernel(x, dt, a, b, c, *, interpret: bool = False):
    """Kernel-layout SSD scan.

    x:  (B, H, nc, Q, P)   head inputs, chunked
    dt: (B, H, nc, Q)      softplus'd step sizes (fp32)
    a:  (B, H, nc, Q)      log-decay dt*A (fp32, negative)
    b:  (B, nc, Q, N)      input projections (shared across heads)
    c:  (B, nc, Q, N)      output projections (shared across heads)
    Returns y: (B, H, nc, Q, P) in x.dtype and final state (B, H, N, P) fp32.
    """
    B, H, nc, Q, P = x.shape
    N = b.shape[-1]
    grid = (B, H, nc)
    kern = functools.partial(_kernel, n_chunks=nc)
    y, s = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b_, h, c_: (b_, h, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b_, h, c_: (b_, h, c_, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b_, h, c_: (b_, h, c_, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b_, h, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b_, h, c_: (b_, c_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P),
                         lambda b_, h, c_: (b_, h, c_, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b_, h, c_: (b_, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), F32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), F32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, s
