"""jit'd cutout wrapper: box -> Morton plan -> gather kernel -> trim."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...core import morton
from ...core.cuboid import CuboidGrid
from .kernel import cutout_gather_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def build_plan(grid: CuboidGrid, lo: Sequence[int], hi: Sequence[int]):
    """Static part of a cutout: box-grid shape + Morton cell per position."""
    cs = grid.cuboid_shape
    glo = [l // c for l, c in zip(lo, cs)]
    ghi = [-(-h // c) for h, c in zip(hi, cs)]
    gshape = tuple(h - l for l, h in zip(glo, ghi))
    mesh_idx = np.meshgrid(*[np.arange(l, h) for l, h in zip(glo, ghi)],
                           indexing="ij")
    coords = np.stack([g.ravel() for g in mesh_idx], axis=-1)
    cells = morton.morton_encode(coords, grid.bits).astype(np.int32)
    return gshape, cells, [g * c for g, c in zip(glo, cs)]


def cutout_gather(packed, grid: CuboidGrid, lo, hi, *, interpret=None):
    """Dense cutout [lo, hi) from a cuboid-major device array."""
    lo = tuple(int(x) for x in lo)
    hi = tuple(int(x) for x in hi)
    interpret = _interpret_default() if interpret is None else interpret
    gshape, cells, alo = build_plan(grid, lo, hi)
    merged = cutout_gather_kernel(packed, jnp.asarray(cells), gshape,
                                  interpret=interpret)
    trim = tuple(slice(l - a, h - a) for l, h, a in zip(lo, hi, alo))
    return merged[trim]
