"""Cuboid->cutout assembly as a Pallas gather kernel (paper C2/C8).

The paper's §5 finding is that cutout *assembly* — not disk I/O — bounds
throughput, and that unaligned assembly (cache-hostile byte shuffles) is 2x
slower than aligned. The TPU translation: assembly = a sequence of
HBM->VMEM block copies whose source row comes from the Morton plan. The
plan (cell index per box-grid position) is a *scalar-prefetched* operand
(pltpu.PrefetchScalarGridSpec), i.e. it is available to the BlockSpec
index_map before the DMA is issued — exactly a database fetching the block
list from its spatial index (C7) and then streaming blocks.

Alignment shows up structurally: cuboid-aligned cutouts copy whole (8,128)-
tiled blocks; unaligned ones round up and trim (the wrapper does this),
paying the read-amplification the paper measures in Fig 10.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(plan_ref, packed_ref, out_ref):
    del plan_ref  # consumed by the index maps
    out_ref[...] = packed_ref[...]


def cutout_gather_kernel(packed, plan, gshape: Tuple[int, ...],
                         interpret: bool = False):
    """packed: (n_cells, cx, cy, cz); plan: (n_box,) int32 cell per box-grid
    position (row-major). Returns (gx*cx, gy*cy, gz*cz)."""
    n_cells, cx, cy, cz = packed.shape
    gx, gy, gz = gshape
    n_box = gx * gy * gz
    assert plan.shape == (n_box,)

    def in_map(g, plan_ref):
        return plan_ref[g], 0, 0, 0

    def out_map(g, plan_ref):
        # row-major decode of the box-grid position
        return g // (gy * gz), (g // gz) % gy, g % gz

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_box,),
        in_specs=[pl.BlockSpec((1, cx, cy, cz), in_map)],
        out_specs=pl.BlockSpec((cx, cy, cz), out_map),
    )
    out_shape = jax.ShapeDtypeStruct((gx * cx, gy * cy, gz * cz),
                                     packed.dtype)

    def _kern(plan_ref, packed_ref, out_ref):
        out_ref[...] = packed_ref[0]

    return pl.pallas_call(_kern, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(plan, packed)
