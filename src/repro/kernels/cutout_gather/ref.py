"""Oracle: numpy cuboid->cutout assembly via the host engine."""
import numpy as np

from ...core.cuboid import CuboidGrid
from ...core.distributed import unpack_from_cuboids


def cutout_ref(packed: np.ndarray, grid: CuboidGrid, lo, hi) -> np.ndarray:
    vol = unpack_from_cuboids(np.asarray(packed), grid)
    return vol[tuple(slice(l, h) for l, h in zip(lo, hi))]
