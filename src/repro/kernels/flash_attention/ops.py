"""Public jit'd wrapper: (B,S,H,D) layout in, kernel layout inside."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import flash_attention_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    block_q: int = 256, block_kv: int = 512,
                    interpret: Optional[bool] = None):
    """q: (B, Sq, H, D); k, v: (B, Skv, K, D) with H = K*G."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    interpret = _interpret_default() if interpret is None else interpret
    # (B,Sq,H,D) -> (B,K,G,Sq,D); k: (B,Skv,K,D) -> (B,K,Skv,D)
    qk = q.reshape(B, Sq, K, G, D).transpose(0, 2, 3, 1, 4)
    kk = k.transpose(0, 2, 1, 3)
    vk = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel(qk, kk, vk, causal=causal, scale=scale,
                                 window=window, block_q=block_q,
                                 block_kv=block_kv, interpret=interpret)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
