"""Pallas TPU flash attention (GQA, causal/window) with explicit VMEM tiling.

Grid: (B, K, nq, nkv) — kv innermost so the online-softmax state for one
query tile lives in VMEM scratch across kv steps (classic Pallas flash
layout). Query tiles carry the G grouped heads with them (GQA: each KV head
serves G query heads), so the MXU sees (G*Bq, D) x (D, Bkv) matmuls.

Causal/window tiles that are fully masked are skipped with ``pl.when`` —
the locality analogue at the schedule level: never touch blocks the query
tile cannot see.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_kv: int, n_kv: int, sq: int, skv: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    G = q_ref.shape[2]
    D = q_ref.shape[-1]

    @pl.when(kj == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_pos = (skv - sq) + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)

    live = None
    if causal:
        # tile live unless its newest q row precedes its oldest k col
        live = ((skv - sq) + (qi + 1) * block_q - 1) >= kj * block_kv
    if window is not None:
        # tile dead when even its oldest q row is past the window
        live_w = ((skv - sq) + qi * block_q) - (
            (kj + 1) * block_kv - 1) < window
        live = live_w if live is None else jnp.logical_and(live, live_w)
    if live is None:
        live = True

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].reshape(G * block_q, D)          # (G*Bq, D)
        k = k_ref[0, 0]                                  # (Bkv, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q.astype(jnp.float32) * scale, k.astype(jnp.float32),
            (((1,), (1,)), ((), ()))).reshape(G, block_q, block_kv)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        mask &= k_pos < skv                              # kv padding
        s = jnp.where(mask[None], s, NEG)

        m_prev = m_sc[...]                               # (G, Bq)
        l_prev = l_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + p.sum(axis=-1)
        m_sc[...] = m_new
        pv = jax.lax.dot_general(
            p.reshape(G * block_q, block_kv).astype(v.dtype), v,
            (((1,), (0,)), ((), ()))).reshape(G, block_q, D)
        acc_sc[...] = acc_sc[...] * corr[..., None] + pv.astype(jnp.float32)

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool, scale: float,
                           window: Optional[int] = None,
                           block_q: int = 256, block_kv: int = 512,
                           interpret: bool = False):
    """q: (B, K, G, Sq, D); k, v: (B, K, Skv, D) -> (B, K, G, Sq, D)."""
    B, K, G, Sq, D = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = -(-Sq // block_q)
    nkv = -(-Skv // block_kv)
    pad_q = nq * block_q - Sq
    pad_kv = nkv * block_kv - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))

    grid = (B, K, nq, nkv)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, n_kv=nkv, sq=Sq, skv=Skv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, block_q, D),
                         lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, block_q, D),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (B, K, G, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, block_q), jnp.float32),
            pltpu.VMEM((G, block_q), jnp.float32),
            pltpu.VMEM((G, block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :, :Sq]
