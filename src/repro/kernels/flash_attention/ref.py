"""Pure-jnp oracle for GQA flash attention (fp32 math, O(S^2) memory)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax

NEG = -1e30


def attention_ref(q, k, v, *, causal: bool, scale: float,
                  window: Optional[int] = None,
                  q_offset: Optional[int] = None) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Skv, K, D), H = K*G. fp32 throughout.

    ``q_offset``: position of q[0] relative to k[0] (defaults to Skv - Sq,
    i.e. queries at the end — matches decode/prefill conventions).
    """
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    off = Skv - Sq if q_offset is None else q_offset
    qf = q.astype(jnp.float32).reshape(B, Sq, K, G, D) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)
    q_pos = off + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(B, Sq, H, D)
