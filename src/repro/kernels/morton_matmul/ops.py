"""jit'd wrapper with padding + HBM-traffic estimator for the two schedules."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core import morton
from .kernel import morton_matmul_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "order", "interpret"))
def morton_matmul(a, b, *, block_m: int = 256, block_n: int = 256,
                  block_k: int = 256, order: str = "morton",
                  interpret=None):
    M, K = a.shape
    _, N = b.shape
    interpret = _interpret_default() if interpret is None else interpret
    pm = (-M) % block_m if M > block_m else 0
    pn = (-N) % block_n if N > block_n else 0
    pk = (-K) % block_k if K > block_k else 0
    bm = min(block_m, M + pm)
    bn = min(block_n, N + pn)
    bk = min(block_k, K + pk)
    pm = (-M) % bm
    pn = (-N) % bn
    pk = (-K) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk)))
    bp = jnp.pad(b, ((0, pk), (0, pn)))
    out = morton_matmul_kernel(ap, bp, block_m=bm, block_n=bn, block_k=bk,
                               order=order, interpret=interpret)
    return out[:M, :N]


def tile_sequence(nm: int, nn: int, order: str):
    """The (i, j) visit order for each schedule (consecutive dups removed)."""
    if order == "morton":
        bits = morton.grid_bits((nm, nn))
        raw = [tuple(morton.morton_decode(t, bits))
               for t in range(1 << morton.total_bits(bits))]
    elif order == "hilbert":
        h = max(morton.grid_bits((nm, nn)))
        xs, ys = morton.hilbert_decode_2d(np.arange(1 << (2 * h)), h)
        raw = list(zip(xs.tolist(), ys.tolist()))
    elif order == "rowmajor":
        raw = [(t // nn, t % nn) for t in range(nm * nn)]
    else:
        raise ValueError(order)
    seq = []
    for i, j in raw:
        s = (min(int(i), nm - 1), min(int(j), nn - 1))
        if not seq or s != seq[-1]:
            seq.append(s)
    return seq


def panel_traffic(nm: int, nn: int, order: str, capacity: int = 1) -> int:
    """#(A,B)-panel HBM fetches under an LRU panel cache of ``capacity``
    panels per operand.

    ``capacity=1`` models Pallas's real TPU semantics (the DMA for an
    operand is skipped iff its index_map output is unchanged from the
    previous grid step). ``capacity>1`` models an explicit multi-panel VMEM
    cache (or a GPU's shared L2 across swizzled CTAs). Findings encoded in
    the tests: Hilbert wins at capacity=1 (every step changes exactly one
    coordinate); Morton needs capacity>=2 — matching the paper's own
    Hilbert-vs-Morton trade-off discussion (§3).
    """
    seq = tile_sequence(nm, nn, order)
    from collections import OrderedDict
    a_cache, b_cache = OrderedDict(), OrderedDict()
    fetches = 0
    for i, j in seq:
        for cache, key in ((a_cache, i), (b_cache, j)):
            if key in cache:
                cache.move_to_end(key)
            else:
                fetches += 1
                cache[key] = True
                if len(cache) > capacity:
                    cache.popitem(last=False)
    return fetches
