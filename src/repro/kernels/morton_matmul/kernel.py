"""Tiled matmul whose (i, j) output-tile traversal follows the Morton curve.

Paper C1 moved one level down the memory hierarchy: the OCP cluster orders
cuboids along a z-order curve so spatially-adjacent data is adjacent on
disk; here the *grid schedule* orders output tiles along the same curve so
temporally-adjacent kernel steps touch overlapping A-row / B-column panels,
which stay resident in VMEM between steps. Row-major traversal reuses only
the A panel; z-order alternates reuse of both (2x fewer HBM panel fetches
asymptotically for square grids).

Grid: (n_tiles, nk) — nk innermost accumulates the K dimension into a VMEM
scratch. ``index_map`` decodes the Morton step -> (i, j) with pure bit ops
(jnp on traced ints, see `repro.core.morton.morton_decode_traced`).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core import morton


def _decode(t, bits: Tuple[int, int]):
    x, y = morton.morton_decode_traced(t, bits)
    return x, y


def _kernel(a_ref, b_ref, o_ref, acc_sc, *, nk: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    acc_sc[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _done():
        o_ref[...] = acc_sc[...].astype(o_ref.dtype)


def morton_matmul_kernel(a, b, *, block_m: int = 256, block_n: int = 256,
                         block_k: int = 256, order: str = "morton",
                         interpret: bool = False):
    """a: (M, K), b: (K, N) -> (M, N). ``order``: morton | rowmajor."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    if M % block_m or N % block_n or K % block_k:
        raise ValueError("dims must divide block sizes (pad first)")
    nm, nn, nk = M // block_m, N // block_n, K // block_k
    bits = morton.grid_bits((nm, nn))
    n_tiles = 1 << morton.total_bits(bits)  # pow2-padded tile count

    if order == "morton":
        def ij(t):
            i, j = _decode(t, bits)
            # clamp padded curve cells onto valid tiles (recomputed cheaply;
            # the extra cells recompute a valid tile, results identical)
            return jnp.minimum(i, nm - 1), jnp.minimum(j, nn - 1)
    elif order == "hilbert":
        # Hilbert wants a square pow2 grid: use the bounding order and
        # clamp (paper §3 picks Morton for exactly this irregularity cost)
        h_order = max(bits) if bits else 0
        n_tiles = 1 << (2 * h_order)

        def ij(t):
            i, j = morton.hilbert_decode_2d_traced(t, h_order)
            return jnp.minimum(i, nm - 1), jnp.minimum(j, nn - 1)
    elif order == "rowmajor":
        n_tiles = nm * nn

        def ij(t):
            return t // nn, t % nn
    else:
        raise ValueError(order)

    def a_map(t, kk):
        i, _ = ij(t)
        return i, kk

    def b_map(t, kk):
        _, j = ij(t)
        return kk, j

    def o_map(t, kk):
        i, j = ij(t)
        return i, j

    kern = functools.partial(_kernel, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(n_tiles, nk),
        in_specs=[pl.BlockSpec((block_m, block_k), a_map),
                  pl.BlockSpec((block_k, block_n), b_map)],
        out_specs=pl.BlockSpec((block_m, block_n), o_map),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
