"""Pallas TPU grouped expert GEMM (Megablocks-lite) for small-expert MoE.

§Perf Cell C showed small-expert MoE (granite: E=32, d_ff=512) is bound by
dispatch staging, and that capacity buffers are mostly padding (top-8 at
capacity 1.25 ⇒ up to 20% padded rows; per-expert imbalance makes real
occupancy lower). This kernel runs the three SwiGLU expert GEMMs over the
(E, C, d) capacity buffer with a grid over (expert, row-tile) and — the
Megablocks idea — **skips row-tiles beyond the expert's actual token
count** (scalar-prefetched), so padded capacity costs neither MXU cycles
nor VMEM traffic. Weights for expert e stream into VMEM once per row-tile
sweep; hidden activations never leave VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(counts_ref, x_ref, wg_ref, wu_ref, wd_ref, y_ref, *,
            block_c: int):
    e = pl.program_id(0)
    ci = pl.program_id(1)
    live = ci * block_c < counts_ref[e]

    @pl.when(live)
    def _compute():
        x = x_ref[0]                                    # (Bc, d)
        prec = jax.lax.Precision.HIGHEST
        g = jax.lax.dot_general(x, wg_ref[0],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=F32,
                                precision=prec)
        u = jax.lax.dot_general(x, wu_ref[0],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=F32,
                                precision=prec)
        h = (jax.nn.silu(g) * u).astype(x.dtype)        # (Bc, f) in VMEM
        y = jax.lax.dot_general(h, wd_ref[0],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=F32,
                                precision=prec)
        y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(jnp.logical_not(live))
    def _skip():
        y_ref[...] = jnp.zeros_like(y_ref)


def moe_gemm_kernel(x, w_gate, w_up, w_down, counts, *,
                    block_c: int = 128, interpret: bool = False):
    """x: (E, C, d); w_*: (E, d, f)/(E, f, d); counts: (E,) int32.

    Returns y: (E, C, d) — SwiGLU expert outputs; rows >= counts[e] are 0.
    """
    E, C, d = x.shape
    f = w_gate.shape[-1]
    block_c = min(block_c, C)
    nc = -(-C // block_c)
    pad = nc * block_c - C
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))

    kern = functools.partial(_kernel, block_c=block_c)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(E, nc),
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e, c, counts: (e, c, 0)),
            pl.BlockSpec((1, d, f), lambda e, c, counts: (e, 0, 0)),
            pl.BlockSpec((1, d, f), lambda e, c, counts: (e, 0, 0)),
            pl.BlockSpec((1, f, d), lambda e, c, counts: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d),
                               lambda e, c, counts: (e, c, 0)),
    )
    y = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, nc * block_c, d), x.dtype),
        interpret=interpret,
    )(counts, x, w_gate, w_up, w_down)
    return y[:, :C]
