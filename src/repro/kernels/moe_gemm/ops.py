"""Public jit'd wrapper for the grouped expert GEMM."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import moe_gemm_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def moe_gemm(x, w_gate, w_up, w_down, counts, *, block_c: int = 128,
             interpret: Optional[bool] = None):
    """Grouped SwiGLU expert GEMM over a capacity buffer.

    x: (E, C, d); w_gate/w_up: (E, d, f); w_down: (E, f, d);
    counts: (E,) int32 actual tokens per expert. Row-tiles past counts[e]
    are skipped on the MXU (Megablocks-style padding elision).
    """
    interpret = _interpret_default() if interpret is None else interpret
    counts = jnp.asarray(counts, jnp.int32)
    return moe_gemm_kernel(x, w_gate, w_up, w_down, counts,
                           block_c=block_c, interpret=interpret)
