from .ops import moe_gemm
from .ref import moe_gemm_ref
