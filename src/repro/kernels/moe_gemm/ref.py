"""Pure-jnp oracle: dense SwiGLU expert GEMMs with count masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def moe_gemm_ref(x, w_gate, w_up, w_down, counts):
    """x: (E, C, d); counts: (E,). Rows >= counts[e] output zero."""
    g = jnp.einsum("ecd,edf->ecf", x.astype(F32), w_gate.astype(F32),
                   precision="highest")
    u = jnp.einsum("ecd,edf->ecf", x.astype(F32), w_up.astype(F32),
                   precision="highest")
    # h rounds to the working dtype, mirroring models/moe.py (and the
    # kernel's VMEM layout)
    h = (jax.nn.silu(g) * u).astype(x.dtype).astype(F32)
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(F32),
                   precision="highest")
    C = x.shape[1]
    mask = jnp.arange(C)[None, :] < counts[:, None]      # (E, C)
    return jnp.where(mask[..., None], y, 0.0).astype(x.dtype)
