"""Public jit'd wrapper matching models.layers.decode_attention semantics."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_decode_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "block_kv",
                                             "interpret"))
def flash_decode(q, k_cache, v_cache, cache_len, *, scale: float,
                 block_kv: int = 512,
                 interpret: Optional[bool] = None):
    """q: (B, 1, H, D); caches: (B, S, K, D); cache_len: scalar or (B,).

    Single-token GQA attention against a cache; positions >= cache_len are
    masked. Returns (B, 1, H, D) in q.dtype.
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, _, H, D = q.shape
    _, S, K, _ = k_cache.shape
    G = H // K
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                            (B,))
    qk = q.reshape(B, K, G, D)
    kk = k_cache.transpose(0, 2, 1, 3)           # (B, K, S, D)
    vk = v_cache.transpose(0, 2, 1, 3)
    out = flash_decode_kernel(qk, kk, vk, lens, scale=scale,
                              block_kv=block_kv, interpret=interpret)
    return out.reshape(B, 1, H, D)
