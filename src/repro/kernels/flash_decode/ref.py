"""Oracle: the model's own jnp decode_attention (fp32 softmax, O(S) HBM)."""
from __future__ import annotations

from ...models.layers import decode_attention as decode_ref  # noqa: F401
