from .ops import flash_decode
from .ref import decode_ref
