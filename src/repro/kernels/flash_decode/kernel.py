"""Pallas TPU flash-decode: one-token GQA attention against a KV cache.

The §Perf Cell B analysis showed optimized decode is bound by cache reads
plus fp32 staging of scores/softmax in HBM. This kernel streams the cache
through VMEM in blocks with the online-softmax state (m, l, acc) resident
in VMEM scratch — the only HBM traffic is one pass over K and V plus the
(G, D) output, the read floor.

Grid: (B, K, nkv) — cache blocks innermost and sequential. The current
cache length arrives via scalar prefetch (SMEM) so masking is dynamic
without retracing per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale: float, block_kv: int, n_kv: int, skv: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    G = q_ref.shape[2]
    D = q_ref.shape[-1]

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0].astype(F32) * scale                  # (G, D)
    kb = k_ref[0, 0].astype(F32)                         # (Bkv, D)
    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())))  # (G, Bkv)

    n_valid = lens_ref[b]
    pos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, (G, block_kv),
                                                  1)
    s = jnp.where((pos < n_valid) & (pos < skv), s, NEG)

    m_prev = m_sc[...]                                   # (G, 1)
    l_prev = l_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                               # (G, Bkv)
    corr = jnp.exp(m_prev - m_new)                       # (G, 1)
    l_sc[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
    m_sc[...] = m_new
    vb = v_ref[0, 0]                                     # (Bkv, D)
    pv = jax.lax.dot_general(p.astype(vb.dtype), vb,
                             (((1,), (0,)), ((), ())))   # (G, D)
    acc_sc[...] = acc_sc[...] * corr + pv.astype(F32)

    @pl.when(j == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l).astype(o_ref.dtype)


def flash_decode_kernel(q, k, v, lens, *, scale: float,
                        block_kv: int = 512, interpret: bool = False):
    """q: (B, K, G, D); k, v: (B, K, Skv, D); lens: (B,) int32.

    Returns (B, K, G, D) attention output in q.dtype.
    """
    B, K, G, D = q.shape
    Skv = k.shape[2]
    block_kv = min(block_kv, Skv)
    nkv = -(-Skv // block_kv)
    pad = nkv * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    kern = functools.partial(_kernel, scale=scale, block_kv=block_kv,
                             n_kv=nkv, skv=Skv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, j, lens: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, j, lens: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), F32),
            pltpu.VMEM((G, 1), F32),
            pltpu.VMEM((G, D), F32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(lens, q, k, v)
