"""Pallas TPU kernels for the compute hot spots.

Each kernel package has: ``kernel.py`` (pl.pallas_call + explicit BlockSpec
VMEM tiling), ``ops.py`` (jit'd public wrapper), ``ref.py`` (pure-jnp
oracle). Kernels run in interpret mode on CPU (tests) and compiled on TPU.

  flash_attention -- fused GQA attention (train/prefill hot spot)
  morton_matmul   -- matmul whose grid walks (i,j) tiles in Morton order:
                     the paper's space-filling-curve locality (C1) applied
                     to MXU supertiles / VMEM block reuse
  cutout_gather   -- cuboid->dense cutout assembly (C2/C8) as aligned VMEM
                     block copies driven by a scalar-prefetched Morton plan
"""
