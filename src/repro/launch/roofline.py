"""Roofline-term extraction from a compiled dry-run artifact.

  compute   = HLO_FLOPs / (chips * peak_FLOP/s)
  memory    = HLO_bytes / (chips * HBM_bw)
  collective= collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
NOT there, so we parse the post-SPMD HLO text and sum the result-shape
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Hardware: TPU v5e-class constants (assignment).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

# hardware constants (per chip), from the assignment
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s/link ICI

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %x = bf16[8,128,512]{2,1,0} all-gather(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")[\s.(]")
_TUPLE_RE = re.compile(
    r"=\s*\(\s*(.*?)\)\s+(" + "|".join(_COLLECTIVES) + r")[\s.(]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective opcode over the HLO module.

    Async collectives appear as ``-start``/``-done`` pairs: count only the
    start (the done would double-count). Result shapes are per-device
    (post-SPMD), so these are bytes moved through each device's links.
    """
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        opcode = None
        for c in _COLLECTIVES:
            if (f" {c}(" in line or f" {c}-start(" in line):
                opcode = c
                break
        if opcode is None or f" {opcode}-done(" in line:
            continue
        marker = (f" {opcode}-start(" if f" {opcode}-start(" in line
                  else f" {opcode}(")
        lhs = line.split(marker)[0]
        if "=" not in lhs:
            continue
        shapes_part = lhs.split("=", 1)[1]
        total = 0
        for dtype, dims in _SHAPE_RE.findall(shapes_part):
            if dtype in _DTYPE_BYTES:
                total += _shape_bytes(dtype, dims)
        out[opcode] += total
    return out


@dataclasses.dataclass
class Roofline:
    """All raw quantities are PER-DEVICE (the post-SPMD module is the
    per-device program; verified empirically: a 2MKN matmul on 256 devices
    reports flops/256 from compiled.cost_analysis()). The assignment's
    ``HLO_FLOPs / (chips x peak)`` with whole-job FLOPs is identical to
    ``per_device_FLOPs / peak``."""
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: Dict[str, int]   # per-opcode collective bytes (per device)
    n_chips: int
    model_flops: float = 0.0     # 6*N*D analytical (whole job)
    per_device_peak_memory: float = 0.0

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.total_coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful work time / achievable step time (max of terms)."""
        denom = max(self.t_compute, self.t_memory, self.t_collective)
        ideal = self.model_flops / (self.n_chips * PEAK_FLOPS)
        return ideal / denom if denom else 0.0

    def row(self) -> Dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops": self.flops,
            "hlo_bytes": self.hbm_bytes,
            "collective_bytes": self.total_coll_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_mem_per_device": self.per_device_peak_memory,
        }


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); decode: per new
    token D = global_batch."""
    from ..models import build_model, count_params
    from ..models.params import is_spec
    import jax

    model = build_model(cfg)
    specs = model.specs()
    n_total = count_params(specs)
    if cfg.family == "moe":
        # active = total - (inactive expert fraction)
        leaves = jax.tree.leaves(specs, is_leaf=is_spec)
        expert_params = sum(
            int(np.prod(s.shape)) for s in leaves
            if "experts" in (s.axes or ()))
        n_active = (n_total - expert_params
                    + expert_params * cfg.top_k / cfg.n_experts)
    else:
        n_active = n_total
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(compiled, cfg, shape, n_chips: int,
            hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    try:
        mem = compiled.memory_analysis()
        peak = float(getattr(mem, "temp_size_in_bytes", 0)
                     + getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = 0.0
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    n_chips=n_chips,
                    model_flops=model_flops_estimate(cfg, shape),
                    per_device_peak_memory=peak)
