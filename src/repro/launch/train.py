"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt

``--smoke`` selects the reduced per-arch config so the driver runs on one
CPU device; the same code path drives the production mesh on real hardware
(mesh selection + plan resolution are config, not code).

The driver wires every substrate together: Morton-sharded data pipeline ->
jit'd train_step under the sharding plan -> async cuboid-chunked
checkpoints -> supervisor (failure recovery + straggler monitor).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..data import DataPipeline, PipelineConfig, TokenStore
from ..ft import FailureInjector, StragglerMonitor, TrainingSupervisor
from ..models import build_model, init_params
from ..optim import AdamWConfig, adamw_init_specs
from ..train import make_train_step, use_plan, make_plan
from .mesh import make_local_mesh, make_production_mesh


def init_opt_state(model_specs, rng):
    from ..models.params import init_params as ip
    specs = adamw_init_specs(model_specs)
    # master starts as a copy of params; mu/nu zeros
    return ip(specs, rng)


def build_state(cfg, seed: int = 0):
    model = build_model(cfg)
    specs = model.specs()
    params = init_params(specs, jax.random.key(seed))
    opt = init_opt_state(specs, jax.random.key(seed + 1))
    opt["master"] = jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return model, params, opt


def synthetic_corpus(cfg, n_docs=256, doc_len=1024, seed=0) -> TokenStore:
    """A Zipf-ish synthetic corpus through the Morton token store."""
    rng = np.random.default_rng(seed)
    store = TokenStore(n_docs, doc_len, cuboid=(16, min(4096, doc_len)))
    ranks = np.arange(1, cfg.vocab + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(cfg.vocab, size=(n_docs, doc_len), p=probs)
    store.ingest_corpus(toks)
    return store


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    mesh = make_local_mesh() if args.smoke else make_production_mesh()
    plan = make_plan(mesh)
    model, params, opt = build_state(cfg)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=5,
                          total_steps=args.steps,
                          grad_compression=args.grad_compression)
    step_fn_raw = make_train_step(model, cfg, opt_cfg,
                                  n_microbatches=args.microbatches)
    jit_step = jax.jit(step_fn_raw, donate_argnums=(0, 1))

    store = synthetic_corpus(cfg, doc_len=args.seq_len + 1 + 64)
    pipe = DataPipeline(store, PipelineConfig(
        seq_len=args.seq_len, global_batch=args.batch))

    losses = []
    monitor = StragglerMonitor(n_workers=1)

    def one_step(state, step):
        params, opt = state
        t0 = time.perf_counter()
        batch = pipe.get_batch(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.frontend == "patch_stub":
            B = batch["tokens"].shape[0]
            rng = np.random.default_rng(step)
            batch["embeds"] = jnp.asarray(rng.normal(size=(
                B, cfg.n_frontend_tokens, cfg.d_model)), jnp.bfloat16)
        if cfg.family == "encdec":
            B, S = batch["tokens"].shape
            rng = np.random.default_rng(step)
            batch["frames"] = jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16)
        with use_plan(plan):
            params, opt, metrics = jit_step(params, opt, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.record(0, time.perf_counter() - t0)
        if step % 5 == 0:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        return (params, opt)

    state = (params, opt)
    if args.ckpt_dir:
        injector = None
        if args.inject_failure_at is not None:
            injector = FailureInjector({args.inject_failure_at: 0})
        sup = TrainingSupervisor(args.ckpt_dir,
                                 ckpt_every=args.ckpt_every,
                                 injector=injector)
        state = sup.run(
            state, one_step, args.steps,
            state_to_tree=lambda s: {"params": s[0], "opt": s[1]},
            tree_to_state=lambda t, s: (
                jax.tree.map(jnp.asarray, t["params"]),
                jax.tree.map(jnp.asarray, t["opt"])))
        if sup.recovery_log:
            print("recoveries:", sup.recovery_log)
    else:
        for s in range(args.steps):
            state = one_step(state, s)
    pipe.stop()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return {"losses": losses}


if __name__ == "__main__":
    main()
