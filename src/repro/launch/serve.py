"""Batched serving driver: prefill + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import build_model, init_params
from ..models.params import ParamSpec
from ..serve import make_serve_step
from ..train import make_plan, use_plan
from .mesh import make_local_mesh, make_production_mesh


def zero_cache(model, cfg, B, cache_len):
    if cfg.family == "encdec":
        specs = model.cache_specs(B, cache_len, enc_len=cache_len)
    else:
        specs = model.cache_specs(B, cache_len)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="slot-based continuous batching engine")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    mesh = make_local_mesh() if args.smoke else make_production_mesh()
    plan = make_plan(mesh)
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(0))
    serve_step = jax.jit(make_serve_step(model, cfg))

    B = args.batch
    cache_len = args.prompt_len + args.gen
    rng = np.random.default_rng(0)
    if args.continuous:
        from ..serve import ContinuousBatcher, Request
        eng = ContinuousBatcher(model, cfg, params, n_slots=B,
                                cache_len=cache_len)
        n_req = 2 * B + 1           # backlog > slots: slots must recycle
        with use_plan(plan):
            t0 = time.perf_counter()
            for rid in range(n_req):
                plen = int(rng.integers(4, args.prompt_len + 1))
                eng.submit(Request(rid, rng.integers(
                    0, cfg.vocab, size=plen).tolist(), args.gen))
            done = eng.run()
            dt = time.perf_counter() - t0
        total = sum(len(v) for v in done.values())
        print(f"continuous batching: {len(done)} requests over {B} slots")
        print(f"occupancy {eng.occupancy:.2f}, "
              f"{total / dt:.1f} gen tok/s (CPU, smoke scale)")
        return done
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       size=(B, args.prompt_len)),
                          jnp.int32)
    cache = zero_cache(model, cfg, B, cache_len)
    with use_plan(plan):
        # prefill by stepping the prompt (batched requests share steps)
        tok = prompts[:, :1]
        t0 = time.perf_counter()
        for i in range(args.prompt_len):
            nxt, logits, cache = serve_step(params, cache,
                                            prompts[:, i:i + 1],
                                            jnp.int32(i))
        generated = [nxt]
        for j in range(args.gen - 1):
            nxt, logits, cache = serve_step(
                params, cache, generated[-1],
                jnp.int32(args.prompt_len + j))
            generated.append(nxt)
        jax.block_until_ready(generated[-1])
        dt = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    total_tokens = B * (args.prompt_len + args.gen - 1)
    print(f"served {B} sequences, {args.gen} new tokens each")
    print(f"throughput {total_tokens / dt:.1f} tok/s (CPU, smoke scale)")
    print("sample:", np.asarray(out[0])[:12].tolist())
    return out


if __name__ == "__main__":
    main()
