"""Dry-run input specs: ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation. ``input_specs`` returns the
abstract argument tuple matching the lowered step for (arch x shape):
train -> train_step(params, opt, batch); prefill -> prefill(params, ...);
decode -> serve_step(params, cache, token, index).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import build_model
from ..models.config import ModelConfig, ShapeConfig
from ..models.params import ParamSpec, tree_map_specs
from ..optim import adamw_init_specs
from ..train.sharding import ShardingPlan, batch_pspec, resolve_leaf

INT = jnp.int32


def _sds(shape, dtype, plan: ShardingPlan, pspec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(plan.mesh, pspec))


def abstract_sharded_params(specs, plan: ShardingPlan):
    def one(s: ParamSpec):
        return _sds(s.shape, jnp.dtype(s.dtype), plan,
                    resolve_leaf(s, plan))
    return tree_map_specs(one, specs)


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig
                       ) -> Tuple[bool, str]:
    """DESIGN.md §5: long_500k only for sub-quadratic families."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch at 524k context "
                       "(KV cache O(S) per token, attention O(S^2))")
    return True, ""


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan
                ) -> Dict:
    """Training-batch abstract inputs."""
    B, S = shape.global_batch, shape.seq_len
    bp = batch_pspec(plan, 2, B)
    out = {}
    if cfg.family == "encdec":
        # enc frames = S (stub audio), teacher-forced text = S // 4
        Sd = max(S // 4, 16)
        out["frames"] = _sds((B, S, cfg.d_model), jnp.bfloat16, plan,
                             batch_pspec(plan, 3, B))
        out["tokens"] = _sds((B, Sd), INT, plan, bp)
        out["labels"] = _sds((B, Sd), INT, plan, bp)
    elif cfg.frontend == "patch_stub":
        St = S - cfg.n_frontend_tokens
        out["embeds"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                             jnp.bfloat16, plan, batch_pspec(plan, 3, B))
        out["tokens"] = _sds((B, St), INT, plan, bp)
        out["labels"] = _sds((B, St), INT, plan, bp)
    else:
        out["tokens"] = _sds((B, S), INT, plan, bp)
        out["labels"] = _sds((B, S), INT, plan, bp)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan):
    """(cache, token, index) abstract inputs for serve_step."""
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        cache_specs = model.cache_specs(B, S, enc_len=min(S, 4096))
    else:
        cache_specs = model.cache_specs(B, S)
    cache = abstract_sharded_params(cache_specs, plan)
    token = _sds((B, 1), INT, plan, batch_pspec(plan, 2, B))
    index = jax.ShapeDtypeStruct((), INT)
    return cache, token, index


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan):
    B, S = shape.global_batch, shape.seq_len
    bp = batch_pspec(plan, 2, B)
    if cfg.family == "encdec":
        return (_sds((B, S, cfg.d_model), jnp.bfloat16, plan,
                     batch_pspec(plan, 3, B)),)
    if cfg.frontend == "patch_stub":
        return (_sds((B, S - cfg.n_frontend_tokens), INT, plan, bp),
                _sds((B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16,
                     plan, batch_pspec(plan, 3, B)))
    return (_sds((B, S), INT, plan, bp),)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan,
                with_optimizer: bool = True, opt_cfg=None):
    """Full abstract argument tuple for the lowered step of this cell."""
    model = build_model(cfg)
    params = abstract_sharded_params(model.specs(), plan)
    if shape.kind == "train":
        args = [params]
        if with_optimizer:
            state_dtype = (opt_cfg.state_dtype if opt_cfg is not None
                           else "float32")
            args.append(abstract_sharded_params(
                adamw_init_specs(model.specs(), state_dtype), plan))
        args.append(batch_specs(cfg, shape, plan))
        return tuple(args)
    if shape.kind == "prefill":
        return (params,) + prefill_specs(cfg, shape, plan)
    cache, token, index = decode_specs(cfg, shape, plan)
    return (params, cache, token, index)
