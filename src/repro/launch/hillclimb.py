import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing harness: re-lower a dry-run cell under a named
variant (config / sharding-rule / loss changes), re-derive the roofline
terms, and append the comparison to perf_log.json.

Each variant is a HYPOTHESIS (EXPERIMENTS.md §Perf records the napkin math
and the verdict); this file is only the measurement mechanism.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch llama3-405b \
      --shape train_4k --variant baseline --variant loss_onehot ...
"""
import argparse
import dataclasses
import json
import time
from typing import Callable, Dict, Optional

import jax

from ..configs import get_config
from ..models import build_model
from ..models.config import SHAPES
from ..optim import AdamWConfig
from ..serve import make_prefill_step, make_serve_step
from ..train import make_train_step
from ..train.sharding import default_rules, make_plan, use_plan
from .dryrun import extrapolated_cost
from .mesh import make_production_mesh
from .roofline import Roofline, model_flops_estimate
from .specs import input_specs


@dataclasses.dataclass
class Variant:
    name: str
    cfg_overrides: Dict = dataclasses.field(default_factory=dict)
    rules_fn: Optional[Callable] = None      # mutate rules dict in place
    loss_impl: str = "gather"
    n_microbatches: int = 1
    opt_overrides: Dict = dataclasses.field(default_factory=dict)
    hypothesis: str = ""


def _rules_batch_over_model(rules):
    """Small-model variant: no TP — shard batch over BOTH mesh axes and
    replicate weights over model (pure DP; avoids replicated attention
    when head counts don't divide the TP axis)."""
    for k in ("act_batch", "batch"):
        rules[k] = [("data", "model"), ("data",), None]
    for k in ("heads", "kv_heads", "ff", "vocab", "experts", "rnn",
              "inner", "act_heads", "act_ff", "act_vocab"):
        rules[k] = [None]
    return rules


def _rules_seq_shard_cache(rules):
    """Decode variant: force sequence-sharded KV cache."""
    rules["kv_len"] = [("model",), None]
    rules["kv_heads_cache"] = [None]
    return rules


def _rules_seq_parallel(rules):
    """Megatron-SP: activations sharded over `model` on the SEQUENCE dim
    at layer boundaries (where ops are elementwise over S), all-gathered
    inside attention/mlp by GSPMD. Saved-for-backward residuals shrink
    model_par-fold; the price is per-layer all-gather/reduce-scatter pairs
    that were already implied by the TP weight layout."""
    rules["act_seq"] = [("model",), None]
    return rules


def _rules_ep_replicated(rules):
    """MoE variant: replicate the experts, keep tokens local.

    EP (experts over `model`) pays an all-to-all on every layer's dispatch
    + return. When the per-layer expert weights are small (granite: ~100MB
    bf16 for all 32 experts), replicating them and routing locally deletes
    the dispatch collective entirely — EP is the wrong parallelism for
    small-expert MoE at 256 chips."""
    rules["experts"] = [None]
    rules["act_experts"] = [None]
    return rules


def _rules_weight_stationary(rules):
    """Decode variant: weights stay put, activations move.

    The default decode layout shards the batch over `data` and FSDP-shards
    weights over `data` too — so every matmul must all-gather its weight
    shard (O(params) ICI bytes per token). Here the batch is REPLICATED,
    weights stay sharded over (`data` on embed) x (`model` on heads/ff),
    and every contraction produces an activation-sized partial reduced
    over `data` — O(batch x d) bytes instead of O(params)."""
    for k in ("batch", "act_batch"):
        rules[k] = [None]
    rules["embed"] = [("data",), None]
    rules["kv_len"] = [("data",), None]       # cache sequence-sharded
    rules["kv_heads_cache"] = [None]
    rules["heads_cache"] = [("model",), None]
    return rules


def _rules_weight_stationary2(rules):
    """weight_stationary, iteration 2: the KV cache's 8 kv-heads cannot
    shard over model=16, so v1 left the cache only 16-way sharded (137
    GB/device — doesn't fit) and its reads doubled the memory term.
    Shard kv_len over BOTH mesh axes (32768/256 = 128 rows/device): cache
    back to 8.5 GB/device, attention psums over the full mesh."""
    rules = _rules_weight_stationary(rules)
    rules["kv_len"] = [("data", "model"), None]
    rules["heads_cache"] = [None]
    return rules


VARIANTS = {
    "baseline": Variant("baseline"),
    "loss_onehot": Variant(
        "loss_onehot", loss_impl="onehot",
        hypothesis="vocab-sharded CE removes the (B,S,V) logits "
                   "all-gather: collective and HBM terms drop"),
    "no_remat": Variant(
        "no_remat", cfg_overrides={"remat": "none"},
        hypothesis="recompute-free bwd: compute term drops ~25%, memory "
                   "(activations) rises"),
    "dp_only": Variant(
        "dp_only", rules_fn=_rules_batch_over_model,
        hypothesis="for models whose heads don't divide TP=16, pure-DP "
                   "batch sharding over 256 devices removes replicated "
                   "attention compute"),
    "seq_cache": Variant(
        "seq_cache", rules_fn=_rules_seq_shard_cache,
        hypothesis="sequence-sharded KV cache parallelizes decode "
                   "attention over the model axis at psum cost"),
    "microbatch4": Variant(
        "microbatch4", n_microbatches=4,
        hypothesis="4 microbatches cut activation memory ~4x; compute "
                   "unchanged; collective unchanged (grads reduced once)"),
    "big_blocks": Variant(
        "big_blocks", cfg_overrides={"attn_block_q": 1024,
                                     "attn_block_kv": 2048},
        hypothesis="bigger attention tiles reduce online-softmax "
                   "rescaling traffic per flop"),
    "remat_dots": Variant(
        "remat_dots", cfg_overrides={"remat": "dots"},
        hypothesis="save matmul outputs, recompute only elementwise in "
                   "bwd: compute term drops ~25% vs block remat, "
                   "activation memory stays far below remat=none"),
    "fused_kv": Variant(
        "fused_kv", cfg_overrides={"fused_prefill_kv": True},
        hypothesis="prefill builds the decode cache from the forward "
                   "pass's K/V projections: removes one full K/V "
                   "projection pass (compute + HBM)"),
    "weight_stationary": Variant(
        "weight_stationary", rules_fn=_rules_weight_stationary,
        hypothesis="decode: replicate the (tiny) batch, keep weights "
                   "sharded; collectives become activation-sized "
                   "partial-reductions instead of O(params) weight "
                   "all-gathers"),
    "int8_grads": Variant(
        "int8_grads", opt_overrides={"grad_compression": "int8"},
        hypothesis="int8(+error feedback) gradient all-reduce quarters "
                   "the gradient-reduction collective bytes vs fp32"),
    "weight_stationary2": Variant(
        "weight_stationary2", rules_fn=_rules_weight_stationary2,
        hypothesis="v1 + kv cache sharded over the full 256 (kv_len over "
                   "both axes): cache reads /16, memory term back below "
                   "baseline while keeping the collective win"),
    "local_dispatch": Variant(
        "local_dispatch", cfg_overrides={"moe_dispatch": "local"},
        hypothesis="shard_map per-device MoE dispatch: the token->expert "
                   "scatter never crosses devices, deleting the all-to-all "
                   "AND the buffer replication; expert weights all-gather "
                   "over DP (ordinary FSDP traffic) instead"),
    "local_dispatch_cap1": Variant(
        "local_dispatch_cap1",
        cfg_overrides={"moe_dispatch": "local", "capacity_factor": 1.0},
        hypothesis="local dispatch + capacity 1.0: buffer rows and expert "
                   "GEMM flops drop 20% at slightly higher drop rate"),
    "ep_replicated": Variant(
        "ep_replicated", rules_fn=_rules_ep_replicated,
        hypothesis="replicating small expert weights deletes the per-layer "
                   "dispatch all-to-all; collective term drops to the "
                   "gradient reduction only"),
    "padded_vocab": Variant(
        "padded_vocab", cfg_overrides={"vocab": 49408},
        hypothesis="granite's vocab 49155 is indivisible by 16 so logits "
                   "replicate over `model`; padding to 49408 (=16*3088) "
                   "restores vocab sharding: (B,S,V) memory/collective "
                   "drops ~16x at +0.5% flops"),
    "seq_parallel": Variant(
        "seq_parallel", rules_fn=_rules_seq_parallel,
        hypothesis="sequence-parallel activations at layer boundaries: "
                   "saved residuals (126 x 2.15GB for llama3-405b) shard "
                   "16x over model; temp memory drops toward fitting"),
    "micro16": Variant(
        "micro16", n_microbatches=16,
        hypothesis="16 microbatches: activation temp ~ /16, compute and "
                   "collectives unchanged (grads reduced once)"),
    "bf16_states": Variant(
        "bf16_states", opt_overrides={"state_dtype": "bfloat16"},
        hypothesis="bf16 AdamW moments: optimizer args drop from 12 to 8 "
                   "bytes/param and the update's f32 temp copies halve"),
    "llama_fit": Variant(
        "llama_fit", loss_impl="onehot", n_microbatches=16,
        opt_overrides={"state_dtype": "bfloat16"},
        rules_fn=_rules_seq_parallel,
        hypothesis="fit stack: 16x microbatch + SP residuals + bf16 "
                   "moments + vocab-sharded CE"),
    "dots_micro16": Variant(
        "dots_micro16", cfg_overrides={"remat": "dots"}, n_microbatches=16,
        hypothesis="remat=dots cut the compute+memory terms 11% but grew "
                   "temp 1.8x; 16x microbatching absorbs the temp growth "
                   "(saved dots are per-microbatch)"),
    "llama_combo": Variant(
        "llama_combo", loss_impl="onehot", n_microbatches=16,
        rules_fn=_rules_seq_parallel,
        hypothesis="compose: SP residuals + 16x microbatching + vocab-"
                   "sharded CE -> per-device temp under 16GB HBM"),
    "granite_combo": Variant(
        "granite_combo", loss_impl="onehot",
        cfg_overrides={"moe_dispatch": "local", "vocab": 49408},
        hypothesis="compose the three independent fixes: local dispatch "
                   "(no replicated (Tk,d) staging), padded vocab 49408 "
                   "(logits shard over model), onehot CE (logits stay "
                   "sharded through the loss)"),
    "onehot_micro4": Variant(
        "onehot_micro4", loss_impl="onehot", n_microbatches=4,
        hypothesis="compose the two confirmed train wins: vocab-sharded "
                   "CE + 4x microbatching"),
}


def run_variant(arch: str, shape_name: str, variant: Variant,
                multi_pod: bool = False) -> Dict:
    cfg = get_config(arch)
    if variant.cfg_overrides:
        cfg = cfg.scaled(**variant.cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(multi_pod)
    if variant.rules_fn is not None:
        rules = variant.rules_fn(rules)
    plan = make_plan(mesh, rules=rules)

    model = build_model(cfg)
    opt_cfg = AdamWConfig(**variant.opt_overrides)
    if shape.kind == "train":
        step = make_train_step(model, cfg, opt_cfg,
                               n_microbatches=variant.n_microbatches,
                               loss_impl=variant.loss_impl)
    elif shape.kind == "prefill":
        step = make_prefill_step(model, cfg)
    else:
        step = make_serve_step(model, cfg)
    args = input_specs(cfg, shape, plan, opt_cfg=opt_cfg)
    t0 = time.time()
    from .dryrun import donate_for
    with mesh, use_plan(plan):
        compiled = jax.jit(step, donate_argnums=donate_for(shape)) \
            .lower(*args).compile()
        mem = compiled.memory_analysis()
    f, b, coll, _ = extrapolated_cost(cfg, shape, plan, mesh)
    roof = Roofline(flops=f, hbm_bytes=b, coll_bytes=coll,
                    n_chips=mesh.size,
                    model_flops=model_flops_estimate(cfg, shape))
    row = roof.row()
    row.update(variant=variant.name, arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               hypothesis=variant.hypothesis,
               wall_s=round(time.time() - t0, 1),
               temp_bytes=getattr(mem, "temp_size_in_bytes", None))
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--variant", action="append", required=True,
                    choices=list(VARIANTS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="perf_log.json")
    args = ap.parse_args()
    rows = []
    for vname in args.variant:
        row = run_variant(args.arch.replace("-", "_"), args.shape,
                          VARIANTS[vname], multi_pod=args.multi_pod)
        rows.append(row)
        print(f"[{row['arch']}/{row['shape']}/{vname}] "
              f"bottleneck={row['bottleneck']} "
              f"t=(c {row['t_compute_s']:.3e}, m {row['t_memory_s']:.3e}, "
              f"x {row['t_collective_s']:.3e}) "
              f"frac={row['roofline_fraction']:.3f}", flush=True)
    log = []
    if os.path.exists(args.out):
        with open(args.out) as fh:
            log = json.load(fh)
    log.extend(rows)
    with open(args.out, "w") as fh:
        json.dump(log, fh, indent=1)
    print(f"appended {len(rows)} rows to {args.out}")


if __name__ == "__main__":
    main()
