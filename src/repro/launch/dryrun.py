import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import (jax locks the device count
on first init); this module is the only place the 512 placeholder devices
exist — tests and benches see the single real CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""
import argparse
import json
import sys
import time
import traceback
from typing import Dict

import jax

from ..configs import ARCH_IDS, get_config
from ..models import build_model
from ..models.config import SHAPES
from ..optim import AdamWConfig
from ..serve import make_prefill_step, make_serve_step
from ..train import make_train_step
from ..train.sharding import make_plan
from .mesh import make_production_mesh
from .roofline import analyze, collective_bytes
from .specs import cell_is_applicable, input_specs


def build_step(cfg, shape):
    model = build_model(cfg)
    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        return make_train_step(model, cfg, opt_cfg)
    if shape.kind == "prefill":
        return make_prefill_step(model, cfg)
    return make_serve_step(model, cfg)


def donate_for(shape) -> tuple:
    """Production buffer donation: train donates (params, opt) — the step
    returns their successors; decode donates the KV cache (in-place
    update). Without donation memory_analysis double-counts these."""
    if shape.kind == "train":
        return (0, 1)
    if shape.kind == "decode":
        return (1,)
    return ()


def depth_variant(cfg, n_layers: int):
    """Same width, reduced depth, layers UNROLLED (a lax.scan body is
    counted once by cost_analysis whatever its trip count, so the variants
    must not scan for the per-layer delta to be observable)."""
    kw = {"n_layers": n_layers, "scan_layers": False,
          # unrolled attention blocks with static skipping: what the flash
          # kernel actually executes, visible to cost_analysis
          "attn_unroll": True,
          "attn_block_q": 2048, "attn_block_kv": 2048}
    if cfg.family == "encdec":
        kw.update(n_enc_layers=n_layers, n_dec_layers=n_layers)
    return cfg.scaled(**kw)


def _cost_tuple(cfg, shape, plan, mesh):
    """(flops, bytes, coll_dict) per device from one lower+compile."""
    from ..train.sharding import use_plan
    step = build_step(cfg, shape)
    args = input_specs(cfg, shape, plan)
    with mesh, use_plan(plan):
        compiled = jax.jit(step, donate_argnums=donate_for(shape)) \
            .lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            collective_bytes(compiled.as_text()))


def extrapolated_cost(cfg, shape, plan, mesh):
    """XLA's cost_analysis counts a lax.scan body ONCE regardless of trip
    count (verified empirically). Compile two reduced-depth variants at
    full width and extrapolate linearly to the real depth:
        cost(L) = cost(L1) + (L - L1) * (cost(L2) - cost(L1)) / (L2 - L1).
    Exact because every scan iteration is the identical program."""
    plen = len(cfg.hybrid_pattern) if cfg.family == "hybrid" else 1
    L = cfg.n_layers
    L1, L2 = plen, 2 * plen
    if L <= L2:  # shallow smoke-scale config: just measure directly
        f, b, c = _cost_tuple(cfg, shape, plan, mesh)
        return f, b, c, False
    f1, b1, c1 = _cost_tuple(depth_variant(cfg, L1), shape, plan, mesh)
    f2, b2, c2 = _cost_tuple(depth_variant(cfg, L2), shape, plan, mesh)
    k = (L - L1) / (L2 - L1)
    f = f1 + (f2 - f1) * k
    b = b1 + (b2 - b1) * k
    coll = {key: int(c1.get(key, 0)
                     + (c2.get(key, 0) - c1.get(key, 0)) * k)
            for key in set(c1) | set(c2)}
    return f, b, coll, True


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        cell["status"] = "skipped"
        cell["reason"] = why
        return cell
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(mesh)
    step = build_step(cfg, shape)
    args = input_specs(cfg, shape, plan)
    from ..train.sharding import use_plan
    with mesh, use_plan(plan):
        lowered = jax.jit(step, donate_argnums=donate_for(shape)).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        roof = analyze(compiled, cfg, shape, n_chips=mesh.size)
    # scan-aware cost correction (see extrapolated_cost)
    f, b, coll, extrap = extrapolated_cost(cfg, shape, plan, mesh)
    roof.flops, roof.hbm_bytes, roof.coll_bytes = f, b, coll
    cell.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "cost_extrapolated": extrap,
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "roofline": roof.row(),
        "coll_breakdown": roof.coll_bytes,
    })
    if verbose:
        r = roof.row()
        print(f"[{arch} x {shape_name} x {mesh_name}] OK "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
              f"bottleneck={r['bottleneck']} "
              f"t=(c {r['t_compute_s']:.2e}, m {r['t_memory_s']:.2e}, "
              f"x {r['t_collective_s']:.2e}) "
              f"useful={r['useful_ratio']:.2f} "
              f"roofline={r['roofline_fraction']:.2f}", flush=True)
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        targets = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        if not args.arch:
            ap.error("--arch or --all required")
        shapes = [args.shape] if args.shape else list(SHAPES)
        targets = [(args.arch.replace("-", "_"), s) for s in shapes]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape in targets:
        for mp in meshes:
            try:
                cells.append(run_cell(arch, shape, mp))
            except Exception as e:  # record, keep going
                failures += 1
                traceback.print_exc()
                cells.append({"arch": arch, "shape": shape,
                              "mesh": "2x16x16" if mp else "16x16",
                              "status": "FAILED", "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(cells, f, indent=1)
        print(f"wrote {args.out} ({len(cells)} cells, {failures} failures)")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
