"""Production meshes (spec'd in the assignment).

Defined as FUNCTIONS so importing this module never touches jax device
state; launch/dryrun.py sets XLA_FLAGS *before* any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh for tests/examples on the real CPU device."""
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
