"""Serving steps: batched prefill and single-token decode.

``serve_step`` is what the decode_* dry-run shapes lower: one new token for
every sequence in the batch against a KV cache (or recurrent state) of the
cell's seq_len. Greedy sampling keeps the step closed (token in, token out).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


def make_serve_step(model, cfg: ModelConfig):
    def serve_step(params, cache, token, index):
        """token: (B,1) int32; index: scalar int32 position.
        Returns (next_token (B,1), logits (B,1,V), new_cache)."""
        logits, cache = model.decode_step(params, cache, token, index)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return serve_step


def make_prefill_step(model, cfg: ModelConfig):
    """Prompt -> (last-token logits[, cache]).

    dense/moe: full KV-cache construction (the real serving prefill path);
    ssm/hybrid/vlm: forward + last-position logits (cache extraction is an
    O(state) epilogue, omitted from the lowered step);
    encdec: encoder + cross-KV construction (decoder prompt is 1 BOS).
    """
    if cfg.family in ("dense", "moe") and cfg.frontend == "none":
        def prefill(params, tokens):
            logits, cache = model.prefill(params, tokens,
                                          cache_len=tokens.shape[1])
            return logits, cache
        return prefill
    if cfg.family == "encdec":
        def prefill(params, frames):
            memory = model.encode(params, frames)
            xk, xv = model.build_cross_cache(params, memory)
            return memory[:, -1:], (xk, xv)
        return prefill
    if cfg.frontend == "patch_stub":
        def prefill(params, tokens, embeds):
            logits, _ = model.forward(params, tokens, embeds=embeds)
            return logits[:, -1:]
        return prefill

    def prefill(params, tokens):
        logits, _ = model.forward(params, tokens)
        return logits[:, -1:]

    return prefill
