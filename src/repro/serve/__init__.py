from .serve_step import make_serve_step, make_prefill_step
from .batcher import ContinuousBatcher, Request
# The volume data-service verbs (paper §4.2) are served through the same
# front door: stateless request-dict handlers over the data cluster.
from ..cluster import VolumeService, dispatch as volume_dispatch

__all__ = [
    "make_serve_step",
    "make_prefill_step",
    "ContinuousBatcher",
    "Request",
    "VolumeService",
    "volume_dispatch",
]
