from .serve_step import make_serve_step, make_prefill_step
from .batcher import ContinuousBatcher, Request
# The volume data-service verbs (paper §4.2) are served through the same
# front door: stateless request-dict handlers over the data cluster, with
# the hot-cuboid cache tier, write-behind ingest queue, and the elastic
# rebalancing verbs (GET /topology, POST /rebalance — paper §6) available
# to every registered store.  HANDLERS is re-exported so HTTP shims can
# enumerate every verb they need to route.
from ..cluster import (
    HANDLERS as VOLUME_HANDLERS,
    ClusterStore,
    CuboidCache,
    VolumeService,
    WriteBehindQueue,
    dispatch as volume_dispatch,
    url_dispatch,
)
from .client import RetryingClient
from .http_front import FrontDoor

__all__ = [
    "RetryingClient",
    "make_serve_step",
    "make_prefill_step",
    "ContinuousBatcher",
    "Request",
    "VolumeService",
    "VOLUME_HANDLERS",
    "volume_dispatch",
    "url_dispatch",
    "FrontDoor",
    "ClusterStore",
    "CuboidCache",
    "WriteBehindQueue",
]
