from .serve_step import make_serve_step, make_prefill_step
from .batcher import ContinuousBatcher, Request
# The volume data-service verbs (paper §4.2) are served through the same
# front door: stateless request-dict handlers over the data cluster, with
# the hot-cuboid cache tier and write-behind ingest queue (paper §6)
# available to every registered store.
from ..cluster import (
    CuboidCache,
    VolumeService,
    WriteBehindQueue,
    dispatch as volume_dispatch,
)

__all__ = [
    "make_serve_step",
    "make_prefill_step",
    "ContinuousBatcher",
    "Request",
    "VolumeService",
    "volume_dispatch",
    "CuboidCache",
    "WriteBehindQueue",
]
