"""A small retrying HTTP client for the volume front door.

The front door sheds load with 503 + ``Retry-After`` when its admission
limiter is full; the polite client reaction — and the one the paper's
always-on service story assumes — is to back off and come again, not to
surface every shed as a failure.  :class:`RetryingClient` wraps stdlib
``urllib`` with exactly that loop:

* retries on 503 envelopes and on transport-level ``URLError``/timeouts,
* sleeps the server's ``Retry-After`` when one is present, else a
  seeded-jitter capped exponential backoff (full jitter: each delay is
  uniform in ``(0, min(cap, base * 2**attempt))``, so a thundering herd
  of shed clients decorrelates),
* gives up after ``retries`` attempts, re-raising/returning the last
  response so callers still see the terminal failure.

No third-party dependency, importable anywhere the repo runs; the
http-smoke CI job drives the front door through it.
"""

from __future__ import annotations

import json
import random
import time
import urllib.error
import urllib.request
from typing import Any, Dict, Optional, Tuple


class RetryingClient:
    """Jittered-backoff HTTP client honouring ``Retry-After``.

    ``request`` returns ``(status, headers, payload)``; ``get_json`` /
    ``post_json`` decode the front door's JSON envelopes.  Retries are
    attempted only for 503 and transport errors — anything else (200,
    404, 400 …) is a real answer and returns immediately.
    """

    def __init__(
        self,
        base_url: str,
        retries: int = 5,
        backoff: float = 0.05,
        cap: float = 2.0,
        timeout: float = 30.0,
        seed: Optional[int] = None,
    ):
        self.base_url = base_url.rstrip("/")
        self.retries = max(1, int(retries))
        self.backoff = float(backoff)
        self.cap = float(cap)
        self.timeout = float(timeout)
        self._rng = random.Random(seed)
        self.attempts = 0
        self.retried = 0
        self.slept_s = 0.0

    # -- core loop ---------------------------------------------------------
    def _sleep_for(self, attempt: int, retry_after: Optional[str]) -> float:
        if retry_after:
            try:
                return max(0.0, float(retry_after))
            except ValueError:
                pass  # malformed header: fall through to backoff
        return self._rng.uniform(0.0, min(self.cap, self.backoff * (2 ** attempt)))

    def request(
        self,
        method: str,
        path: str,
        body: Optional[bytes] = None,
        headers: Optional[Dict[str, str]] = None,
    ) -> Tuple[int, Dict[str, str], bytes]:
        url = self.base_url + path
        last_exc: Optional[Exception] = None
        for attempt in range(self.retries):
            self.attempts += 1
            req = urllib.request.Request(
                url, data=body, method=method, headers=dict(headers or {})
            )
            retry_after = None
            try:
                with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                    return resp.status, dict(resp.headers), resp.read()
            except urllib.error.HTTPError as e:
                payload = e.read()
                if e.code != 503:
                    return e.code, dict(e.headers), payload
                last_exc = e
                retry_after = e.headers.get("Retry-After")
            except urllib.error.URLError as e:
                last_exc = e
            if attempt + 1 >= self.retries:
                break
            delay = self._sleep_for(attempt, retry_after)
            self.retried += 1
            self.slept_s += delay
            time.sleep(delay)
        if isinstance(last_exc, urllib.error.HTTPError):
            return last_exc.code, dict(last_exc.headers), b""
        raise last_exc if last_exc is not None else RuntimeError("no attempts made")

    # -- JSON conveniences ---------------------------------------------------
    def get_json(self, path: str) -> Dict[str, Any]:
        status, _, payload = self.request("GET", path)
        return self._decode(status, payload)

    def post_json(self, path: str, body: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        data = json.dumps(body or {}).encode("utf-8")
        status, _, payload = self.request(
            "POST", path, body=data, headers={"Content-Type": "application/json"}
        )
        return self._decode(status, payload)

    def get_raw(self, path: str) -> Tuple[int, Dict[str, str], bytes]:
        """Raw turn for binary verbs (cutouts return voxel payloads)."""
        return self.request("GET", path)

    def put_raw(
        self, path: str, payload: bytes, headers: Optional[Dict[str, str]] = None
    ) -> Dict[str, Any]:
        status, _, body = self.request("PUT", path, body=payload, headers=headers)
        return self._decode(status, body)

    @staticmethod
    def _decode(status: int, payload: bytes) -> Dict[str, Any]:
        try:
            out = json.loads(payload.decode("utf-8"))
        except (ValueError, UnicodeDecodeError):
            out = {"status": status, "raw": payload}
        if isinstance(out, dict):
            out.setdefault("status", status)
            return out
        return {"status": status, "body": out}

    def counters(self) -> Dict[str, float]:
        return {
            "attempts": self.attempts,
            "retried": self.retried,
            "slept_s": self.slept_s,
        }
