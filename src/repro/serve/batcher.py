"""Continuous batching: slot-based scheduler over per-sequence decode.

The serving analogue of the paper's parallel-requests doctrine (C8): the
unit of parallelism is the *request*, and throughput comes from keeping
every batch slot busy — when one sequence finishes, the next request is
admitted into its slot immediately instead of waiting for the whole batch
(vLLM-style). Requires per-sequence decode positions, which every model
family's ``decode_step`` supports (``index`` may be a (B,) vector).

New prompts are streamed through the same decode step one token per
engine tick (decode-only admission): slots in the prefill phase feed
prompt tokens and discard samples; slots in the generate phase feed back
their last sample. One jit'd step serves both phases — no shape
polymorphism, no separate prefill graph to schedule around.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.params import ParamSpec


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    eos_id: Optional[int] = None


@dataclasses.dataclass
class _Slot:
    req: Request
    pos: int = 0                      # next cache position to write
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def prefilling(self) -> bool:
        return self.pos < len(self.req.prompt)

    @property
    def next_token(self) -> int:
        if self.prefilling:
            return self.req.prompt[self.pos]
        return self.out[-1]

    @property
    def done(self) -> bool:
        if len(self.out) >= self.req.max_new:
            return True
        return (self.req.eos_id is not None and self.out
                and self.out[-1] == self.req.eos_id)


class ContinuousBatcher:
    """Greedy continuous-batching engine over ``model.decode_step``."""

    def __init__(self, model, cfg: ModelConfig, params, *, n_slots: int,
                 cache_len: int):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.finished: Dict[int, List[int]] = {}
        self.ticks = 0
        self.busy_slot_ticks = 0
        self._cache_specs = model.cache_specs(n_slots, cache_len)
        self.cache = self._zero_cache()

        def step(params, cache, tokens, index):
            logits, cache = model.decode_step(params, cache, tokens, index)
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return nxt, cache

        self._step = jax.jit(step, donate_argnums=(1,))

    # ------------------------------------------------------------ state ----
    def _zero_cache(self):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
            self._cache_specs,
            is_leaf=lambda x: isinstance(x, ParamSpec))

    def _reset_slot_cache(self, slot: int) -> None:
        """Zero one slot's slice in every cache leaf. The batch axis is
        found from the leaf's ParamSpec (stacked block caches are
        (layers, B, ...): batch is NOT dim 0)."""
        def reset(c, spec: ParamSpec):
            bidx = spec.axes.index("batch")
            idx = (slice(None),) * bidx + (slot,)
            return c.at[idx].set(jnp.zeros_like(c[idx]))

        self.cache = jax.tree.map(
            reset, self.cache, self._cache_specs,
            is_leaf=lambda x: isinstance(x, ParamSpec))

    # -------------------------------------------------------------- api ----
    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new > self.cache_len:
            raise ValueError(f"request {req.rid} exceeds cache_len")
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                self.slots[i] = _Slot(self.queue.popleft())
                self._reset_slot_cache(i)

    def tick(self) -> None:
        """One engine step: every busy slot advances one position."""
        self._admit()
        busy = [i for i, s in enumerate(self.slots) if s is not None]
        if not busy:
            return
        self.ticks += 1
        self.busy_slot_ticks += len(busy)
        tokens = np.zeros((self.n_slots, 1), np.int32)
        index = np.zeros((self.n_slots,), np.int32)
        for i in busy:
            tokens[i, 0] = self.slots[i].next_token
            index[i] = self.slots[i].pos
        nxt, self.cache = self._step(self.params, self.cache,
                                     jnp.asarray(tokens),
                                     jnp.asarray(index))
        nxt = np.asarray(nxt)
        for i in busy:
            s = self.slots[i]
            s.pos += 1
            if not s.prefilling:       # sample counts once past the prompt
                s.out.append(int(nxt[i, 0]))
            if s.done:
                self.finished[s.req.rid] = s.out
                self.slots[i] = None

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue; returns {rid: generated tokens}."""
        while self.queue or any(s is not None for s in self.slots):
            self.tick()
        return self.finished

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots busy per tick (the C8 utilization
        metric: continuous batching keeps this near 1.0 under load)."""
        if self.ticks == 0:
            return 0.0
        return self.busy_slot_ticks / (self.ticks * self.n_slots)
