"""HTTP front door: the paper's stateless Web-service tier on a socket.

A stdlib :class:`~http.server.ThreadingHTTPServer` adapter over the
URL-routed v1 API (`repro.cluster.api`): every request thread parses the
paper-style path, merges the query string and body into a request dict,
and dispatches into the same transport-free handlers the verb table
serves — the front door adds only *wire* concerns:

* **Admission control** — data-plane requests (cutouts, projections,
  writes, batches) pass a semaphore sized from the cluster's
  ``request_slots`` (the `run_batch` pool that actually executes them)
  plus a small waiting room; beyond that the request is shed immediately
  with a ``503`` envelope instead of queueing without bound (the paper's
  "millions of users" story needs a front door that degrades by refusing,
  not by collapsing).
* **Micro-batch coalescing** — concurrent small ``GET /cutout`` requests
  against the same dataset coalesce `ContinuousBatcher`-style: the first
  arrival becomes the *leader* and drains whatever queued while the
  previous batch executed through ``store.run_batch`` (so the boxes
  overlap on the cluster's request pool); identical requests are served
  once and fan the response out.  Serial traffic passes straight through
  with no added latency — a batch of one runs inline.

Wire contract: volume GETs return ``application/octet-stream`` bodies
with ``X-Shape`` / ``X-Dtype`` / ``X-Encode`` (``raw`` or ``zlib``)
headers; everything else is a JSON envelope (``bytes`` and arrays
base64-encoded).  ``PUT .../cutout/...`` takes the voxel payload as the
request body (raw little-endian or ``?encode=zlib``).  See the README
API reference for every route.
"""

from __future__ import annotations

import base64
import collections
import functools
import json
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..analysis.witness import ordered_lock
from ..cluster import deadline
from ..cluster.api import ApiError, parse_url
from ..cluster.handlers import HANDLERS, Request, Response, VolumeService, _error, get_cutout
from ..obs import log as obs_log
from ..obs import trace

# Verbs that do voxel I/O — these pass the admission limiter; control
# verbs (topology, stats, flush, rebalance, node add/remove) always get
# through so the cluster stays operable under load.
_DATA_PLANE = {
    "GET /cutout",
    "PUT /cutout",
    "GET /projection",
    "GET /objects/cutout",
    "POST /batch/cutout",
}
# Data-plane GETs whose 200 body is the volume itself (octet-stream).
_VOLUME_VERBS = {"GET /cutout", "GET /projection", "GET /objects/cutout"}
# Response fields surfaced as X- headers alongside an octet-stream body.
_HEADER_FIELDS = {
    "encode": "X-Encode",
    "level": "X-Level",
    "cuboids_read": "X-Cuboids-Read",
    "runs": "X-Runs",
    "zero_copy": "X-Zero-Copy",
    "id": "X-Id",
    "lo": "X-Lo",
}


def _json_default(obj):
    """JSON fallback for envelope payloads: numpy scalars widen, bytes and
    arrays travel base64 (arrays as raw little-endian bytes — the
    surrounding envelope carries their shape/dtype)."""
    if isinstance(obj, (bytes, bytearray)):
        return base64.b64encode(bytes(obj)).decode("ascii")
    if isinstance(obj, np.ndarray):
        return base64.b64encode(np.ascontiguousarray(obj).tobytes()).decode("ascii")
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    raise TypeError(f"not JSON serializable: {type(obj)!r}")


class _Pending:
    """One queued cutout awaiting its (possibly shared) response."""

    __slots__ = ("request", "response", "done")

    def __init__(self, request: Request):
        self.request = request
        self.response: Optional[Response] = None
        self.done = threading.Event()


class _CutoutCoalescer:
    """Leader/follower micro-batching for concurrent ``GET /cutout``.

    The continuous-batching idiom from `repro.serve.batcher` applied to
    reads: requests arriving while a batch executes queue up, and the
    leader drains them as the *next* batch through ``store.run_batch`` —
    batch size adapts to instantaneous load with zero idle-path latency.
    Identical concurrent requests (same box/resolution/encoding) execute
    once and share the response.
    """

    def __init__(self, service: VolumeService, max_batch: int = 16):
        self._service = service
        self.max_batch = max_batch
        self._lock = ordered_lock("frontdoor.coalesce", 65)
        self._queues: Dict[str, collections.deque] = {}
        self._busy: set = set()
        self.batches = 0  # drain rounds executed
        self.coalesced = 0  # requests that rode a batch of >= 2
        self.deduped = 0  # requests served from an identical twin's result

    @staticmethod
    def _key(req: Request) -> Tuple:
        return (
            req.get("resolution"),
            tuple(req.get("lo", ())),
            tuple(req.get("hi", ())),
            req.get("channel"),
            req.get("encode"),
            req.get("level"),
        )

    def submit(self, request: Request) -> Response:
        dataset = request.get("dataset")
        store = self._service.datasets.get(dataset)
        if store is None or not hasattr(store, "run_batch"):
            return get_cutout(self._service, request)  # nothing to coalesce onto
        item = _Pending(request)
        with self._lock:
            queue = self._queues.setdefault(dataset, collections.deque())
            queue.append(item)
            leader = dataset not in self._busy
            if leader:
                self._busy.add(dataset)
        if leader:
            self._drain(dataset, store)
        item.done.wait()
        return item.response

    def _drain(self, dataset: str, store) -> None:
        while True:
            with self._lock:
                queue = self._queues[dataset]
                if not queue:
                    # busy is cleared under the same lock as the emptiness
                    # check, so a request appended now sees no leader and
                    # elects itself.
                    self._busy.discard(dataset)
                    return
                batch = [queue.popleft() for _ in range(min(len(queue), self.max_batch))]
            self.batches += 1
            if len(batch) > 1:
                self.coalesced += len(batch)
            groups: Dict[Tuple, List[_Pending]] = {}
            for item in batch:
                groups.setdefault(self._key(item.request), []).append(item)
            self.deduped += len(batch) - len(groups)
            reps = [items[0] for items in groups.values()]
            try:
                jobs = [
                    functools.partial(get_cutout, self._service, rep.request) for rep in reps
                ]
                results = store.run_batch(jobs) if len(jobs) > 1 else [jobs[0]()]
            except Exception as e:  # a handler bug must not strand waiters
                results = [_error(500, f"batch execution failed: {e}")] * len(reps)
            for items, resp in zip(groups.values(), results):
                for item in items:
                    item.response = resp
                    item.done.set()


class FrontDoor:
    """The HTTP server: ``with FrontDoor(service) as front: ...``.

    ``admit_limit`` bounds concurrent data-plane requests (default:
    2x the largest registered cluster's ``request_slots`` + 2 — the
    executing set plus a short waiting room); a request that cannot get a
    slot within ``admit_timeout`` seconds is shed with 503.  ``port=0``
    binds an ephemeral port (see ``.address`` after ``start()``).
    """

    def __init__(
        self,
        service: VolumeService,
        host: str = "127.0.0.1",
        port: int = 0,
        admit_limit: Optional[int] = None,
        admit_timeout: float = 0.5,
        coalesce: bool = True,
        coalesce_max: int = 16,
        retry_after: int = 1,
    ):
        self.service = service
        self._host = host
        self._port = port
        if admit_limit is None:
            slots = [
                getattr(store, "request_slots", 0) for store in service.datasets.values()
            ]
            admit_limit = 2 * max([s for s in slots if s] or [2]) + 2
        self.admit_limit = int(admit_limit)
        self.admit_timeout = admit_timeout
        # Advertised back-off for shed (503) responses, in whole seconds
        # (the retrying client honours it over its own backoff schedule).
        self.retry_after = max(1, int(retry_after))
        self._sem = threading.BoundedSemaphore(self.admit_limit)
        self.coalescer = _CutoutCoalescer(service, coalesce_max) if coalesce else None
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self.address: Optional[Tuple[str, int]] = None
        self.requests = 0
        self.shed = 0

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> Tuple[str, int]:
        front = self

        class Handler(_RequestHandler):
            pass

        Handler.front = front
        self._server = ThreadingHTTPServer((self._host, self._port), Handler)
        self._server.daemon_threads = True
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="ocp-frontdoor", daemon=True
        )
        self._thread.start()
        self.address = self._server.server_address[:2]
        return self.address

    def close(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def __enter__(self) -> "FrontDoor":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def counters(self) -> Dict[str, int]:
        out = {"requests": self.requests, "shed": self.shed}
        if self.coalescer is not None:
            out.update(
                batches=self.coalescer.batches,
                coalesced=self.coalescer.coalesced,
                deduped=self.coalescer.deduped,
            )
        return out

    # -- request handling ---------------------------------------------------
    def handle(
        self, method: str, path: str, query: Dict[str, str], body: bytes
    ) -> Tuple[str, Response]:
        """(method, path, query, body) -> (verb, response envelope)."""
        self.requests += 1
        try:
            verb, params = parse_url(method, path)
        except ApiError as e:
            return "", _error(e.status, e.message)
        request: Dict[str, Any] = dict(query)
        if verb == "PUT /cutout":
            try:
                self._attach_put_payload(request, params, body)
            except (ValueError, TypeError) as e:
                return verb, _error(400, f"bad write payload: {e}")
        elif body and method in ("POST", "DELETE"):
            try:
                parsed = json.loads(body.decode("utf-8"))
                if not isinstance(parsed, dict):
                    raise ValueError("body must be a JSON object")
            except (ValueError, UnicodeDecodeError) as e:
                return verb, _error(400, f"bad JSON body: {e}")
            request.update(parsed)
        request.update(params)  # the path IS the address: it wins

        if verb not in _DATA_PLANE:
            return verb, HANDLERS[verb](self.service, request)
        # The wait for an admission slot is the first stage of a sampled
        # request's span tree (queue wait → plan → fetch → decode →
        # assemble); shedding shows up as an errored queue.wait span.
        with trace.span("queue.wait", limit=self.admit_limit) as tmeta:
            admitted = self._sem.acquire(timeout=self.admit_timeout)
            if tmeta is not None:
                tmeta["admitted"] = admitted
        if not admitted:
            self.shed += 1
            return verb, _error(
                503, f"admission limit ({self.admit_limit} in flight) reached; retry"
            )
        try:
            # The deadline budget opened here propagates (thread-locally)
            # into the cluster's replicated read paths: no single hung
            # node may stall this request past REPRO_OP_DEADLINE_MS.
            with deadline.budget():
                if verb == "GET /cutout" and self.coalescer is not None:
                    return verb, self.coalescer.submit(request)
                return verb, HANDLERS[verb](self.service, request)
        finally:
            self._sem.release()

    def _attach_put_payload(
        self, request: Dict[str, Any], params: Request, body: bytes
    ) -> None:
        """Turn a PUT body into the handler's ``data`` field.

        ``?encode=zlib`` hands the compressed blob straight to the handler
        (its shape is the URL box); otherwise the body is raw
        little-endian voxels of the dataset's dtype (or ``?dtype=``)."""
        store = self.service.datasets.get(params.get("dataset"))
        if store is None:
            return  # the handler 404s before touching data
        shape = [b - a for a, b in zip(params["lo"], params["hi"])]
        dtype = request.get("dtype") or str(store.spec.dtype)
        if request.get("encode") == "zlib":
            request["data"] = body
            request["shape"] = shape
            request["dtype"] = dtype
        else:
            arr = np.frombuffer(body, dtype=np.dtype(dtype))
            expected = int(np.prod(shape)) if shape else 0
            if arr.size != expected:
                raise ValueError(
                    f"payload holds {arr.size} voxels, box {shape} needs {expected}"
                )
            request.pop("encode", None)
            request["data"] = arr.reshape(shape)

    def wire(
        self,
        method: str,
        path: str,
        query: Dict[str, str],
        body: bytes,
        headers: Optional[Dict[str, str]] = None,
    ) -> Tuple[int, Dict[str, str], bytes]:
        """Full wire turn: returns (http status, headers, payload).

        ``headers`` are the request headers; an ``X-Trace-Id`` there
        always traces the request (whatever ``REPRO_TRACE_SAMPLE`` says),
        and a traced response carries the id back in ``X-Trace-Id`` so
        the caller can fetch the span tree via ``GET /trace/<id>``.
        """
        t0 = time.perf_counter()
        ctx = trace.maybe_start((headers or {}).get("X-Trace-Id"))
        if ctx is None:
            verb, resp = self.handle(method, path, query, body)
        else:
            with trace.activate(ctx):
                with trace.span("request", method=method, path=path):
                    verb, resp = self.handle(method, path, query, body)
        status, out_headers, payload = self._encode_response(verb, resp)
        trace_id = ctx.trace_id if ctx is not None else None
        if trace_id is not None:
            out_headers["X-Trace-Id"] = trace_id
        dur = time.perf_counter() - t0
        threshold = obs_log.slow_threshold_s()
        if threshold is not None and dur >= threshold:
            tree = trace.trace_tree(trace_id) if trace_id is not None else []
            obs_log.slow_request(method, path, dur, trace_id, tree)
        obs_log.access_log(method, path, status, dur, trace_id)
        return status, out_headers, payload

    def _encode_response(
        self, verb: str, resp: Response
    ) -> Tuple[int, Dict[str, str], bytes]:
        status = int(resp.get("status", 500))
        if status == 200 and verb in _VOLUME_VERBS and "data" in resp:
            resp = dict(resp)  # coalesced twins share the dict — don't mutate
            data = resp.pop("data")
            if isinstance(data, np.ndarray):
                payload = np.ascontiguousarray(data).tobytes()
                resp.setdefault("encode", "raw")
            else:
                payload = bytes(data)
            headers = {
                "Content-Type": "application/octet-stream",
                "X-Shape": ",".join(str(s) for s in resp["shape"]),
                "X-Dtype": str(resp["dtype"]),
            }
            for field, header in _HEADER_FIELDS.items():
                if field in resp:
                    value = resp[field]
                    if isinstance(value, (list, tuple)):
                        value = ",".join(str(v) for v in value)
                    headers[header] = str(value)
            return status, headers, payload
        if status == 200 and "text" in resp:
            # Plain-text envelope (the Prometheus /metrics exposition).
            payload = str(resp["text"]).encode("utf-8")
            content_type = str(resp.get("content_type", "text/plain; charset=utf-8"))
            return status, {"Content-Type": content_type}, payload
        payload = json.dumps(resp, default=_json_default).encode("utf-8")
        headers = {"Content-Type": "application/json"}
        if status == 503:
            # Shed responses tell the client when to come back; the
            # retrying client (serve.client) honours this over its own
            # backoff schedule.
            headers["Retry-After"] = str(self.retry_after)
        return status, headers, payload


class _RequestHandler(BaseHTTPRequestHandler):
    front: FrontDoor  # injected per-server by FrontDoor.start()
    protocol_version = "HTTP/1.1"

    def log_request(self, code="-", size="-"):  # noqa: D102
        # The per-request stderr line BaseHTTPRequestHandler would print
        # here is replaced by the structured access log `wire()` emits
        # (method, path, status, duration, trace id) — same gate, one
        # JSON line per request instead of interleaved raw stderr.
        pass

    def log_message(self, fmt, *args):  # noqa: D102
        # Everything else the stdlib handler logs (log_error: malformed
        # requests, broken pipes) routes through the structured logger —
        # silent by default, REPRO_ACCESS_LOG=1 to enable.
        if obs_log.access_enabled():
            obs_log.emit("httpd", message=fmt % args)

    def _read_body(self) -> bytes:
        length = int(self.headers.get("Content-Length") or 0)
        return self.rfile.read(length) if length > 0 else b""

    def _turn(self, method: str) -> None:
        try:
            split = urllib.parse.urlsplit(self.path)
            query = dict(urllib.parse.parse_qsl(split.query))
            body = self._read_body()
            status, headers, payload = self.front.wire(
                method, urllib.parse.unquote(split.path), query, body,
                headers=dict(self.headers.items()),
            )
        except Exception as e:  # a handler bug must answer, not hang the socket
            payload = json.dumps({"status": 500, "error": f"internal error: {e}"}).encode()
            status, headers = 500, {"Content-Type": "application/json"}
        self.send_response(status)
        for key, value in headers.items():
            self.send_header(key, value)
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        self.wfile.write(payload)

    def do_GET(self):
        self._turn("GET")

    def do_PUT(self):
        self._turn("PUT")

    def do_POST(self):
        self._turn("POST")

    def do_DELETE(self):
        self._turn("DELETE")


def demo_service(n_nodes: int = 3, replication: int = 2, size: int = 64) -> VolumeService:
    """A small self-contained service for smoke tests and manual poking:
    one replicated cluster dataset ("demo") filled with a gradient."""
    from ..cluster import ClusterStore, VolumeService
    from ..core.cuboid import DatasetSpec
    from ..core.cutout import ingest

    spec = DatasetSpec(
        name="demo",
        volume_shape=(size, size, size // 2),
        dtype="uint8",
        base_cuboid=(16, 16, 8),
        n_resolutions=2,
    )
    store = ClusterStore(spec, n_nodes=n_nodes, replication=replication)
    rng = np.random.default_rng(7)
    ingest(store, 0, rng.integers(1, 255, size=spec.volume_shape, dtype=np.uint8))
    service = VolumeService()
    service.add_dataset("demo", store)
    return service


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description="OCP data-cluster HTTP front door (demo)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8765)
    ap.add_argument("--nodes", type=int, default=3)
    ap.add_argument("--replication", type=int, default=2)
    args = ap.parse_args(argv)
    front = FrontDoor(demo_service(args.nodes, args.replication), args.host, args.port)
    host, port = front.start()
    print(f"front door on http://{host}:{port}  (dataset 'demo'; Ctrl-C stops)")
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        front.close()


if __name__ == "__main__":
    main()
