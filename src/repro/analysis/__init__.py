"""Correctness tooling: knob registry, lock-order witness, project lints.

This package is deliberately dependency-free (stdlib only) so the lowest
layers of the tree (`core.store`, `obs.*`) can import it without cycles,
and `tools/check.py` can run it without numpy/jax installed.
"""

from . import knobs, lints, witness
