"""Runtime lock-order witness (deadlock detector for the test suite).

Every long-lived lock in the tree is created through `ordered_lock` /
`ordered_rlock` with a **name** and a **rank**.  With
``REPRO_LOCK_WITNESS`` unset the factories return plain
``threading.Lock`` / ``threading.RLock`` objects — zero overhead, the
wrapper class is never instantiated.  With the knob set, every acquire
is checked against the per-thread held stack and recorded into a shared
acquisition graph:

* **rank violation** — acquiring a lock whose rank is *lower* than the
  highest-ranked lock already held.  The global order (rank ascending)
  is the order the code is allowed to nest in; see `RANKS`.
* **cycle** — a new edge ``A -> B`` in the acquisition graph closes a
  cycle (the classic ABBA shape between equal-rank locks, e.g. two
  node-store locks).  Reported with the stack that first recorded the
  reverse path and the stack of the closing acquisition.
* **submit while locked** — `before_submit()` is called at every
  thread-pool ``submit`` site; holding a ranked lock across a submit is
  a deadlock hazard when the pool is saturated (PR 5's nested-submit
  bug).  Sites where the submitted work provably never takes the held
  lock pass it via ``allow=``.

Cost when enabled: the hot path is one dict lookup (known edge) plus a
scan of the held stack (depth <= 4 in this tree).  Stacks are captured
only the first time an edge is seen and when a violation is recorded.

The global rank order (must match the `ordered_lock` call sites):

====  ======================  ==================================
rank  name                    lock
====  ======================  ==================================
 10   cluster.admin           ``ClusterStore._admin_lock``
 20   cluster.move            ``ClusterStore._move_lock``
 22   cluster.health          ``ClusterStore._health_lock``
 24   cluster.repair          ``ClusterStore._repair_lock``
 30   store.order             ``CuboidStore._order_lock``
 40   store.data              ``CuboidStore._lock`` (also the
                              write-behind apply lock)
 50   wal.log                 ``LogBackend._lock``
 50   backend.memory          ``MemoryBackend._lock``
 60   cache.segments          ``CuboidCache._lock``
 65   frontdoor.coalesce      ``_CutoutCoalescer._lock``
 70   store.stats             ``CuboidStore._stats_lock``
 75   cluster.heat            ``ClusterStore._heat_lock``
 76   cluster.batch           ``ClusterStore._batch_lock``
 80   store.decode_pools      ``_DECODE_POOLS_LOCK``
 81   store.drain             cold-read drain ``todo_lock``
 90   obs.ring                ``SpanRing._lock``
 91   obs.registry            ``Registry._lock``
 92   obs.hist                ``Histogram._lock``
 93   obs.log                 ``obs.log._handler_lock``
====  ======================  ==================================

Conditions (`_OpGate._cond`, the write-behind queue's ``_mu``) stay raw
``threading.Condition`` objects: they are leaves that wrap their own
private mutex and are never held across another ranked acquire.
"""

from __future__ import annotations

import threading
import traceback
from typing import Dict, Iterable, List, Optional, Set, Tuple

from . import knobs

ENABLED = knobs.get_flag("REPRO_LOCK_WITNESS", False)


class Violation:
    """One recorded lock-discipline violation (kept cheap to build)."""

    __slots__ = ("kind", "message", "stack", "other_stack")

    def __init__(self, kind: str, message: str, stack: str, other_stack: str = ""):
        self.kind = kind  # "order" | "cycle" | "submit"
        self.message = message
        self.stack = stack
        self.other_stack = other_stack

    def format(self) -> str:
        out = [f"[{self.kind}] {self.message}", "--- acquiring stack ---", self.stack]
        if self.other_stack:
            out += ["--- prior (first-edge) stack ---", self.other_stack]
        return "\n".join(out)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Violation({self.kind!r}, {self.message!r})"


def _stack() -> str:
    return "".join(traceback.format_stack(limit=18)[:-2])


class Witness:
    """Shared acquisition graph + per-thread held stacks."""

    def __init__(self):
        self._mu = threading.Lock()
        # thread ident -> [[lock, count], ...] in acquisition order
        self._held: Dict[int, List[list]] = {}
        # (id(a), id(b)) -> stack captured when the edge was first seen
        self._edges: Dict[Tuple[int, int], str] = {}
        self._succ: Dict[int, Set[int]] = {}
        self._names: Dict[int, Tuple[str, int]] = {}
        # Edge endpoints are keyed by id(); pin the lock objects so a
        # dead lock's id is never recycled into a phantom graph node
        # (id reuse after gc would fabricate cycles across tests).
        self._pinned: Dict[int, object] = {}
        self._violations: List[Violation] = []

    # -- acquisition hooks -------------------------------------------------

    def note_attempt(self, lock) -> None:
        """Check (and record) the edge *before* blocking on the acquire."""
        held = self._held.get(threading.get_ident())
        if not held:
            return
        for entry in held:
            if entry[0] is lock:  # RLock re-entry: no new edge
                return
        prev = held[-1][0]
        key = (id(prev), id(lock))
        if key in self._edges:  # fast path: edge already checked once
            return
        with self._mu:
            if key in self._edges:
                return
            stack = _stack()
            self._edges[key] = stack
            self._pinned[id(prev)] = prev
            self._pinned[id(lock)] = lock
            self._names[id(prev)] = (prev.name, prev.rank)
            self._names[id(lock)] = (lock.name, lock.rank)
            self._succ.setdefault(id(prev), set()).add(id(lock))
            top_rank = max(e[0].rank for e in held)
            if lock.rank < top_rank:
                holder = max(held, key=lambda e: e[0].rank)[0]
                rev = self._edges.get((id(lock), id(prev)), "")
                self._violations.append(Violation(
                    "order",
                    f"acquired {lock.name!r} (rank {lock.rank}) while holding "
                    f"{holder.name!r} (rank {holder.rank}); ranks must ascend",
                    stack, rev))
            elif self._path_exists(id(lock), id(prev)):
                self._violations.append(Violation(
                    "cycle",
                    f"edge {prev.name!r} -> {lock.name!r} closes a cycle in the "
                    f"acquisition graph (potential deadlock)",
                    stack, self._edges.get((id(lock), id(prev)), "")))

    def note_acquired(self, lock) -> None:
        ident = threading.get_ident()
        held = self._held.get(ident)
        if held is None:
            held = self._held[ident] = []
        for entry in held:
            if entry[0] is lock:
                entry[1] += 1
                return
        held.append([lock, 1])

    def note_released(self, lock) -> None:
        ident = threading.get_ident()
        held = self._held.get(ident)
        if not held:
            return
        for entry in reversed(held):
            if entry[0] is lock:
                entry[1] -= 1
                if entry[1] == 0:
                    held.remove(entry)
                break
        if not held:
            self._held.pop(ident, None)

    def before_submit(self, allow: Iterable = ()) -> None:
        """Flag a thread-pool submit issued while ranked locks are held."""
        held = self._held.get(threading.get_ident())
        if not held:
            return
        allowed = {id(a) for a in allow}
        bad = [e[0] for e in held if id(e[0]) not in allowed]
        if not bad:
            return
        names = ", ".join(f"{l.name!r} (rank {l.rank})" for l in bad)
        with self._mu:
            self._violations.append(Violation(
                "submit",
                f"pool submit while holding {names}: deadlock hazard if the "
                f"pool's work needs the same lock",
                _stack()))

    # -- graph -------------------------------------------------------------

    def _path_exists(self, src: int, dst: int) -> bool:
        """DFS over the acquisition graph; caller holds ``self._mu``."""
        stack, seen = [src], set()
        while stack:
            node = stack.pop()
            if node == dst:
                return True
            if node in seen:
                continue
            seen.add(node)
            stack.extend(self._succ.get(node, ()))
        return False

    # -- inspection --------------------------------------------------------

    def take_violations(self) -> List[Violation]:
        with self._mu:
            out, self._violations = self._violations, []
        return out

    def held_snapshot(self) -> Dict[int, List[Tuple[str, int, int]]]:
        """{thread ident: [(name, rank, depth)]} for every tracked thread."""
        out = {}
        for ident, held in list(self._held.items()):
            entries = [(e[0].name, e[0].rank, e[1]) for e in list(held)]
            if entries:
                out[ident] = entries
        return out


GLOBAL = Witness()


class OrderedLock:
    """A named, ranked ``threading.Lock`` reporting into a `Witness`."""

    __slots__ = ("name", "rank", "_lock", "_witness")
    _factory = staticmethod(threading.Lock)

    def __init__(self, name: str, rank: int, witness: Optional[Witness] = None):
        self.name = name
        self.rank = rank
        self._lock = self._factory()
        self._witness = witness if witness is not None else GLOBAL

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._witness.note_attempt(self)
        got = self._lock.acquire(blocking, timeout)
        if got:
            self._witness.note_acquired(self)
        return got

    def release(self) -> None:
        self._lock.release()
        self._witness.note_released(self)

    def __enter__(self) -> "OrderedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}({self.name!r}, rank={self.rank})"


class OrderedRLock(OrderedLock):
    """Re-entrant variant; same-thread re-entry records no edge."""

    __slots__ = ()
    _factory = staticmethod(threading.RLock)


def ordered_lock(name: str, rank: int):
    """A ranked Lock when the witness is on, a plain Lock otherwise."""
    if not ENABLED:
        return threading.Lock()
    return OrderedLock(name, rank)


def ordered_rlock(name: str, rank: int):
    """A ranked RLock when the witness is on, a plain RLock otherwise."""
    if not ENABLED:
        return threading.RLock()
    return OrderedRLock(name, rank)


def before_submit(allow: Iterable = ()) -> None:
    """Call at every pool ``submit`` site; no-op when the witness is off.

    ``allow`` lists held locks that are safe to hold across this submit
    (the submitted work is known never to acquire them).
    """
    if ENABLED:
        GLOBAL.before_submit(allow)
