"""Project-specific AST lints (the invariants ruff cannot see).

Rules:

* **L001 fsync discipline** — in any function body that contains a
  durability sync (``os.fsync`` or the ``_sync_dir`` / ``_sync_root``
  helpers), no *ack* may lexically precede the first sync: publishing a
  rename (``os.replace`` / ``os.rename``), binding a key's log location
  (``self._set_loc(...)`` / ``self._index[...] = ...``).  Paper §4.1's
  write-path separation only delivers durability if the bytes hit disk
  before the index or caller can see them.
* **L002 no submit under a ranked lock** — a thread-pool ``.submit(...)``
  lexically inside a ``with <ranked lock>:`` block can deadlock when the
  pool is saturated and the submitted work needs the same lock.
* **L003 knob registry** — every environment read of a ``REPRO_*`` name
  must go through `repro.analysis.knobs` (which documents it and renders
  the README table); direct ``os.environ`` / ``os.getenv`` reads of
  ``REPRO_*`` constants are flagged.
* **L004 handler envelope** — every function registered in a module's
  ``HANDLERS`` table must return the ``{status, error?}`` envelope: an
  ``_error(...)`` call, a dict literal with a ``"status"`` key, a
  ``_encode_volume(...)`` body, or a name assigned from one of those.
* **L005 no swallowed exceptions in storage/migration paths** — a bare
  ``except:`` anywhere, or (in the storage modules) an
  ``except Exception/BaseException`` whose body neither re-raises nor
  references the caught exception, hides corruption instead of
  surfacing it.

Suppression: append ``# lint: allow(L00X) <reason>`` to the offending
line.  Suppressions are deliberate and reviewable — the reason is part
of the pragma.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

RULES = ("L001", "L002", "L003", "L004", "L005")

# Attribute/global names created via ordered_lock()/ordered_rlock() —
# L002's definition of "a ranked lock is statically held".
RANKED_LOCK_NAMES = frozenset({
    "_admin_lock", "_move_lock", "_order_lock", "_lock", "_apply_lock",
    "_stats_lock", "_heat_lock", "_batch_lock", "_DECODE_POOLS_LOCK",
    "_health_lock", "_repair_lock",
})

# L005's broad-handler scope: the storage + migration modules.
STORAGE_PATH_SUFFIXES = (
    "core/store.py", "core/wal.py", "core/compact.py",
    "cluster/store.py", "cluster/cache.py",
)

_SYNC_CALLS = frozenset({"fsync", "_sync_dir", "_sync_root"})
_ACK_OS_CALLS = frozenset({"replace", "rename"})
_ENVELOPE_PRODUCERS = frozenset({"_error", "_encode_volume"})

_PRAGMA = re.compile(r"#\s*lint:\s*allow\((L\d{3})\)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _pragmas(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, ln in enumerate(source.splitlines(), start=1):
        for m in _PRAGMA.finditer(ln):
            out.setdefault(i, set()).add(m.group(1))
    return out


def _norm(path: str) -> str:
    return path.replace("\\", "/")


def _call_name(node: ast.Call) -> str:
    """Trailing identifier of the called thing ('' when not a name)."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _is_os_call(node: ast.Call, attrs: frozenset) -> bool:
    fn = node.func
    return (isinstance(fn, ast.Attribute) and fn.attr in attrs
            and isinstance(fn.value, ast.Name) and fn.value.id == "os")


# --------------------------------------------------------------------------
# L001 — fsync discipline
# --------------------------------------------------------------------------

def _l001(tree: ast.AST, path: str) -> List[Finding]:
    findings = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        syncs, acks = [], []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                if _is_os_call(node, frozenset({"fsync"})) or name in _SYNC_CALLS:
                    syncs.append(node.lineno)
                elif _is_os_call(node, _ACK_OS_CALLS):
                    acks.append((node.lineno, f"os.{node.func.attr}(...)"))
                elif name == "_set_loc":
                    acks.append((node.lineno, "_set_loc(...)"))
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Subscript)
                            and isinstance(tgt.value, ast.Attribute)
                            and tgt.value.attr == "_index"):
                        acks.append((node.lineno, "_index[...] = ..."))
        if not syncs:
            continue
        first_sync = min(syncs)
        for line, what in acks:
            if line < first_sync:
                findings.append(Finding(
                    "L001", path, line,
                    f"{what} in {fn.name!r} precedes the first fsync "
                    f"(line {first_sync}); acks must follow durability"))
    return findings


# --------------------------------------------------------------------------
# L002 — no pool submit under a ranked lock
# --------------------------------------------------------------------------

def _lock_name(expr: ast.expr) -> Optional[str]:
    if isinstance(expr, ast.Attribute) and expr.attr in RANKED_LOCK_NAMES:
        return expr.attr
    if isinstance(expr, ast.Name) and expr.id in RANKED_LOCK_NAMES:
        return expr.id
    return None


def _l002(tree: ast.AST, path: str) -> List[Finding]:
    findings = []

    def visit(node: ast.AST, held: Tuple[str, ...]) -> None:
        if isinstance(node, ast.With):
            names = [n for item in node.items
                     if (n := _lock_name(item.context_expr)) is not None]
            inner = held + tuple(names)
            for child in node.body:
                visit(child, inner)
            return
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                and node.func.attr == "submit" and held):
            findings.append(Finding(
                "L002", path, node.lineno,
                f"pool submit inside `with {held[-1]}:`; release the lock "
                f"before fanning out"))
        for child in ast.iter_child_nodes(node):
            # nested defs start with an empty held stack: the closure body
            # runs later, not under this with-block... except it *can* run
            # inline (fan-out jobs), so keep the conservative held stack.
            visit(child, held)

    visit(tree, ())
    return findings


# --------------------------------------------------------------------------
# L003 — REPRO_* env reads must go through the knob registry
# --------------------------------------------------------------------------

def _repro_const(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str) \
            and node.value.startswith("REPRO_"):
        return node.value
    return None


def _is_environ(expr: ast.expr) -> bool:
    """Matches ``os.environ`` or a bare ``environ`` name."""
    if isinstance(expr, ast.Attribute) and expr.attr == "environ":
        return True
    return isinstance(expr, ast.Name) and expr.id == "environ"


def _l003(tree: ast.AST, path: str) -> List[Finding]:
    if _norm(path).endswith("analysis/knobs.py"):
        return []
    findings = []
    for node in ast.walk(tree):
        knob = None
        if isinstance(node, ast.Call):
            args = [a for a in node.args] + [k.value for k in node.keywords]
            named = any((k := _repro_const(a)) and (knob := k) for a in args)
            fn = node.func
            env_get = (isinstance(fn, ast.Attribute) and fn.attr in ("get", "setdefault", "pop")
                       and _is_environ(fn.value))
            getenv = (isinstance(fn, ast.Attribute) and fn.attr == "getenv"
                      and isinstance(fn.value, ast.Name) and fn.value.id == "os") \
                or (isinstance(fn, ast.Name) and fn.id == "getenv")
            if not (named and (env_get or getenv)):
                continue
        elif isinstance(node, ast.Subscript) and _is_environ(node.value):
            knob = _repro_const(node.slice)
            if knob is None:
                continue
        else:
            continue
        findings.append(Finding(
            "L003", path, node.lineno,
            f"direct environ read of {knob!r}; route it through "
            f"repro.analysis.knobs so it is registered and documented"))
    return findings


# --------------------------------------------------------------------------
# L004 — handler envelope shape
# --------------------------------------------------------------------------

def _dict_has_status(node: ast.expr) -> bool:
    return isinstance(node, ast.Dict) and any(
        isinstance(k, ast.Constant) and k.value == "status" for k in node.keys)


def _l004(tree: ast.AST, path: str) -> List[Finding]:
    handler_names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            tgts = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if "HANDLERS" in tgts and isinstance(node.value, ast.Dict):
                for v in node.value.values:
                    if isinstance(v, ast.Name):
                        handler_names.add(v.id)
    if not handler_names:
        return []

    findings = []
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef) or fn.name not in handler_names:
            continue
        compliant: Set[str] = set()
        for node in ast.walk(fn):
            value, target = None, None
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                value, target = node.value, node.targets[0].id
            elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
                value, target = node.value, node.target.id
            if target is None or value is None:
                continue
            if _dict_has_status(value) or (
                    isinstance(value, ast.Call)
                    and _call_name(value) in _ENVELOPE_PRODUCERS):
                compliant.add(target)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            v = node.value
            ok = (_dict_has_status(v)
                  or (isinstance(v, ast.Call) and _call_name(v) in _ENVELOPE_PRODUCERS)
                  or (isinstance(v, ast.Name) and v.id in compliant))
            if not ok:
                findings.append(Finding(
                    "L004", path, node.lineno,
                    f"handler {fn.name!r} returns a value that is not the "
                    f"{{status, error?}} envelope"))
    return findings


# --------------------------------------------------------------------------
# L005 — no swallowed exceptions in storage/migration paths
# --------------------------------------------------------------------------

def _broad_type(node: Optional[ast.expr]) -> bool:
    if node is None:
        return False
    if isinstance(node, ast.Tuple):
        return any(_broad_type(e) for e in node.elts)
    return isinstance(node, ast.Name) and node.id in ("Exception", "BaseException")


def _l005(tree: ast.AST, path: str) -> List[Finding]:
    in_storage = _norm(path).endswith(STORAGE_PATH_SUFFIXES)
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            findings.append(Finding(
                "L005", path, node.lineno,
                "bare `except:` swallows everything including KeyboardInterrupt"))
            continue
        if not in_storage or not _broad_type(node.type):
            continue
        has_raise = any(isinstance(n, ast.Raise) for body in node.body
                        for n in ast.walk(body))
        uses_exc = node.name is not None and any(
            isinstance(n, ast.Name) and n.id == node.name
            for body in node.body for n in ast.walk(body))
        if not has_raise and not uses_exc:
            findings.append(Finding(
                "L005", path, node.lineno,
                "broad except swallows the error in a storage/migration path; "
                "re-raise it or record it (counter/log)"))
    return findings


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

_RULE_FNS = (_l001, _l002, _l003, _l004, _l005)


def run_source(source: str, path: str,
               rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one file's text; `path` scopes the path-sensitive rules."""
    tree = ast.parse(source, filename=path)
    allowed = _pragmas(source)
    findings: List[Finding] = []
    for fn in _RULE_FNS:
        rule = fn.__name__.strip("_").upper()
        if rules is not None and rule not in rules:
            continue
        for f in fn(tree, path):
            if f.rule in allowed.get(f.line, ()):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def run_paths(paths: Sequence[str]) -> List[Finding]:
    """Lint every ``*.py`` under the given files/directories."""
    import pathlib

    files: List[pathlib.Path] = []
    for p in paths:
        pth = pathlib.Path(p)
        if pth.is_dir():
            files.extend(sorted(pth.rglob("*.py")))
        else:
            files.append(pth)
    findings: List[Finding] = []
    for f in files:
        findings.extend(run_source(f.read_text(), str(f)))
    return findings
