"""Logical-axis -> mesh-axis sharding rules with divisibility fallback.

This is the curve-partition idea (paper C3) generalized: every parameter /
cache dim carries a logical name; an ordered candidate list maps names to
mesh axes; resolution checks divisibility and one-mesh-axis-per-leaf
uniqueness in two passes (primary, then fallback), so ANY of the 10
architectures (9 heads, 10 heads, kv=1, 128 experts, ...) resolves to a
legal GSPMD sharding on the production mesh without per-arch hand edits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.params import ParamSpec, tree_map_specs

Candidate = Union[None, str, Tuple[str, ...]]


def default_rules(multi_pod: bool) -> Dict[str, List[Candidate]]:
    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    return {
        # weights: FSDP over data(+pod) on the model dim, TP over model
        "embed": [dp, ("data",), None],
        "vocab": [("model",), None],
        "heads": [("model",), None],
        "kv_heads": [("model",), None],
        "ff": [("model",), None],
        "experts": [("model",), None],
        "rnn": [("model",), None],
        "inner": [("model",), None],
        "layers": [None],
        # activations / caches
        "batch": [dp, ("data",), None],
        "kv_len": [None, ("model",)],     # fallback: sequence-shard cache
        "kv_heads_cache": [("model",), None],
        "heads_cache": [("model",), None],
        # activation constraints (see constrain())
        "act_batch": [dp, ("data",), None],
        "act_seq": [None],
        "act_embed": [None],
        "act_heads": [("model",), None],
        "act_ff": [("model",), None],
        "act_vocab": [("model",), None],
        "act_experts": [("model",), None],
    }


@dataclasses.dataclass
class ShardingPlan:
    mesh: Mesh
    rules: Dict[str, List[Candidate]]
    multi_pod: bool

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return ("pod", "data") if self.multi_pod else ("data",)


def make_plan(mesh: Mesh, multi_pod: Optional[bool] = None,
              rules: Optional[Dict[str, List[Candidate]]] = None
              ) -> ShardingPlan:
    if multi_pod is None:
        multi_pod = "pod" in mesh.axis_names
    return ShardingPlan(mesh, rules or default_rules(multi_pod), multi_pod)


def _axes_size(mesh: Mesh, cand: Candidate) -> int:
    if cand is None:
        return 1
    names = (cand,) if isinstance(cand, str) else cand
    return int(np.prod([mesh.shape[n] for n in names]))


def _axes_names(cand: Candidate) -> Tuple[str, ...]:
    if cand is None:
        return ()
    return (cand,) if isinstance(cand, str) else tuple(cand)


def resolve_leaf(spec: ParamSpec, plan: ShardingPlan) -> P:
    """Two-pass assignment: primary candidates first, fallbacks second."""
    mesh = plan.mesh
    used: set = set()
    assign: List[Candidate] = [None] * len(spec.shape)

    def try_assign(dim: int, cand: Candidate) -> bool:
        names = _axes_names(cand)
        if any(n in used for n in names):
            return False
        if names and spec.shape[dim] % _axes_size(mesh, cand) != 0:
            return False
        assign[dim] = cand
        used.update(names)
        return True

    # pass 1: primary candidate per named dim
    for dim, name in enumerate(spec.axes):
        if name is None:
            continue
        cands = plan.rules.get(name, [None])
        if cands and _axes_names(cands[0]):
            try_assign(dim, cands[0])
    # pass 2: fallbacks for still-unassigned named dims
    for dim, name in enumerate(spec.axes):
        if name is None or assign[dim] is not None:
            continue
        for cand in plan.rules.get(name, [None])[1:]:
            if cand is None:
                break
            if try_assign(dim, cand):
                break
    return P(*assign)


def resolve_specs(specs, plan: ShardingPlan):
    """ParamSpec tree -> PartitionSpec tree."""
    return tree_map_specs(lambda s: resolve_leaf(s, plan), specs)


def resolve_shardings(specs, plan: ShardingPlan):
    return tree_map_specs(
        lambda s: NamedSharding(plan.mesh, resolve_leaf(s, plan)), specs)


def batch_pspec(plan: ShardingPlan, rank: int, batch_size: int) -> P:
    """Activation sharding: batch dim over DP axes (with divisibility
    fallback for e.g. long_500k's global_batch=1)."""
    for cand in [plan.dp_axes, ("data",), None]:
        if cand is None:
            return P(*([None] * rank))
        if batch_size % _axes_size(plan.mesh, cand) == 0:
            return P(cand, *([None] * (rank - 1)))
    return P(*([None] * rank))


# --- activation sharding constraints -----------------------------------
#
# GSPMD propagation alone mis-shards activations when params are FSDP-
# sharded on contracting dims (e.g. the embedding gather inherits the
# table's d_model sharding and drops batch sharding). Production JAX
# frameworks pin activations explicitly; models call ``constrain(x, ...)``
# with logical names, resolved against the active plan (no-op when unset,
# e.g. in single-device tests).

_ACTIVE_PLAN: List[Optional[ShardingPlan]] = [None]


def set_activation_plan(plan: Optional[ShardingPlan]) -> None:
    _ACTIVE_PLAN[0] = plan


class use_plan:
    def __init__(self, plan: ShardingPlan):
        self.plan = plan

    def __enter__(self):
        self.prev = _ACTIVE_PLAN[0]
        _ACTIVE_PLAN[0] = self.plan
        return self.plan

    def __exit__(self, *exc):
        _ACTIVE_PLAN[0] = self.prev
        return False


def constrain(x, names: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axis names (divisibility-safe)."""
    plan = _ACTIVE_PLAN[0]
    if plan is None:
        return x
    spec = ParamSpec(tuple(x.shape), tuple(names), dtype="float32")
    p = resolve_leaf(spec, plan)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, p))


def abstract_sharded(specs, plan: ShardingPlan):
    """ShapeDtypeStruct tree with shardings attached (dry-run inputs)."""
    import jax.numpy as jnp

    def one(s: ParamSpec):
        sh = NamedSharding(plan.mesh, resolve_leaf(s, plan))
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype), sharding=sh)

    return tree_map_specs(one, specs)
