from .sharding import (ShardingPlan, batch_pspec, constrain, make_plan,
                       resolve_specs, set_activation_plan, use_plan)
from .train_step import make_train_step, loss_fn

__all__ = ["ShardingPlan", "batch_pspec", "constrain", "make_plan",
           "resolve_specs", "set_activation_plan", "use_plan",
           "make_train_step", "loss_fn"]
