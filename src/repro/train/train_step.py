"""Train step: loss, grad, (optional) compression, AdamW — jit-able whole.

Supports microbatch gradient accumulation (jax.lax.scan over microbatches;
the per-microbatch remat policy comes from the model config) and the
gradient-compression hook for cross-pod reductions.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..optim import (AdamWConfig, adamw_update, compress_grads,
                     decompress_grads)

F32 = jnp.float32


def loss_fn(model, params, batch: Dict, cfg: ModelConfig,
            loss_impl: str = "gather"):
    """Next-token cross-entropy (+ MoE aux). Returns (loss, metrics).

    ``loss_impl``:
      gather — log_softmax + take_along_axis. Simple, but under a vocab-
               sharded (TP) logits layout GSPMD all-gathers the full
               (B, S, V) logits for the gather: huge HBM + ICI traffic for
               256k vocabularies (the §Perf baseline).
      onehot — label log-prob via a contraction over the vocab axis
               (einsum with one-hot) + local logsumexp: every reduction
               contracts the sharded axis, so logits stay vocab-sharded
               end-to-end and the collective is a scalar-sized psum.
    """
    tokens = batch["tokens"]
    labels = batch["labels"]
    if cfg.family == "encdec":
        logits, aux = model.forward(params, tokens, batch["frames"])
    elif "embeds" in batch:
        logits, aux = model.forward(params, tokens, embeds=batch["embeds"])
        logits = logits[:, -tokens.shape[1]:]   # loss on text positions
    else:
        logits, aux = model.forward(params, tokens)
    logits = logits.astype(F32)
    mask = (labels >= 0).astype(F32)
    if loss_impl == "gather":
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    elif loss_impl == "onehot":
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(labels, logits.shape[-1],
                                dtype=logits.dtype)
        picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
        ll = picked - lse
    else:
        raise ValueError(loss_impl)
    ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(model, cfg: ModelConfig, opt_cfg: AdamWConfig,
                    n_microbatches: int = 1, loss_impl: str = "gather"):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With n_microbatches > 1 the global batch is split along axis 0 and
    gradients accumulate in fp32 across a lax.scan — decoupling the HBM
    activation footprint from the global batch (pipeline-style microbatching
    without inter-stage plumbing; PP proper is future work, see DESIGN.md).
    """

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(model, p, b, cfg, loss_impl=loss_impl),
        has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulate(params, batch):
        def split(x):
            B = x.shape[0]
            mb = B // n_microbatches
            return x.reshape(n_microbatches, mb, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, loss_sum = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(F32), acc, grads)
            return (acc, loss_sum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: g / n_microbatches, grads)
        return loss_sum / n_microbatches, {}, grads

    def train_step(params, opt_state, batch):
        if n_microbatches > 1:
            loss, metrics, grads = accumulate(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        if opt_cfg.grad_compression != "none":
            # compression round-trip (the all-reduce happens on the
            # compressed representation; GSPMD places it at the cast)
            comp, _ = compress_grads(grads, opt_cfg.grad_compression)
            grads = decompress_grads(comp, opt_cfg.grad_compression)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state,
                                             params)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    return train_step
