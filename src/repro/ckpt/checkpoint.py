"""Cuboid-chunked checkpointing with an async write path (paper C4) and
elastic restore (paper C3).

Every array leaf is flattened and split into fixed-size *chunks* — the 1-d
analogue of cuboids — indexed by position on the (trivially Morton) 1-d
curve. A checkpoint is a directory of chunk files plus a JSON manifest
written LAST and atomically renamed (the commit point). Restore reads the
manifest and reassembles each leaf; because chunk ownership is a curve
partition, a job restarted on a DIFFERENT mesh (elastic rescale) just
re-partitions the same chunk list — no rewrite, no all-to-all of small
pieces.

The async manager mirrors the paper's SSD write nodes: snapshots are taken
synchronously (cheap host copy of device shards) and flushed by a
background thread, so checkpoint I/O never blocks the training step
(write path separated from the read path).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

CHUNK_BYTES = 4 << 20  # 4 MiB chunks (the "cuboid" of the 1-d curve)


def _leaf_paths(tree, prefix=()) -> List[Tuple[Tuple, Any]]:
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += _leaf_paths(tree[k], prefix + (k,))
    else:
        out.append((prefix, tree))
    return out


def _path_str(path: Tuple) -> str:
    return "/".join(str(p) for p in path)


def save_checkpoint(ckpt_dir: str, step: int, tree,
                    compress: bool = False) -> str:
    """Write one checkpoint synchronously. Returns the committed dir."""
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "chunk_bytes": CHUNK_BYTES,
                "compress": compress}
    for path, leaf in _leaf_paths(tree):
        arr = np.asarray(leaf)
        key = _path_str(path)
        raw = arr.tobytes()
        n_chunks = max(1, -(-len(raw) // CHUNK_BYTES))
        fn = key.replace("/", "__")
        for c in range(n_chunks):
            blob = raw[c * CHUNK_BYTES:(c + 1) * CHUNK_BYTES]
            if compress:
                blob = zlib.compress(blob, 1)
            with open(os.path.join(tmp, f"{fn}.{c:05d}.chunk"), "wb") as f:
                f.write(blob)
        manifest["leaves"][key] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "n_chunks": n_chunks,
            "nbytes": len(raw),
            "file": fn,
        }
    # manifest last + atomic rename = commit point
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final)
    return final


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       shard_info: Optional[Tuple[int, int]] = None
                       ) -> Tuple[int, Dict]:
    """Restore (step, tree). ``shard_info=(host_id, n_hosts)``: elastic
    restore — this host materializes only its curve segment of each leaf's
    chunk list (chunks outside the segment are zero-filled; the training
    runtime re-shards via device_put with the new plan)."""
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    step = steps[-1] if step is None else step
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    compress = manifest.get("compress", False)

    def load_leaf(meta):
        n = meta["n_chunks"]
        lo, hi = 0, n
        if shard_info is not None:
            from ..core.morton import partition_curve
            host, n_hosts = shard_info
            lo, hi = partition_curve(n, n_hosts)[host]
        buf = bytearray(meta["nbytes"])
        for c in range(lo, hi):
            with open(os.path.join(
                    d, f"{meta['file']}.{c:05d}.chunk"), "rb") as f:
                blob = f.read()
            if compress:
                blob = zlib.decompress(blob)
            start = c * manifest["chunk_bytes"]
            buf[start:start + len(blob)] = blob
        arr = np.frombuffer(bytes(buf), dtype=meta["dtype"])
        return arr.reshape(meta["shape"])

    tree: Dict = {}
    for key, meta in manifest["leaves"].items():
        node = tree
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = load_leaf(meta)
    return step, tree


@dataclasses.dataclass
class _Pending:
    step: int
    snapshot: Dict
    t_start: float


class CheckpointManager:
    """Async checkpointing: snapshot on the step path, flush off it."""

    def __init__(self, ckpt_dir: str, keep: int = 3,
                 compress: bool = False):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.compress = compress
        os.makedirs(ckpt_dir, exist_ok=True)
        self._q: List[_Pending] = []
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.flush_times: List[float] = []

    def save_async(self, step: int, tree) -> None:
        # synchronous part: device -> host copy (snapshot isolation)
        snap = jax.tree.map(lambda x: np.asarray(x), tree)
        with self._lock:
            self._q.append(_Pending(step, snap, time.perf_counter()))
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._drain, daemon=True)
            self._thread.start()

    def _drain(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                if not self._q:
                    return
                item = self._q.pop(0)
            save_checkpoint(self.ckpt_dir, item.step, item.snapshot,
                            compress=self.compress)
            self.flush_times.append(time.perf_counter() - item.t_start)
            self._gc()

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1])
                       for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        while True:
            with self._lock:
                if not self._q:
                    break
            time.sleep(0.01)
        if self._thread is not None:
            self._thread.join(timeout=10)

    def latest_step(self) -> Optional[int]:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                 if d.startswith("step_")]
        return max(steps) if steps else None
