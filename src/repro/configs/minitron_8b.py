"""Minitron-8B [arXiv:2407.14679] — width-pruned Nemotron-4, dense GQA."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
    vocab=256000, act="swiglu", tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=192, vocab=256)
