"""Assigned-architecture registry: one module per arch (``--arch <id>``)."""
import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCH_IDS: List[str] = [
    "smollm_135m",
    "minitron_8b",
    "llama3_405b",
    "gemma_2b",
    "arctic_480b",
    "granite_moe_1b_a400m",
    "internvl2_76b",
    "recurrentgemma_2b",
    "seamless_m4t_medium",
    "mamba2_370m",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical(arch: str) -> str:
    a = arch.replace("-", "_")
    if a not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return a


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.smoke()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
