"""Llama-3.1-405B [arXiv:2407.21783] — dense GQA, 128k vocab."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
    vocab=128256, act="swiglu", tie_embeddings=False, rope_theta=500000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
                         head_dim=16, d_ff=384, vocab=512)
