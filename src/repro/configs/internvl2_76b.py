"""InternVL2-Llama3-76B [arXiv:2404.16821] — VLM; the LM backbone is a
dense llama3-70B-class decoder. The InternViT frontend is a STUB per the
assignment: ``input_specs()`` supplies precomputed patch embeddings which
are prepended to the token sequence."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, act="swiglu", tie_embeddings=False,
    frontend="patch_stub", n_frontend_tokens=256,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=192, vocab=256,
                         n_frontend_tokens=8)
