"""Gemma-2B [arXiv:2403.08295] — GeGLU, head_dim=256, MQA (kv=1)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, act="geglu", tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                         head_dim=32, d_ff=256, vocab=256)
