"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] —
32-expert top-8 fine-grained MoE."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab=49155, act="swiglu", tie_embeddings=True,
    n_experts=32, top_k=8,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=64, vocab=256, n_experts=8,
                         top_k=4)
