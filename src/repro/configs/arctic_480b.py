"""Snowflake Arctic [hf:Snowflake/snowflake-arctic-base] — 128-expert top-2
MoE with a dense residual MLP in parallel (dense-MoE hybrid)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, act="swiglu", tie_embeddings=False,
    n_experts=128, top_k=2, moe_dense_residual=True,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=96, vocab=256, n_experts=8,
                         top_k=2)
