"""SeamlessM4T-medium [arXiv:2308.11596] — encoder-decoder multimodal
backbone (MHA, kv=16). The speech frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings to the encoder."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, n_dec_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, act="swiglu", tie_embeddings=True,
    frontend="frame_stub",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, n_enc_layers=2, n_dec_layers=2,
                         d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                         d_ff=128, vocab=256)
