"""Mamba2-370M [arXiv:2405.21060] — attention-free SSD (state-space
duality): chunked-matmul train path, O(1)-state decode."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, d_ff=0,
    vocab=50280, tie_embeddings=True,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, vocab=256, ssm_state=16,
                         ssm_head_dim=16, ssm_chunk=16)
