"""RecurrentGemma-2B [arXiv:2402.19427] — Griffin: RG-LRU recurrent blocks
with local (sliding-window) attention at a 1:2 ratio (pattern RRA)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000, act="geglu", tie_embeddings=True,
    hybrid_pattern="RRA", local_window=2048, d_rnn=2560, conv_width=4,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=1,
                         head_dim=16, d_ff=128, vocab=256, d_rnn=64,
                         local_window=32)
