from .adamw import (AdamWConfig, adamw_init_specs, adamw_update,
                    cosine_schedule, global_norm)
from .compression import compress_grads, decompress_grads

__all__ = ["AdamWConfig", "adamw_init_specs", "adamw_update",
           "cosine_schedule", "global_norm", "compress_grads",
           "decompress_grads"]
