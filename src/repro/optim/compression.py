"""Gradient compression for cross-pod all-reduce (distributed-opt trick).

Cross-pod links (DCI) are the scarcest bandwidth in a multi-pod mesh; we
compress gradients before the data-parallel reduction: bf16 cast or int8
with per-tensor scale, with an *error-feedback* residual so compression
noise is fed back into the next step (1-bit-Adam-style convergence
guarantee shape). The hook lives between loss.grad and adamw_update.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

F32 = jnp.float32


def compress_grads(grads, method: str, residual=None):
    """Returns (compressed_tree, new_residual). residual matches grads."""
    if method == "none":
        return grads, residual
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, F32), grads)

    def comp(g, r):
        g = g.astype(F32) + r
        if method == "bf16":
            q = g.astype(jnp.bfloat16)
            back = q.astype(F32)
        elif method == "int8":
            scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            back = q.astype(F32) * scale
            q = (q, scale)
        else:
            raise ValueError(method)
        return q, g - back

    flat, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, rs = [], []
    for g, r in zip(flat, flat_r):
        q, nr = comp(g, r)
        qs.append(q)
        rs.append(nr)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, rs))


def decompress_grads(comp, method: str):
    if method == "none":
        return comp
    if method == "bf16":
        return jax.tree.map(lambda q: q.astype(F32), comp)
    if method == "int8":
        def dec(q):
            arr, scale = q
            return arr.astype(F32) * scale
        return jax.tree.map(dec, comp,
                            is_leaf=lambda x: isinstance(x, tuple))
    raise ValueError(method)
