"""AdamW with fully-sharded fp32 master weights + moments.

Optimizer state is declared as ParamSpec trees mirroring the model's
logical axes, so ZeRO-3-style sharding falls out of the same rules as the
parameters (DESIGN.md §4) and the dry-run can size it without allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from ..models.params import ParamSpec, tree_map_specs

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # distributed-optimization knobs
    grad_compression: str = "none"   # none | bf16 | int8
    error_feedback: bool = True
    state_dtype: str = "float32"     # moments dtype: float32 | bfloat16
                                     # (masters always fp32)


def adamw_init_specs(model_specs, state_dtype: str = "float32") -> dict:
    """ParamSpec tree for optimizer state (same logical axes; moments in
    ``state_dtype``, masters fp32)."""
    def moment_like(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, dtype=state_dtype, init="zeros")

    out = {
        "mu": tree_map_specs(moment_like, model_specs),
        "nu": tree_map_specs(moment_like, model_specs),
        "master": tree_map_specs(
            lambda s: dataclasses.replace(s, dtype="float32"), model_specs),
        "step": ParamSpec((), (), dtype="int32", init="zeros"),
    }
    return out


def cosine_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr_peak * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params
                 ) -> Tuple[dict, dict, dict]:
    """One step. Returns (new_params(bf16 views), new_opt_state, metrics).

    params are the working (bf16) weights; opt_state["master"] holds fp32
    masters; the bf16 weights are recast views of the updated masters.
    """
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(g, mu, nu, master, p):
        state_dt = mu.dtype              # moments math in f32, stored as-is
        g = g.astype(F32) * scale
        mu = cfg.b1 * mu.astype(F32) + (1 - cfg.b1) * g
        nu = cfg.b2 * nu.astype(F32) + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step.astype(F32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(F32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        master = master - lr * (delta + cfg.weight_decay * master)
        return (mu.astype(state_dt), nu.astype(state_dt), master,
                master.astype(p.dtype))

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    flat_ma = jax.tree.leaves(opt_state["master"])
    flat_p = jax.tree.leaves(params)
    new_mu, new_nu, new_ma, new_p = [], [], [], []
    for g, mu, nu, ma, p in zip(flat_g, flat_mu, flat_nu, flat_ma, flat_p):
        a, b, c, d = upd(g, mu, nu, ma, p)
        new_mu.append(a)
        new_nu.append(b)
        new_ma.append(c)
        new_p.append(d)
    new_opt = {"mu": jax.tree.unflatten(treedef, new_mu),
               "nu": jax.tree.unflatten(treedef, new_nu),
               "master": jax.tree.unflatten(treedef, new_ma),
               "step": step}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return jax.tree.unflatten(treedef, new_p), new_opt, metrics
