"""Annotation projects: RAMON-like metadata + spatial labels (paper §3.2).

An :class:`AnnotationProject` pairs
  * a metadata table implementing a small RAMON-like ontology
    (synapse / seed / segment / neuron / organelle + user KV pairs), with
    predicate queries (equality on ints/enums/strings, range on floats), and
  * a spatial label database: a uint32 CuboidStore registered to an image
    dataset, with lazy cuboids, per-cuboid exception lists for multiply
    labeled voxels, write disciplines, and deferred resolution-hierarchy
    propagation (paper: consistency traded for write throughput).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cuboid import DatasetSpec
from .cutout import cutout, write_cutout, build_hierarchy
from .spatial_index import ObjectIndex
from .store import Backend, CuboidStore

# --- RAMON-ish metadata ------------------------------------------------

RAMON_TYPES = ("generic", "seed", "synapse", "segment", "neuron", "organelle")


@dataclasses.dataclass
class Annotation:
    ann_id: int
    ann_type: str = "generic"
    confidence: float = 1.0
    status: int = 0
    author: str = ""
    kv: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # synapse-specific (paper's driving application):
    synapse_type: int = 0
    weight: float = 0.0
    segments: Tuple[int, ...] = ()      # linked segment ids
    # segment/neuron-specific:
    neuron: int = 0
    parent_seed: int = 0

    def matches(self, field: str, op: str, value) -> bool:
        v = self.kv.get(field) if field in self.kv else getattr(
            self, field, None)
        if v is None:
            return False
        if op == "eq":
            return str(v) == str(value) if isinstance(v, str) else v == value
        x, y = float(v), float(value)
        return {"lt": x < y, "leq": x <= y, "gt": x > y,
                "geq": x >= y}[op]


class MetadataTable:
    """Key/value predicate queries over annotation metadata (paper §4.2)."""

    def __init__(self):
        self._rows: Dict[int, Annotation] = {}
        self._next_id = itertools.count(1)
        self._lock = threading.Lock()

    def create(self, ann: Optional[Annotation] = None, **kwargs) -> Annotation:
        with self._lock:
            if ann is None:
                ann_id = kwargs.pop("ann_id", None) or next(self._next_id)
                ann = Annotation(ann_id=ann_id, **kwargs)
            elif ann.ann_id in (0, None):
                ann.ann_id = next(self._next_id)
            if ann.ann_type not in RAMON_TYPES:
                raise ValueError(f"unknown RAMON type {ann.ann_type!r}")
            self._rows[ann.ann_id] = ann
            # keep auto-ids ahead of explicit ids
            self._next_id = itertools.count(max(self._rows) + 1)
            return ann

    def get(self, ann_id: int) -> Optional[Annotation]:
        return self._rows.get(int(ann_id))

    def update(self, ann_id: int, **fields) -> Annotation:
        ann = self._rows[int(ann_id)]
        for k, v in fields.items():
            if hasattr(ann, k):
                setattr(ann, k, v)
            else:
                ann.kv[k] = v
        return ann

    def delete(self, ann_id: int) -> None:
        self._rows.pop(int(ann_id), None)

    def query(self, *predicates: Tuple[str, str, Any]) -> List[int]:
        """Conjunctive predicates: [(field, op, value), ...] -> ids.

        Paper example: ``objects/type/synapse/confidence/geq/0.99``.
        """
        out = []
        for ann_id, ann in self._rows.items():
            if all(ann.matches(f, op, v) for f, op, v in predicates):
                out.append(ann_id)
        return sorted(out)

    def __len__(self):
        return len(self._rows)


# --- the spatial annotation database ------------------------------------


class AnnotationProject:
    """One annotation database registered to an image dataset (paper §3.2).

    ``enable_exceptions`` activates per-cuboid exception tracking: every
    read then pays a small check cost (the paper notes this), and conflicting
    writes with the ``exception`` discipline are preserved per voxel.
    """

    def __init__(self, name: str, image_spec: DatasetSpec,
                 enable_exceptions: bool = False,
                 readonly: bool = False,
                 backend: Optional[Backend] = None,
                 write_path_backend: Optional[Backend] = None,
                 store_factory: Optional[Callable[[DatasetSpec], Any]] = None):
        """``store_factory(spec)`` overrides the default single-node store —
        pass e.g. ``lambda s: ClusterStore(s, n_nodes=4)`` to hold the label
        database sharded across the cluster (paper §4.1: annotation projects
        are distributed exactly like image datasets)."""
        self.name = name
        spec = dataclasses.replace(
            image_spec, name=f"{image_spec.name}/{name}",
            dtype="uint32", n_channels=1)
        self.spec = spec
        if store_factory is not None:
            self.store = store_factory(spec)
        else:
            self.store = CuboidStore(spec, backend=backend,
                                     write_path_backend=write_path_backend,
                                     compression_level=1)
        self.meta = MetadataTable()
        self.index = ObjectIndex()
        self.enable_exceptions = enable_exceptions
        self.readonly = readonly
        # (resolution, morton) -> list of (flat_voxel_offset, label)
        self._exceptions: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        self._dirty_levels: set[int] = set()

    # -- write -------------------------------------------------------------
    def write(self, r: int, lo: Sequence[int], labels: np.ndarray,
              discipline: str = "overwrite",
              update_index: bool = True) -> None:
        """Write a labeled volume with a conflict discipline (paper §3.2).

        Annotations become visible at resolution ``r`` immediately; other
        levels are stale until :meth:`propagate` runs (deferred-consistency
        design, paper §3.2).
        """
        if self.readonly:
            raise PermissionError(f"project {self.name} is readonly")
        if discipline == "exception" and not self.enable_exceptions:
            raise ValueError("exceptions not enabled for this project")
        labels = labels.astype(np.uint32)

        exc_sink = None
        if discipline == "exception":
            def exc_sink(m, origin, old_block, new_block):
                lst = self._exceptions.setdefault((r, m), [])
                flat_new = new_block.ravel()
                for off in np.flatnonzero(flat_new):
                    lst.append((int(off), int(flat_new[off])))

        write_cutout(self.store, r, lo, labels, discipline=discipline,
                     on_conflict=exc_sink)
        self._dirty_levels.add(r)
        if update_index:
            grid = self.spec.grid(r)
            hi = [l + s for l, s in zip(lo, labels.shape)]
            clo, chi = grid.clamp_box(lo, hi)
            updates: Dict[int, set] = {}
            for start, stop in grid.box_to_runs(clo, chi):
                for m in range(start, stop):
                    origin = grid.cuboid_origin(m)
                    if any(o >= v for o, v in
                           zip(origin, grid.volume_shape)):
                        continue
                    b_lo = [max(0, l - o) for l, o in zip(clo, origin)]
                    b_hi = [min(c, h - o) for c, h, o in
                            zip(grid.cuboid_shape, chi, origin)]
                    if any(a >= b for a, b in zip(b_lo, b_hi)):
                        continue
                    d_lo = [o + bl - l for o, bl, l in zip(origin, b_lo, lo)]
                    d_hi = [o + bh - l for o, bh, l in zip(origin, b_hi, lo)]
                    sub = labels[tuple(slice(a, b)
                                       for a, b in zip(d_lo, d_hi))]
                    for ann_id in np.unique(sub):
                        if ann_id:
                            updates.setdefault(int(ann_id), set()).add(m)
            if updates:
                self.index.append_batch(updates)

    # -- read ---------------------------------------------------------------
    def read(self, r: int, lo: Sequence[int], hi: Sequence[int],
             with_exceptions: bool = False) -> np.ndarray:
        out = cutout(self.store, r, lo, hi)
        if self.enable_exceptions and with_exceptions:
            # exception check happens on every read once enabled (paper).
            pass  # dense array holds primary labels; exceptions via getter
        return out

    def exceptions_at(self, r: int, m: int) -> List[Tuple[int, int]]:
        return list(self._exceptions.get((r, m), ()))

    def voxel_labels(self, r: int, voxel: Sequence[int]) -> List[int]:
        """All labels at one voxel: primary + exceptions (paper §3.2)."""
        grid = self.spec.grid(r)
        m = grid.cuboid_of_voxel(voxel)
        block = self.store.read_cuboid(r, m)
        origin = grid.cuboid_origin(m)
        local = tuple(v - o for v, o in zip(voxel, origin))
        labels = []
        primary = int(block[local])
        if primary:
            labels.append(primary)
        flat = int(np.ravel_multi_index(local, grid.cuboid_shape))
        for off, lab in self._exceptions.get((r, m), ()):
            if off == flat and lab not in labels:
                labels.append(lab)
        return labels

    # -- object-level queries (paper §4.2) -----------------------------------
    def object_cutout(self, ann_id: int, r: int,
                      box: Optional[Tuple[Sequence[int], Sequence[int]]] = None
                      ) -> Tuple[List[int], np.ndarray]:
        """Dense array of one object within its bbox (others filtered out)."""
        bbox = (box or self.index.bounding_box(ann_id, self.spec.grid(r)))
        if bbox is None:
            return [0] * self.spec.spatial_rank, np.zeros(
                (0,) * self.spec.spatial_rank, np.uint32)
        lo, hi = bbox
        dense = self.read(r, lo, hi)
        mask = dense == np.uint32(ann_id)
        return list(lo), np.where(mask, dense, 0).astype(np.uint32)

    def voxel_list(self, ann_id: int, r: int) -> np.ndarray:
        """Sparse (N, rank) voxel coordinates — better for skinny objects.

        Reads the object's cuboids in one morton-sorted pass via the index
        (paper Fig 9), not a bbox cutout: for long skinny neurites the bbox
        is pathologically larger than the object.
        """
        grid = self.spec.grid(r)
        coords = []
        for start, stop in self.index.runs(ann_id):
            blocks = self.store.read_run(r, start, stop)
            for m, block in zip(range(start, stop), blocks):
                where = np.argwhere(block == np.uint32(ann_id))
                if where.size:
                    origin = np.array(grid.cuboid_origin(m))
                    coords.append(where + origin)
        if not coords:
            return np.zeros((0, grid.rank), dtype=np.int64)
        return np.concatenate(coords, axis=0)

    def objects_in_region(self, r: int, lo, hi) -> List[int]:
        """What objects are in a region? cutout + unique (paper §4.2)."""
        dense = self.read(r, lo, hi)
        ids = np.unique(dense)
        return [int(i) for i in ids if i]

    def bounding_box(self, ann_id: int, r: int):
        return self.index.bounding_box(ann_id, self.spec.grid(r))

    # -- batch interface (paper §4.2) ---------------------------------------
    def batch_write_objects(
            self, r: int,
            objects: List[Tuple[Annotation, Sequence[int], np.ndarray]],
            discipline: str = "overwrite") -> List[int]:
        """Write many (metadata, offset, labeled-volume) at once.

        The paper doubled synapse-finder throughput batching 40 writes; the
        batch path shares one index append transaction across objects.
        """
        ids = []
        for ann, lo, vol in objects:
            ann = self.meta.create(ann)
            ids.append(ann.ann_id)
            vol = np.where(vol != 0, np.uint32(ann.ann_id), 0)
            self.write(r, lo, vol, discipline=discipline)
        return ids

    def batch_read_objects(self, ann_ids: Sequence[int], r: int):
        return {i: self.object_cutout(i, r) for i in ann_ids}

    # -- hierarchy (deferred consistency) ------------------------------------
    def propagate(self) -> None:
        """Background batch job building the annotation resolution hierarchy
        (paper §3.2: annotations visible only at write resolution until
        propagation runs)."""
        build_hierarchy(self.store, labels=True)
        self._dirty_levels.clear()

    @property
    def pending_propagation(self) -> bool:
        return bool(self._dirty_levels) and self.spec.n_resolutions > 1

    # -- spatial analysis helpers (paper §2 kasthuri11 use case) -------------
    def centroid(self, ann_id: int, r: int) -> Optional[np.ndarray]:
        vox = self.voxel_list(ann_id, r)
        return vox.mean(axis=0) if len(vox) else None

    def distance(self, a: int, b: int, r: int) -> float:
        """Min voxel-to-voxel distance between two objects (e.g. synapse to
        dendrite backbone, paper §2)."""
        va, vb = self.voxel_list(a, r), self.voxel_list(b, r)
        if not len(va) or not len(vb):
            return float("inf")
        # chunked pairwise min to bound memory
        best = np.inf
        for i in range(0, len(va), 4096):
            d = np.linalg.norm(va[i:i + 4096, None, :] - vb[None], axis=-1)
            best = min(best, float(d.min()))
        return best
