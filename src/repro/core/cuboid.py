"""Cuboid grids and the anisotropic multi-resolution hierarchy (paper §3.1).

A dataset is a dense N-d array partitioned into fixed-shape *cuboids*.
Per level: X,Y halve, Z (and time / channel) do not — matching serial-section
EM anisotropy — and the cuboid *shape* changes across levels so cuboids stay
roughly isometric in sample space (paper Fig 5: flat 128x128x16 at high res,
cubic 64^3 beyond level 4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Sequence, Tuple

import numpy as np

from . import morton

# Paper default: cuboids contain 2^18 = 256K voxels (§3.1).
CUBOID_VOXELS = 1 << 18


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class CuboidGrid:
    """One resolution level: volume shape + cuboid shape + morton layout."""
    volume_shape: Tuple[int, ...]   # voxels per dim at this level
    cuboid_shape: Tuple[int, ...]   # voxels per cuboid per dim

    def __post_init__(self):
        if len(self.volume_shape) != len(self.cuboid_shape):
            raise ValueError("rank mismatch")

    @property
    def rank(self) -> int:
        return len(self.volume_shape)

    @property
    def grid_shape(self) -> Tuple[int, ...]:
        return tuple(_ceil_div(v, c)
                     for v, c in zip(self.volume_shape, self.cuboid_shape))

    @property
    def bits(self) -> Tuple[int, ...]:
        return morton.grid_bits(self.grid_shape)

    @property
    def n_cells(self) -> int:
        """Size of the (dense, padded-to-pow2) morton index space."""
        return 1 << morton.total_bits(self.bits)

    @property
    def n_cuboids(self) -> int:
        """Number of real (in-volume) cuboids."""
        return int(np.prod(self.grid_shape))

    def cuboid_of_voxel(self, voxel: Sequence[int]) -> int:
        coords = [v // c for v, c in zip(voxel, self.cuboid_shape)]
        return int(morton.morton_encode(np.array(coords), self.bits))

    def cuboid_origin(self, idx: int) -> Tuple[int, ...]:
        coords = morton.morton_decode(idx, self.bits)
        return tuple(int(c) * s for c, s in zip(coords, self.cuboid_shape))

    def box_to_runs(self, lo: Sequence[int], hi: Sequence[int],
                    max_runs: int | None = None) -> morton.Runs:
        """Morton runs of cuboids intersecting voxel box [lo, hi)."""
        glo = [l // c for l, c in zip(lo, self.cuboid_shape)]
        ghi = [_ceil_div(h, c) for h, c in zip(hi, self.cuboid_shape)]
        return morton.range_decompose(glo, ghi, self.bits, max_runs=max_runs)

    def clamp_box(self, lo, hi):
        lo = [max(0, int(l)) for l in lo]
        hi = [min(int(v), int(h)) for v, h in zip(self.volume_shape, hi)]
        return lo, hi


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Dataset configuration (paper §4.2 'Projects and Datasets').

    ``spatial_rank``: leading dims that participate in the morton index
    (XYZ, optionally +time = 4-d curve, paper §3.1). Channels are NOT in the
    index — separate cuboids per channel (paper: "we do not include channel
    data in the index").
    """
    name: str
    volume_shape: Tuple[int, ...]          # full-res spatial shape (X,Y,Z[,T])
    n_channels: int = 1
    n_resolutions: int = 1
    dtype: str = "uint8"
    # dims that downscale per level (X,Y for EM; never Z/T):
    scaled_dims: Tuple[int, ...] = (0, 1)
    base_cuboid: Tuple[int, ...] | None = None  # default: auto per level
    # zlib codec level for stored cuboids (0 = stored uncompressed, 9 =
    # smallest); a dataset property because the right trade depends on the
    # data (labels compress far better than EM imagery, paper §3.2).
    # ``REPRO_COMPRESS_LEVEL`` overrides it deployment-wide.
    compress_level: int = 1

    @property
    def spatial_rank(self) -> int:
        return len(self.volume_shape)

    @functools.cached_property
    def levels(self) -> Dict[int, CuboidGrid]:
        """Resolution hierarchy; level 0 = full res (paper: bock11 has 9)."""
        out = {}
        for r in range(self.n_resolutions):
            vol = []
            for d, v in enumerate(self.volume_shape):
                vol.append(max(1, v >> r) if d in self.scaled_dims else v)
            out[r] = CuboidGrid(tuple(vol), self.cuboid_shape_at(r, tuple(vol)))
        return out

    def cuboid_shape_at(self, r: int,
                        vol: Tuple[int, ...]) -> Tuple[int, ...]:
        """Anisotropy-aware cuboid shapes (paper Fig 5).

        High resolutions use flat cuboids (128,128,16,...) because one Z step
        spans ~10x the sample length of an X step; once cumulative XY
        downscaling restores isotropy we switch to cubic (64,64,64,...).
        Always ~CUBOID_VOXELS voxels. Trailing (time) dims get the Z shape.
        """
        if self.base_cuboid is not None:
            return tuple(min(c, v) for c, v in zip(self.base_cuboid, vol))
        rank = len(vol)
        if rank == 1:
            return (min(CUBOID_VOXELS, vol[0]),)
        if rank == 2:
            side = int(np.sqrt(CUBOID_VOXELS))
            return tuple(min(side, v) for v in vol)
        if r < 4:
            shape = [128, 128] + [16] * (rank - 2)
        else:
            shape = [64, 64] + [64] * (rank - 2)
        return tuple(min(s, max(1, v)) for s, v in zip(shape, vol))

    def grid(self, r: int) -> CuboidGrid:
        return self.levels[r]


def downsample_block(block: np.ndarray, scaled_dims: Tuple[int, ...],
                     factor: int = 2) -> np.ndarray:
    """Average-pool ``scaled_dims`` by ``factor`` (hierarchy construction)."""
    out = block
    for d in sorted(scaled_dims):
        n = out.shape[d] - out.shape[d] % factor
        sl = [slice(None)] * out.ndim
        sl[d] = slice(0, n)
        trimmed = out[tuple(sl)]
        new_shape = (trimmed.shape[:d] + (n // factor, factor)
                     + trimmed.shape[d + 1:])
        out = trimmed.reshape(new_shape).mean(axis=d + 1)
    return out.astype(block.dtype)


def downsample_labels(block: np.ndarray, scaled_dims: Tuple[int, ...],
                      factor: int = 2) -> np.ndarray:
    """Label-preserving (stride) downsample for annotation hierarchies."""
    sl = [slice(None)] * block.ndim
    for d in scaled_dims:
        sl[d] = slice(0, None, factor)
    return block[tuple(sl)]
