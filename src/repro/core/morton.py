"""N-dimensional Morton (z-order) space-filling curve.

Faithful to OCP (Burns et al., SSDBM'13) §3: cuboids are assigned indexes by
bit-interleaving their per-dimension offsets.  We support *unequal* per-dim
bit widths (anisotropic grids: e.g. a 2^10 x 2^10 x 2^6 cuboid grid) by
skipping exhausted dimensions during interleave, so the index stays dense in
[0, prod(2^bits)).  Properties preserved (and property-tested):

  * encode/decode are bijective on the grid,
  * the index is non-decreasing in every dimension (paper: "cube addresses
    are strictly non-decreasing in each dimension so that the index works on
    subspaces"),
  * any power-of-two aligned subregion is contiguous in the index,
  * `range_decompose` covers an axis-aligned box with a minimal set of
    contiguous index runs (paper: cutouts become few sequential I/Os).

Everything here is pure numpy (host-side index math); `morton_decode_traced`
is a jnp variant usable inside jitted code / Pallas index maps.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import numpy as np

Runs = List[Tuple[int, int]]  # half-open [start, stop) morton-index runs


@functools.lru_cache(maxsize=None)
def bit_placement(bits: Tuple[int, ...]) -> Tuple[Tuple[int, int], ...]:
    """Output-bit layout for interleaving dims with per-dim bit widths.

    Returns a tuple of (dim, src_bit) ordered from LSB (position 0) to MSB.
    Round-robin across dims level by level; dims with fewer bits drop out at
    higher levels, keeping the code dense.
    """
    placement = []
    for level in range(max(bits) if bits else 0):
        for dim, b in enumerate(bits):
            if level < b:
                placement.append((dim, level))
    return tuple(placement)


def grid_bits(grid_shape: Sequence[int]) -> Tuple[int, ...]:
    """Per-dim bit widths for a cuboid-grid shape (rounded up to pow2)."""
    out = []
    for s in grid_shape:
        if s <= 0:
            raise ValueError(f"grid dim must be positive, got {grid_shape}")
        out.append(int(np.ceil(np.log2(s))) if s > 1 else 0)
    return tuple(out)


def morton_encode(coords, bits: Tuple[int, ...]):
    """Vectorized Morton encode. coords: (..., d) int array -> (...) int64."""
    coords = np.asarray(coords, dtype=np.int64)
    placement = bit_placement(bits)
    out = np.zeros(coords.shape[:-1], dtype=np.int64)
    for pos, (dim, src_bit) in enumerate(placement):
        out |= ((coords[..., dim] >> src_bit) & 1) << pos
    return out


def morton_decode(idx, bits: Tuple[int, ...]):
    """Vectorized Morton decode. idx: (...) int -> (..., d) int64."""
    idx = np.asarray(idx, dtype=np.int64)
    placement = bit_placement(bits)
    out = np.zeros(idx.shape + (len(bits),), dtype=np.int64)
    for pos, (dim, src_bit) in enumerate(placement):
        out[..., dim] |= ((idx >> pos) & 1) << src_bit
    return out


def morton_decode_traced(idx, bits: Tuple[int, ...]):
    """jnp-traceable decode of a scalar/array morton index -> tuple of coords.

    Usable inside jit / Pallas ``index_map`` (pure bit ops on traced ints).
    """
    import jax.numpy as jnp

    placement = bit_placement(bits)
    coords = [jnp.zeros_like(idx) for _ in bits]
    for pos, (dim, src_bit) in enumerate(placement):
        coords[dim] = coords[dim] | (((idx >> pos) & 1) << src_bit)
    return tuple(coords)


def total_bits(bits: Tuple[int, ...]) -> int:
    return int(sum(bits))


def range_decompose(lo: Sequence[int], hi: Sequence[int],
                    bits: Tuple[int, ...], max_runs: int | None = None) -> Runs:
    """Decompose the axis-aligned box [lo, hi) into contiguous Morton runs.

    Recursive descent over the implicit 2^d tree defined by the interleave
    layout: at output bit position p (MSB→LSB) the curve splits the current
    power-of-two cell in half along ``placement[p]``'s dimension.  Cells
    fully inside the box emit one run; disjoint cells prune; partial cells
    recurse.  Adjacent runs merge, so aligned boxes come back as ONE run
    (paper §3: "any power-of-two aligned subregion is wholly contiguous").

    ``max_runs``: optional coarsening — if the exact decomposition would
    exceed this, greedily merge nearest runs (reading + discarding a little
    extra data, like rounding a cutout up to cuboid boundaries).
    """
    lo = [int(x) for x in lo]
    hi = [int(x) for x in hi]
    d = len(bits)
    if len(lo) != d or len(hi) != d:
        raise ValueError("lo/hi rank mismatch with bits")
    for dim in range(d):
        if not (0 <= lo[dim] <= hi[dim] <= (1 << bits[dim])):
            raise ValueError(
                f"box [{lo},{hi}) out of grid range for bits={bits}")
        if lo[dim] == hi[dim]:
            return []

    placement = bit_placement(bits)
    nbits = len(placement)
    runs: Runs = []

    # Iterative DFS; state = (pos, start_index, cell_lo tuple). pos counts
    # from the MSB side: output bit index = nbits - 1 - pos.
    stack = [(0, 0, tuple(0 for _ in range(d)))]
    while stack:
        pos, start, cell_lo = stack.pop()
        span = 1 << (nbits - pos)  # indices covered by this cell
        # Cell extent per dim given remaining bits.
        remaining = [0] * d
        for p in range(pos, nbits):
            dim, _ = placement[nbits - 1 - p]
            remaining[dim] += 1
        contained = True
        disjoint = False
        for dim in range(d):
            c_lo = cell_lo[dim]
            c_hi = c_lo + (1 << remaining[dim])
            if c_hi <= lo[dim] or c_lo >= hi[dim]:
                disjoint = True
                break
            if not (lo[dim] <= c_lo and c_hi <= hi[dim]):
                contained = False
        if disjoint:
            continue
        if contained or pos == nbits:
            if runs and runs[-1][1] == start:
                runs[-1] = (runs[-1][0], start + span)
            else:
                runs.append((start, start + span))
            continue
        dim, src_bit = placement[nbits - 1 - pos]
        half = 1 << src_bit
        hi_cell = list(cell_lo)
        hi_cell[dim] += half
        # Push child 1 first so child 0 pops first (curve order, enables
        # the adjacent-run merge above).
        stack.append((pos + 1, start + span // 2, tuple(hi_cell)))
        stack.append((pos + 1, start, cell_lo))

    if max_runs is not None and len(runs) > max_runs:
        runs = coarsen_runs(runs, max_runs)
    return runs


def coarsen_runs(runs: Runs, max_runs: int) -> Runs:
    """Merge nearest runs until len <= max_runs (reads extra, never less)."""
    runs = sorted(runs)
    while len(runs) > max_runs:
        # find smallest gap
        gaps = [(runs[i + 1][0] - runs[i][1], i) for i in range(len(runs) - 1)]
        _, i = min(gaps)
        runs[i:i + 2] = [(runs[i][0], runs[i + 1][1])]
    return runs


def runs_to_indices(runs: Runs) -> np.ndarray:
    """Expand runs to a flat int64 array of morton indices (curve order)."""
    if not runs:
        return np.zeros((0,), dtype=np.int64)
    return np.concatenate([np.arange(a, b, dtype=np.int64) for a, b in runs])


def indices_to_runs(cells: Sequence[int]) -> Runs:
    """Collapse *sorted* morton indices into minimal contiguous runs —
    the inverse of `runs_to_indices` (few sequential I/Os, paper C7)."""
    runs: Runs = []
    for m in cells:
        m = int(m)
        if runs and runs[-1][1] == m:
            runs[-1] = (runs[-1][0], m + 1)
        else:
            runs.append((m, m + 1))
    return runs


def hilbert_decode_2d(t, order: int):
    """Vectorized 2-d Hilbert curve decode: t -> (x, y) on a 2^order grid.

    The paper (§3) notes the Hilbert curve has the best clustering
    properties [Moon et al.] but picks Morton for simplicity. We provide
    both: Hilbert's every-step-changes-one-coordinate property is exactly
    what a capacity-1 block-reuse schedule (Pallas consecutive-DMA skip)
    wants, while Morton needs a small LRU panel cache to win.
    """
    t = np.asarray(t, dtype=np.int64)
    x = np.zeros_like(t)
    y = np.zeros_like(t)
    tt = t.copy()
    for s in range(order):
        rx = (tt >> 1) & 1
        ry = (tt ^ rx) & 1
        # rotate quadrant
        swap = ry == 0
        flip = swap & (rx == 1)
        side = (1 << s)
        x_f = np.where(flip, side - 1 - x, x)
        y_f = np.where(flip, side - 1 - y, y)
        x_r = np.where(swap, y_f, x_f)
        y_r = np.where(swap, x_f, y_f)
        x = x_r + rx * side
        y = y_r + ry * side
        tt >>= 2
    return x, y


def hilbert_decode_2d_traced(t, order: int):
    """jnp-traceable 2-d Hilbert decode (usable in Pallas index maps)."""
    import jax.numpy as jnp

    x = jnp.zeros_like(t)
    y = jnp.zeros_like(t)
    tt = t
    for s in range(order):
        rx = (tt >> 1) & 1
        ry = (tt ^ rx) & 1
        swap = ry == 0
        flip = swap & (rx == 1)
        side = 1 << s
        x_f = jnp.where(flip, side - 1 - x, x)
        y_f = jnp.where(flip, side - 1 - y, y)
        x_r = jnp.where(swap, y_f, x_f)
        y_r = jnp.where(swap, x_f, y_f)
        x = x_r + rx * side
        y = y_r + ry * side
        tt = tt >> 2
    return x, y


def partition_curve(n_cells: int, n_parts: int) -> List[Tuple[int, int]]:
    """Partition [0, n_cells) of the curve into n_parts contiguous segments.

    Paper §4.1 / Fig 4: sharding is implemented by partitioning the Morton
    curve; each node owns one contiguous segment, so each node's data is
    spatially compact and reads within a node stay sequential.
    """
    if n_parts <= 0:
        raise ValueError("n_parts must be positive")
    base, rem = divmod(n_cells, n_parts)
    parts = []
    start = 0
    for i in range(n_parts):
        size = base + (1 if i < rem else 0)
        parts.append((start, start + size))
        start += size
    return parts


def owner_of(idx, n_cells: int, n_parts: int):
    """Vectorized owner lookup for morton index(es) under partition_curve.

    Evaluated against the explicit boundary list rather than the old
    closed-form ``idx // base`` arithmetic: with ``n_parts > n_cells``
    (tiny grids at coarse resolutions) ``base == 0`` and the division
    form mis-assigns owners past the cutoff, while ``searchsorted`` over
    the boundaries is correct for every segment shape — including the
    empty segments rebalancing produces.
    """
    return Partition.even(n_cells, n_parts).owner(idx)


@dataclasses.dataclass(frozen=True)
class Partition:
    """Explicit contiguous partition of the curve [0, n_cells).

    ``bounds`` has ``n_parts + 1`` non-decreasing entries: part ``i`` owns
    the half-open segment ``[bounds[i], bounds[i+1])``.  This is the
    ownership function made *movable* (paper §6 "dynamically redistribute
    data"): `partition_curve` is the even default, `balanced` re-cuts the
    boundaries by occupancy, and `moves` diffs two partitions into the
    segment migrations a rebalance must perform.  Empty segments (equal
    adjacent bounds) are legal everywhere — a node may own nothing at a
    resolution — and `owner`/`split` skip them.
    """

    bounds: Tuple[int, ...]

    def __post_init__(self):
        bounds = tuple(int(b) for b in self.bounds)
        object.__setattr__(self, "bounds", bounds)
        if len(bounds) < 2:
            raise ValueError("bounds needs >= 2 entries (one segment)")
        if bounds[0] != 0:
            raise ValueError(f"bounds must start at 0, got {bounds[0]}")
        if any(a > b for a, b in zip(bounds, bounds[1:])):
            raise ValueError(f"bounds must be non-decreasing: {bounds}")
        object.__setattr__(self, "_bounds_arr", np.asarray(bounds, dtype=np.int64))

    @staticmethod
    def even(n_cells: int, n_parts: int) -> "Partition":
        """The `partition_curve` default as an explicit boundary list."""
        return Partition.from_segments(partition_curve(n_cells, n_parts))

    @staticmethod
    def from_segments(segments: Sequence[Tuple[int, int]]) -> "Partition":
        bounds = [0]
        for a, b in segments:
            if a != bounds[-1]:
                raise ValueError(f"segments not contiguous at {a}")
            bounds.append(b)
        return Partition(tuple(bounds))

    @staticmethod
    def balanced(cells: Sequence[int], n_cells: int, n_parts: int) -> "Partition":
        """Occupancy-balanced boundaries: ~equal key counts per part.

        ``cells`` is the (multiset of) occupied morton indexes — one entry
        per stored key, so multi-channel cells weigh more.  Boundaries are
        quantile cuts of the sorted occupancy; an empty occupancy falls
        back to the even split.
        """
        if n_parts <= 0:
            raise ValueError("n_parts must be positive")
        cells = np.sort(np.asarray(cells, dtype=np.int64))
        if cells.size == 0:
            return Partition.even(n_cells, n_parts)
        if cells[0] < 0 or cells[-1] >= n_cells:
            raise ValueError("occupied cell out of range")
        cuts = []
        for i in range(1, n_parts):
            ideal = (i * cells.size) // n_parts
            v = int(cells[ideal])
            # A cut can only land on a cell boundary; duplicates (multi-
            # channel keys) make the two candidate boundaries around the
            # ideal count differ — take whichever splits closer to it.
            below = int(np.searchsorted(cells, v, side="left"))
            above = int(np.searchsorted(cells, v, side="right"))
            cuts.append(v if ideal - below < above - ideal else v + 1)
        cuts = np.minimum.accumulate(np.minimum(cuts, n_cells)[::-1])[::-1]
        cuts = np.maximum.accumulate(cuts)  # keep bounds non-decreasing
        return Partition((0, *(int(c) for c in cuts), int(n_cells)))

    @property
    def n_parts(self) -> int:
        return len(self.bounds) - 1

    @property
    def n_cells(self) -> int:
        return self.bounds[-1]

    def segments(self) -> List[Tuple[int, int]]:
        return list(zip(self.bounds[:-1], self.bounds[1:]))

    def owner(self, idx):
        """Owning part of morton index(es); scalar in, scalar out.

        ``searchsorted(..., 'right') - 1`` lands on the *last* segment
        whose start is <= idx, which is exactly the non-empty one — empty
        segments (zero span) can never win, so ownership stays total even
        when ``n_parts > n_cells`` or rebalanced bounds collapse a node's
        segment to nothing.
        """
        arr = np.asarray(idx, dtype=np.int64)
        if arr.size and (arr.min() < 0 or arr.max() >= max(self.n_cells, 1)):
            raise ValueError(f"morton index out of range [0, {self.n_cells})")
        owner = np.searchsorted(self._bounds_arr, arr, side="right") - 1
        return owner if arr.ndim else int(owner)

    def split(self, start: int, stop: int) -> List[Tuple[int, int, int]]:
        """Split curve run [start, stop) at partition boundaries.

        Returns [(part, start, stop), ...] in curve order; every piece is
        non-empty and wholly owned.  Empty segments are skipped rather
        than walked into (the historical ``node += 1`` scan emitted
        zero-length pieces and could run off the segment list).
        """
        if not (0 <= start <= stop <= self.n_cells):
            raise ValueError(f"run [{start},{stop}) outside [0, {self.n_cells})")
        pieces: List[Tuple[int, int, int]] = []
        while start < stop:
            part = int(np.searchsorted(self._bounds_arr, start, side="right")) - 1
            piece_stop = min(stop, self.bounds[part + 1])
            pieces.append((part, start, piece_stop))
            start = piece_stop
        return pieces

    def moves(self, new: "Partition") -> List[Tuple[int, int, int, int]]:
        """Diff against ``new``: [(start, stop, src, dst), ...] runs whose
        owner changes — the segment migrations a rebalance performs."""
        if new.n_cells != self.n_cells:
            raise ValueError("partitions cover different curves")
        cuts = sorted(set(self.bounds) | set(new.bounds))
        out: List[Tuple[int, int, int, int]] = []
        for a, b in zip(cuts, cuts[1:]):
            if a == b:
                continue
            src, dst = self.owner(a), new.owner(a)
            if src == dst:
                continue
            if out and out[-1][1] == a and out[-1][2:] == (src, dst):
                out[-1] = (out[-1][0], b, src, dst)
            else:
                out.append((a, b, src, dst))
        return out
