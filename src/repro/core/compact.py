"""Compaction: merge sealed log segments into the read tier, Morton order.

The other half of the paper's write/read split (§4.1): writes land
sequentially on the log tier (`repro.core.wal`); a background merge —
"migrate write-hot databases back to the disk nodes when they cool" —
moves them into the compacted `DirectoryBackend` the cold read path
streams from.  The merge is Morton-ordered (the log index sorts by
(r, c, m)), so the read tier keeps its curve-sequential layout.

Coherence: each batch copies under ``store._lock`` — the same lock the
write-behind flusher and ``migrate()`` take — with a CAS per entry
(`LogBackend.entry_value`): a key superseded mid-compaction is skipped,
its newer version belongs to a later segment.  The read-tier copy lands
*before* the index entry drops, so a concurrent read sees either the log
copy or the read-tier copy — bit-identical.  Values never change, so no
cache invalidation or write-generation bump is needed.

Crash safety rides on ordering: segments are processed and removed
strictly ascending, so the surviving log is always a suffix of history —
replay after a crash can re-apply a record already compacted (idempotent,
same bytes) but can never resurrect an older version over a newer one.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

from ..obs import trace
from ..obs.registry import REGISTRY
from .store import CuboidStore, crashpoint
from .wal import LogBackend


@dataclasses.dataclass
class CompactionStats:
    """One compaction run's work (also accumulated on
    ``store.compactions``)."""

    segments: int = 0    # sealed segments fully merged and removed
    keys: int = 0        # index entries applied (puts + tombstones)
    tombstones: int = 0  # of which deletes
    bytes: int = 0       # payload bytes copied to the read tier
    seconds: float = 0.0

    def asdict(self):
        return dataclasses.asdict(self)


def compact_store(store: CuboidStore, max_segments: Optional[int] = None,
                  batch_keys: int = 64, seal: bool = True) -> CompactionStats:
    """Merge flushed log segments into the read tier; returns run stats.

    A no-op (all-zero stats) when the write tier is not a `LogBackend`.
    ``seal=True`` rotates the active segment first so everything flushed
    so far is compactable; ``max_segments`` bounds one run's work (the
    background compactor trickles, an explicit ``POST /compact`` drains).
    """
    stats = CompactionStats()
    log = store.write_backend
    if not isinstance(log, LogBackend):
        return stats
    t0 = time.perf_counter()
    if seal:
        log.seal_active()
    segments = log.sealed_segments()
    if max_segments is not None:
        segments = segments[:max_segments]
    for seg in segments:
        with trace.span("compact.segment", segment=seg):
            entries = log.segment_entries(seg)  # Morton-sorted
            for i in range(0, len(entries), batch_keys):
                batch = entries[i:i + batch_keys]
                # store._lock (rank 40, "store.data") serializes us with
                # the flusher's applies and with migrate() — per-key
                # atomic against every writer.  The nested read-tier and
                # WAL acquisitions below rank higher (50), so the
                # compactor thread stays inside the witnessed order.
                with store._lock:
                    drop = []
                    for key, loc in batch:
                        current, blob = log.entry_value(key, loc)
                        if not current:
                            continue  # superseded: a later segment owns it
                        if blob is None:
                            store.read_backend.delete(key)  # tombstone
                            stats.tombstones += 1
                        else:
                            store.read_backend.put(key, blob)
                            stats.bytes += len(blob)
                        stats.keys += 1
                        drop.append((key, loc))
                    crashpoint("compact.copied")
                    # read-tier copy is live; NOW stop shadowing it
                    log.drop_entries(drop)
            removed = log.remove_segment(seg)
            crashpoint("compact.segment-removed")
        if removed:
            stats.segments += 1
    stats.seconds = time.perf_counter() - t0
    REGISTRY.histogram(
        "repro_compaction_seconds", None,
        "log-to-read-tier compaction run duration",
    ).observe(stats.seconds)
    totals = store.compactions
    totals["runs"] += 1
    totals["segments"] += stats.segments
    totals["keys"] += stats.keys
    totals["tombstones"] += stats.tombstones
    totals["bytes"] += stats.bytes
    totals["seconds"] += stats.seconds
    return stats


class Compactor:
    """Background compactor for one store.

    Wakes every ``interval`` seconds (or on :meth:`poke`) and runs
    :func:`compact_store` when the log holds at least ``min_sealed``
    sealed segments, or when total log bytes exceed ``max_log_bytes``
    (then the active segment is sealed so the backlog can drain).
    ``step()`` runs one deterministic tick without the thread — the shape
    tests and the storage supervisor drive directly.
    """

    def __init__(self, store: CuboidStore, interval: float = 0.25,
                 min_sealed: int = 1,
                 max_log_bytes: Optional[int] = None):
        self.store = store
        self.interval = interval
        self.min_sealed = min_sealed
        self.max_log_bytes = max_log_bytes
        self.runs = 0
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _pressure(self) -> bool:
        log = self.store.write_backend
        if not isinstance(log, LogBackend):
            return False
        s = log.stats()
        if s["sealed"] >= self.min_sealed:
            return True
        return (self.max_log_bytes is not None
                and s["log_bytes"] > self.max_log_bytes)

    def step(self) -> CompactionStats:
        """One tick: compact if there is pressure, else all-zero stats."""
        if not self._pressure():
            return CompactionStats()
        self.runs += 1
        return compact_store(self.store)

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self._wake.wait(self.interval)
                self._wake.clear()
                if self._stop.is_set():
                    return
                self.step()

        self._thread = threading.Thread(
            target=loop, name="ocp-compactor", daemon=True)
        self._thread.start()

    def poke(self) -> None:
        """Wake the background thread now (e.g. after a burst of writes)."""
        self._wake.set()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()
