"""Distributed cutout over a device mesh (paper §4.1 C3, TPU-native).

The paper shards large datasets by partitioning the Morton curve across
database nodes, with application-level request routing. The TPU-native
analogue: the volume lives device-resident as a *cuboid-major* array of
shape ``(n_cells, *cuboid_shape)`` sharded along axis 0 over the mesh
``data`` axis — each device owns one contiguous curve segment (== one
paper "database node"). A cutout is then:

  1. (host, static) box -> Morton runs -> cell indices -> owning devices,
  2. (device, shard_map) each device gathers its local cells,
  3. all_gather + static permutation assembles the dense cutout.

Collective cost is proportional to the cutout, not the volume: only the
touched cells move. This module is also the substrate for the training
data pipeline (`repro.data`): a global batch is a cutout of the token grid.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import morton
from .cuboid import CuboidGrid

try:  # jax >= 0.6: public jax.shard_map with the check_vma kwarg
    _public_shard_map = jax.shard_map

    def _shard_map(f, mesh, in_specs, out_specs):
        return _public_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
except AttributeError:  # jax 0.4.x: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _experimental_sm

    def _shard_map(f, mesh, in_specs, out_specs):
        return _experimental_sm(f, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_rep=False)


def pack_to_cuboids(volume: np.ndarray, grid: CuboidGrid) -> np.ndarray:
    """Dense volume -> (n_cells, *cuboid_shape), rows in Morton order.

    Out-of-volume cells (pow2 padding) are zero — they exist so the row
    index IS the Morton index (lazy cuboids, paper §3.2).
    """
    cs = grid.cuboid_shape
    out = np.zeros((grid.n_cells,) + tuple(cs), dtype=volume.dtype)
    for m in range(grid.n_cells):
        origin = grid.cuboid_origin(m)
        if any(o >= v for o, v in zip(origin, grid.volume_shape)):
            continue
        sl = tuple(slice(o, min(o + c, v))
                   for o, c, v in zip(origin, cs, grid.volume_shape))
        src = volume[sl]
        out[m][tuple(slice(0, s) for s in src.shape)] = src
    return out


def unpack_from_cuboids(packed: np.ndarray, grid: CuboidGrid) -> np.ndarray:
    vol = np.zeros(grid.volume_shape, dtype=packed.dtype)
    cs = grid.cuboid_shape
    for m in range(grid.n_cells):
        origin = grid.cuboid_origin(m)
        if any(o >= v for o, v in zip(origin, grid.volume_shape)):
            continue
        sl = tuple(slice(o, min(o + c, v))
                   for o, c, v in zip(origin, cs, grid.volume_shape))
        vol[sl] = packed[m][tuple(slice(0, h - o) for o, h in
                                  zip(origin, [s.stop for s in sl]))]
    return vol


def shard_cuboids(packed: jax.Array, mesh: Mesh,
                  axis: str = "data") -> jax.Array:
    """Place the cuboid-major array with curve-partitioned ownership."""
    spec = P(axis, *([None] * (packed.ndim - 1)))
    return jax.device_put(packed, NamedSharding(mesh, spec))


def _cutout_plan(grid: CuboidGrid, lo, hi, n_devices: int):
    """Static plan: per-device padded cell lists + assembly permutation."""
    cs = grid.cuboid_shape
    glo = [l // c for l, c in zip(lo, cs)]
    ghi = [-(-h // c) for h, c in zip(hi, cs)]
    gshape = tuple(h - l for l, h in zip(glo, ghi))
    # cells in box-grid order (row-major over the sub-grid)
    mesh_idx = np.meshgrid(*[np.arange(l, h) for l, h in zip(glo, ghi)],
                           indexing="ij")
    coords = np.stack([g.ravel() for g in mesh_idx], axis=-1)
    cells = morton.morton_encode(coords, grid.bits)          # (n_box,)
    n_box = len(cells)

    seg = morton.partition_curve(grid.n_cells, n_devices)
    owner = morton.owner_of(cells, grid.n_cells, n_devices)  # (n_box,)
    counts = np.bincount(owner, minlength=n_devices)
    max_k = max(1, int(counts.max()))
    local_idx = np.zeros((n_devices, max_k), dtype=np.int32)
    slot_of = np.zeros(n_box, dtype=np.int64)  # flat (dev*max_k+slot) per cell
    fill = [0] * n_devices
    for i, (c, o) in enumerate(zip(cells, owner)):
        s = fill[o]
        local_idx[o, s] = c - seg[o][0]     # row within the device's shard
        slot_of[i] = o * max_k + s
        fill[o] += 1
    return gshape, local_idx, slot_of, max_k


def distributed_cutout(packed: jax.Array, grid: CuboidGrid,
                       lo: Sequence[int], hi: Sequence[int],
                       mesh: Mesh, axis: str = "data") -> jax.Array:
    """Dense cutout of [lo, hi) from a curve-sharded cuboid array.

    ``lo``/``hi`` are static (trace-time) — like the paper's URL-specified
    ranges. Assembly (gather + transpose-merge + trim) happens on device.
    """
    lo = tuple(int(x) for x in lo)
    hi = tuple(int(x) for x in hi)
    n_dev = mesh.shape[axis]
    gshape, local_idx, slot_of, max_k = _cutout_plan(grid, lo, hi, n_dev)
    cs = grid.cuboid_shape
    local_idx_j = jnp.asarray(local_idx)                  # (n_dev, max_k)

    ndim_tail = packed.ndim - 1
    in_specs = (jax.sharding.PartitionSpec(axis, *([None] * ndim_tail)),
                jax.sharding.PartitionSpec())
    out_specs = jax.sharding.PartitionSpec()

    def gather_local(shard, idx_table):
        me = jax.lax.axis_index(axis)
        mine = idx_table[me]                               # (max_k,)
        picked = jnp.take(shard, mine, axis=0)             # (max_k, *cs)
        return jax.lax.all_gather(picked, axis)            # (n_dev,max_k,*cs)

    gathered = jax.jit(
        _shard_map(gather_local, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    )(packed, local_idx_j)                                 # replicated

    flat = gathered.reshape((n_dev * max_k,) + tuple(cs))
    ordered = jnp.take(flat, jnp.asarray(slot_of), axis=0)  # box-grid order
    blocks = ordered.reshape(tuple(gshape) + tuple(cs))
    # interleave grid and intra-cuboid axes: (g0,c0,g1,c1,...) then merge
    rank = len(cs)
    perm = []
    for d in range(rank):
        perm += [d, rank + d]
    merged = blocks.transpose(perm).reshape(
        tuple(g * c for g, c in zip(gshape, cs)))
    glo = [l // c * c for l, c in zip(lo, cs)]
    trim = tuple(slice(l - a, h - a) for l, h, a in zip(lo, hi, glo))
    return merged[trim]


def distributed_write_cutout(packed: jax.Array, grid: CuboidGrid,
                             lo: Sequence[int], data: jax.Array,
                             mesh: Mesh, axis: str = "data") -> jax.Array:
    """Functional distributed write: returns updated cuboid array.

    Analogue of the paper's write path; each device applies updates only to
    its own curve segment (no cross-device write traffic — the update is
    broadcast and masked locally, writes stay node-local as in §4.1).
    """
    lo = tuple(int(x) for x in lo)
    hi = tuple(l + s for l, s in zip(lo, data.shape))
    n_dev = mesh.shape[axis]
    cs = grid.cuboid_shape
    glo = [l // c for l, c in zip(lo, cs)]
    ghi = [-(-h // c) for h, c in zip(hi, cs)]
    gshape = tuple(h - l for l, h in zip(glo, ghi))
    # pad data out to the cuboid-aligned box; an explicit mask marks which
    # voxels the write covers (numeric data overwrites fully inside the box)
    alo = [g * c for g, c in zip(glo, cs)]
    pad_widths = []
    for l, h, a, gl, g, c in zip(lo, hi, alo, glo, gshape, cs):
        before = l - a
        after = (gl + g) * c - h
        pad_widths.append((before, after))
    dpad = jnp.pad(data, pad_widths)
    mpad = jnp.pad(jnp.ones(data.shape, dtype=bool), pad_widths)
    # split into blocks: reshape to (g0,c0,g1,c1,...) -> (n_box, *cs)
    rank = len(cs)
    shape_i = []
    for g, c in zip(gshape, cs):
        shape_i += [g, c]
    perm = list(range(0, 2 * rank, 2)) + list(range(1, 2 * rank, 2))
    dblocks = dpad.reshape(shape_i).transpose(perm).reshape(
        (-1,) + tuple(cs))
    mblocks = mpad.reshape(shape_i).transpose(perm).reshape(
        (-1,) + tuple(cs))

    mesh_idx = np.meshgrid(*[np.arange(l, h) for l, h in zip(glo, ghi)],
                           indexing="ij")
    coords = np.stack([g.ravel() for g in mesh_idx], axis=-1)
    cells = morton.morton_encode(coords, grid.bits)
    seg = morton.partition_curve(grid.n_cells, n_dev)
    seg_starts = jnp.asarray(np.array([a for a, _ in seg], dtype=np.int32))
    cells_j = jnp.asarray(cells.astype(np.int32))

    ndim_tail = packed.ndim - 1
    pspec = jax.sharding.PartitionSpec(axis, *([None] * ndim_tail))
    rep = jax.sharding.PartitionSpec()

    def apply_local(shard, dblk, mblk, cells_, seg_starts_):
        me = jax.lax.axis_index(axis)
        start = seg_starts_[me]
        n_local = shard.shape[0]

        def body(i, acc):
            cell = cells_[i]
            row = cell - start
            in_range = (row >= 0) & (row < n_local)
            row_c = jnp.clip(row, 0, n_local - 1)
            cur = acc[row_c]
            new = jnp.where(mblk[i], dblk[i].astype(acc.dtype), cur)
            new = jnp.where(in_range, new, cur)
            return acc.at[row_c].set(new)

        return jax.lax.fori_loop(0, dblk.shape[0], body, shard)

    updated = jax.jit(
        _shard_map(apply_local, mesh=mesh,
                   in_specs=(pspec, rep, rep, rep, rep),
                   out_specs=pspec)
    )(packed, dblocks, mblocks, cells_j, seg_starts)
    return updated
