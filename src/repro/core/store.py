"""Cuboid storage with separated read/write I/O paths (paper §4.1, C4).

The paper directs *reads* to parallel disk arrays and *small random writes*
to SSD nodes, and migrates write-hot databases back to the disk nodes when
they cool. We reproduce the architecture: a `CuboidStore` is backed by a
*read path* (bulk, sequential-friendly, the "database node") and an optional
*write path* (an absorbing write-back store, the "SSD node"). Both paths are
instrumented so the Fig 13 experiment (SSD vs DB small random writes) is a
measurable property of the system rather than prose.

Storage itself is a dict or directory of gzip-compressed cuboids keyed by
(resolution, channel, morton_index). Lazy allocation: a missing cuboid reads
as zeros and occupies no storage (paper §3.2).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .cuboid import DatasetSpec

Key = Tuple[int, int, int]  # (resolution, channel, morton index)


@dataclasses.dataclass
class PathStats:
    """Per-path I/O counters.

    ``reads`` counts every cuboid lookup served by the path; with a cache
    attached every lookup also increments exactly one of ``cache_hits`` /
    ``cache_misses`` (on the read path), so for a cache-enabled store
    ``read_stats.reads + write_stats.reads ==
    read_stats.cache_hits + read_stats.cache_misses`` — the coherence
    invariant the stress suite asserts.  ``queue_depth`` / ``queue_peak``
    mirror the write-behind queue occupancy (gauges, updated on enqueue
    and flush).
    """

    reads: int = 0
    read_bytes: int = 0
    writes: int = 0
    write_bytes: int = 0
    seeks: int = 0          # discontiguous accesses (run boundaries)
    time_s: float = 0.0
    cache_hits: int = 0     # lookups served by the hot-cuboid cache
    cache_misses: int = 0   # lookups that had to go below the cache
    queue_depth: int = 0    # write-behind pending writes (gauge)
    queue_peak: int = 0     # max pending writes observed (gauge)

    def snapshot(self) -> "PathStats":
        return dataclasses.replace(self)


class Backend:
    """Minimal KV backend for compressed cuboids."""

    def get(self, key: Key) -> Optional[bytes]:
        raise NotImplementedError

    def put(self, key: Key, blob: bytes) -> None:
        raise NotImplementedError

    def delete(self, key: Key) -> None:
        raise NotImplementedError

    def keys(self) -> Iterable[Key]:
        raise NotImplementedError

    def __contains__(self, key: Key) -> bool:
        return self.get(key) is not None

    # -- batch ops (paper C7: a cutout is few sequential I/Os, not many
    # random ones).  Backends override when they can do better than a loop.
    def get_many(self, keys: Sequence[Key]) -> List[Optional[bytes]]:
        """Fetch many blobs in one backend call (order preserved)."""
        return [self.get(k) for k in keys]

    def put_many(self, items: Sequence[Tuple[Key, bytes]]) -> None:
        """Store many blobs in one backend call."""
        for k, blob in items:
            self.put(k, blob)


class MemoryBackend(Backend):
    def __init__(self):
        self._d: Dict[Key, bytes] = {}
        self._lock = threading.Lock()

    def get(self, key):
        return self._d.get(key)

    def put(self, key, blob):
        with self._lock:
            self._d[key] = blob

    def delete(self, key):
        with self._lock:
            self._d.pop(key, None)

    def keys(self):
        return list(self._d.keys())

    def __contains__(self, key):
        return key in self._d

    def get_many(self, keys):
        d = self._d
        return [d.get(k) for k in keys]

    def put_many(self, items):
        with self._lock:
            self._d.update(items)


class DirectoryBackend(Backend):
    """One file per cuboid, laid out r/channel/morton.bin.

    Mirrors the paper's CATMAID re-layout (§3.3): grouping by resolution
    first keeps each directory a single access plane and bounds dirsize.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: Key) -> str:
        r, c, m = key
        return os.path.join(self.root, str(r), str(c), f"{m:016x}.bin")

    def get(self, key):
        p = self._path(key)
        if not os.path.exists(p):
            return None
        with open(p, "rb") as f:
            return f.read()

    def put(self, key, blob):
        p = self._path(key)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        tmp = p + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, p)  # atomic

    def delete(self, key):
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self):
        # Tolerate foreign entries anywhere in the tree (editor droppings,
        # .tmp files from interrupted puts, stray data dirs): only
        # <digits>/<digits>/<hex>.bin regular files are cuboids.
        for r in os.listdir(self.root):
            rd = os.path.join(self.root, r)
            if not r.isdigit() or not os.path.isdir(rd):
                continue
            for c in os.listdir(rd):
                cd = os.path.join(rd, c)
                if not c.isdigit() or not os.path.isdir(cd):
                    continue
                for fn in os.listdir(cd):
                    if not fn.endswith(".bin"):
                        continue
                    try:
                        m = int(fn[:-4], 16)
                    except ValueError:
                        continue
                    if not os.path.isfile(os.path.join(cd, fn)):
                        continue
                    yield (int(r), int(c), m)

    def __contains__(self, key):
        return os.path.exists(self._path(key))


def compress(arr: np.ndarray, level: int = 1) -> bytes:
    """gzip/zlib cuboid compression (paper §3.2: labels compress well)."""
    return zlib.compress(np.ascontiguousarray(arr).tobytes(), level)


def decompress(blob: bytes, shape, dtype) -> np.ndarray:
    return np.frombuffer(zlib.decompress(blob), dtype=dtype).reshape(shape)


class CuboidStore:
    """Cuboid store for one dataset: lazy, compressed, path-separated.

    ``write_path_backend`` (the "SSD node") absorbs writes when attached;
    reads consult it first (freshest), then the read path. ``migrate()``
    flushes write-path contents into the read path — the paper's
    dump-and-restore migration performed when a project cools down.

    Two optional memory tiers sit in front of the paths (paper §6 vision,
    see ``repro.cluster.cache``):

    * ``cache`` — a `CuboidCache` fronting the *merged* read view.  Every
      lookup is a hit or a miss; writes absorb into it, so it is never
      stale (read-your-writes).  Attach via the constructor or
      ``repro.cluster.cache.attach_cache``.
    * ``write_behind`` — a `WriteBehindQueue` absorbing writes and
      applying them to the backends from a background flusher.  Reads
      consult its pending map below the cache, so data is readable the
      moment a write returns; ``flush()`` is the durability barrier.
      Attach via ``repro.cluster.cache.enable_write_behind``.
    """

    def __init__(self, spec: DatasetSpec,
                 backend: Optional[Backend] = None,
                 write_path_backend: Optional[Backend] = None,
                 compression_level: int = 1,
                 cache=None):
        self.spec = spec
        self.read_backend = backend or MemoryBackend()
        self.write_backend = write_path_backend
        self.compression_level = compression_level
        self.read_stats = PathStats()
        self.write_stats = PathStats()
        self._np_dtype = np.dtype(spec.dtype)
        self._lock = threading.Lock()
        self.cache = cache                # duck-typed CuboidCache | None
        self.write_behind = None          # duck-typed WriteBehindQueue | None
        # Serializes same-key write *order* across tiers (queue/backends vs
        # cache) and guards read-absorption against concurrent writes.
        self._order_lock = threading.Lock()
        self._write_gen = 0
        # Counter updates are batched per call and applied under this lock
        # so the reads == cache_hits + cache_misses invariant survives
        # concurrent clients (bare += would lose updates).
        self._stats_lock = threading.Lock()

    @property
    def has_cache(self) -> bool:
        return self.cache is not None

    def flush(self) -> int:
        """Durability barrier: block until pending write-behind writes are
        applied to the backends.  Returns the number drained (0 if no
        queue is attached)."""
        if self.write_behind is None:
            return 0
        n = self.write_behind.flush()
        self.write_stats.queue_depth = self.write_behind.depth
        return n

    def close(self) -> None:
        """Flush and detach the write-behind queue (stops its flusher)."""
        if self.write_behind is not None:
            self.write_behind.close()  # flushes; pending stays readable until drained
            self.write_behind = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- helpers ----------------------------------------------------------
    def _cuboid_shape(self, r: int) -> Tuple[int, ...]:
        return self.spec.grid(r).cuboid_shape

    def _zeros(self, r: int) -> np.ndarray:
        return np.zeros(self._cuboid_shape(r), dtype=self._np_dtype)

    # -- the merged view below the cache -----------------------------------
    def _fetch_misses(self, keys: Sequence[Key]) -> List[Optional[bytes]]:
        """Resolve keys below the cache: pending write-behind values first
        (freshest), then the write path, then the read path.  Maintains the
        per-path read counters (pending hits count on the read path)."""
        blobs: List[Optional[bytes]] = [None] * len(keys)
        resolved = [False] * len(keys)
        pending_hits = 0
        if self.write_behind is not None:
            for i, (found, blob) in enumerate(
                    self.write_behind.peek_many(keys)):
                if found:
                    blobs[i] = blob
                    resolved[i] = True
                    pending_hits += 1
        idx = [i for i in range(len(keys)) if not resolved[i]]
        wp_reads = wp_bytes = rp_reads = rp_bytes = 0
        if idx:
            sub = [keys[i] for i in idx]
            fetched: List[Optional[bytes]] = [None] * len(sub)
            if self.write_backend is not None:
                fetched = list(self.write_backend.get_many(sub))
                hits = [b for b in fetched if b is not None]
                wp_reads = len(hits)
                wp_bytes = sum(len(b) for b in hits)
            still = [j for j, b in enumerate(fetched) if b is None]
            if still:
                got = self.read_backend.get_many([sub[j] for j in still])
                for j, blob in zip(still, got):
                    fetched[j] = blob
                rp_reads = len(still)
                rp_bytes = sum(len(b) for b in got if b is not None)
            for i, blob in zip(idx, fetched):
                blobs[i] = blob
        with self._stats_lock:
            self.read_stats.reads += pending_hits + rp_reads
            self.read_stats.read_bytes += rp_bytes
            self.write_stats.reads += wp_reads
            self.write_stats.read_bytes += wp_bytes
        return blobs

    def _read_gen(self) -> int:
        """Snapshot the write generation for a read-absorb guard.

        Taken under ``_order_lock`` so the snapshot can never land in the
        middle of a writer's critical section: a fetch that starts after
        this either sees the landed write or a generation bump.
        """
        with self._order_lock:
            return self._write_gen

    def _absorb_reads(self, items, gen0: int, blocks=None) -> None:
        """Populate the cache with read results — only if no write raced
        the fetch (``_write_gen`` unchanged since the ``_read_gen``
        snapshot), so a stale blob can never overwrite a fresher absorbed
        write."""
        if self.cache is None:
            return
        with self._order_lock:
            if self._write_gen != gen0:
                return
            for i, (key, blob) in enumerate(items):
                block = blocks[i] if blocks is not None else None
                if blob is not None and block is not None:
                    self.cache.put_block(key, blob, block)
                else:
                    self.cache.put(key, blob)

    def _apply_writes(self, items: Sequence[Tuple[Key, Optional[bytes]]]) -> None:
        """Land compressed writes (``None`` = lazy-zero delete) on every
        tier, in a single serialized order: write-behind queue (or the
        backends directly, under the store lock so ``migrate()`` is
        per-key atomic against us), then the cache — so after this call
        returns the write is readable (read-your-writes)."""
        with self._order_lock:
            self._write_gen += 1
            if self.write_behind is not None:
                self.write_behind.enqueue_many(items)
                self.write_stats.queue_depth = self.write_behind.depth
                self.write_stats.queue_peak = self.write_behind.depth_peak
            else:
                target = self.write_backend or self.read_backend
                puts = [(k, b) for k, b in items if b is not None]
                with self._lock:
                    for k, b in items:
                        if b is None:
                            # lazy allocation: all-zero cuboids occupy no
                            # storage on either path
                            target.delete(k)
                            self.read_backend.delete(k)
                    if puts:
                        target.put_many(puts)
            if self.cache is not None:
                self.cache.put_many(items)

    # -- single-cuboid ops -------------------------------------------------
    def read_cuboid(self, r: int, m: int, channel: int = 0) -> np.ndarray:
        key = (r, channel, m)
        t0 = time.perf_counter()
        hit = False
        blob = None
        if self.cache is not None:
            hit, blob = self.cache.get_blob(key)
            with self._stats_lock:
                if hit:
                    self.read_stats.reads += 1
                    self.read_stats.cache_hits += 1
                else:
                    self.read_stats.cache_misses += 1
        if not hit:
            gen0 = self._read_gen()
            blob = self._fetch_misses([key])[0]
            self._absorb_reads([(key, blob)], gen0)
        if blob is None:
            out = self._zeros(r)  # lazy: absent cuboid reads as zeros
        else:
            out = decompress(blob, self._cuboid_shape(r), self._np_dtype)
        with self._stats_lock:
            self.read_stats.time_s += time.perf_counter() - t0
        return out

    def write_cuboid(self, r: int, m: int, data: np.ndarray,
                     channel: int = 0) -> None:
        if tuple(data.shape) != self._cuboid_shape(r):
            raise ValueError(
                f"cuboid shape {data.shape} != {self._cuboid_shape(r)}")
        key = (r, channel, m)
        t0 = time.perf_counter()
        if not data.any():
            blob = None  # lazy allocation: all-zero cuboids occupy no storage
        else:
            blob = compress(data.astype(self._np_dtype),
                            self.compression_level)
        self._apply_writes([(key, blob)])
        with self._stats_lock:
            self.write_stats.writes += 1
            self.write_stats.write_bytes += len(blob) if blob else 0
            self.write_stats.time_s += time.perf_counter() - t0

    def has_cuboid(self, r: int, m: int, channel: int = 0) -> bool:
        key = (r, channel, m)
        if self.cache is not None:
            hit, blob = self.cache.probe(key)
            if hit:
                return blob is not None
        if self.write_behind is not None:
            found, blob = self.write_behind.peek(key)
            if found:
                return blob is not None
        if self.write_backend is not None and key in self.write_backend:
            return True
        return key in self.read_backend

    # -- run (batch/sequential) ops ----------------------------------------
    def read_run(self, r: int, start: int, stop: int,
                 channel: int = 0) -> List[np.ndarray]:
        """Read a contiguous morton run — ONE sequential pass (paper C7)."""
        blobs = self.fetch_runs(r, [(start, stop)], channel)
        shape = self._cuboid_shape(r)
        return [self._zeros(r) if blobs[m] is None
                else decompress(blobs[m], shape, self._np_dtype)
                for m in range(start, stop)]

    def fetch_runs(self, r: int, runs: Sequence[Tuple[int, int]],
                   channel: int = 0) -> Dict[int, Optional[bytes]]:
        """Batch-fetch compressed blobs for every cuboid in ``runs``.

        Lookup order per key: hot-cuboid cache (when attached), pending
        write-behind values (freshest), then one ``get_many`` per run per
        path — write path first, misses fall through to the read path.
        Absent cuboids come back as ``None`` (lazy zeros) and are cached as
        absences.  Returns {morton_index: blob | None}.
        """
        out: Dict[int, Optional[bytes]] = {}
        cache = self.cache
        for start, stop in runs:
            t0 = time.perf_counter()
            keys = [(r, channel, m) for m in range(start, stop)]
            blobs: List[Optional[bytes]] = [None] * len(keys)
            miss_idx = list(range(len(keys)))
            hits_n = 0
            if cache is not None:
                miss_idx = []
                for i, k in enumerate(keys):
                    hit, blob = cache.get_blob(k)
                    if hit:
                        blobs[i] = blob
                        hits_n += 1
                    else:
                        miss_idx.append(i)
            with self._stats_lock:
                self.read_stats.seeks += 1
                self.read_stats.reads += hits_n
                if cache is not None:
                    self.read_stats.cache_hits += hits_n
                    self.read_stats.cache_misses += len(miss_idx)
            if miss_idx:
                gen0 = self._read_gen()
                sub = [keys[i] for i in miss_idx]
                fetched = self._fetch_misses(sub)
                for i, blob in zip(miss_idx, fetched):
                    blobs[i] = blob
                self._absorb_reads(list(zip(sub, fetched)), gen0)
            with self._stats_lock:
                self.read_stats.time_s += time.perf_counter() - t0
            for m, blob in zip(range(start, stop), blobs):
                out[m] = blob
        return out

    def fetch_blocks(self, r: int, runs: Sequence[Tuple[int, int]],
                     channel: int = 0) -> Dict[int, Optional[np.ndarray]]:
        """Decoded-cuboid variant of :meth:`fetch_runs` (the cutout
        engine's cache fast path): hot cuboids skip backend I/O *and*
        decompression, served as read-only arrays memoized by the cache.
        Returns {morton_index: ndarray | None} (None = lazy zeros).
        """
        shape = self._cuboid_shape(r)
        dtype = self._np_dtype
        cache = self.cache
        if cache is None:
            blobs = self.fetch_runs(r, runs, channel)
            return {m: None if b is None else decompress(b, shape, dtype)
                    for m, b in blobs.items()}
        out: Dict[int, Optional[np.ndarray]] = {}
        for start, stop in runs:
            t0 = time.perf_counter()
            keys = [(r, channel, m) for m in range(start, stop)]
            blocks: List[Optional[np.ndarray]] = [None] * len(keys)
            miss_idx: List[int] = []
            hits_n = 0
            for i, k in enumerate(keys):
                hit, block = cache.get_block(k, shape, dtype)
                if hit:
                    blocks[i] = block
                    hits_n += 1
                else:
                    miss_idx.append(i)
            with self._stats_lock:
                self.read_stats.seeks += 1
                self.read_stats.reads += hits_n
                self.read_stats.cache_hits += hits_n
                self.read_stats.cache_misses += len(miss_idx)
            if miss_idx:
                gen0 = self._read_gen()
                sub = [keys[i] for i in miss_idx]
                fetched = self._fetch_misses(sub)
                decoded = [None if b is None else decompress(b, shape, dtype)
                           for b in fetched]
                for i, block in zip(miss_idx, decoded):
                    blocks[i] = block
                self._absorb_reads(list(zip(sub, fetched)), gen0,
                                   blocks=decoded)
            with self._stats_lock:
                self.read_stats.time_s += time.perf_counter() - t0
            for m, block in zip(range(start, stop), blocks):
                out[m] = block
        return out

    def store_cuboids(self, r: int, blocks: Dict[int, np.ndarray],
                      channel: int = 0) -> None:
        """Batch write: compress all blocks, then ONE ``put_many``.

        Keeps the single-cuboid semantics: shape-checked, all-zero cuboids
        are deleted rather than stored (lazy allocation, paper §3.2), writes
        land on the write path when attached.
        """
        shape = self._cuboid_shape(r)
        t0 = time.perf_counter()
        items: List[Tuple[Key, Optional[bytes]]] = []
        blob_bytes = 0
        for m, data in blocks.items():
            if tuple(data.shape) != shape:
                raise ValueError(f"cuboid shape {data.shape} != {shape}")
            key = (r, channel, m)
            if not data.any():
                items.append((key, None))
                continue
            blob = compress(data.astype(self._np_dtype),
                            self.compression_level)
            blob_bytes += len(blob)
            items.append((key, blob))
        if items:
            self._apply_writes(items)
        with self._stats_lock:
            self.write_stats.writes += len(items)
            self.write_stats.write_bytes += blob_bytes
            self.write_stats.time_s += time.perf_counter() - t0

    def ingest_blobs(self, items: Sequence[Tuple[Key, Optional[bytes]]]) -> None:
        """Land pre-compressed blobs on this store (``None`` = lazy-zero
        delete) — the cluster's segment-migration entry point.

        Blobs move between node shards without a decompress/re-compress
        round trip, through the same single write order as normal writes
        (write-behind queue when attached, then the cache), so a moved key
        is readable here the moment this returns (read-your-writes).
        """
        if not items:
            return
        t0 = time.perf_counter()
        self._apply_writes(list(items))
        with self._stats_lock:
            self.write_stats.writes += len(items)
            self.write_stats.write_bytes += sum(
                len(b) for _, b in items if b is not None)
            self.write_stats.time_s += time.perf_counter() - t0

    def migrate(self) -> int:
        """Flush write path into the read path (paper: SSD→DB migration).

        Pending write-behind writes are flushed first (so nothing is in
        flight), and each key moves under the store lock — a write landing
        concurrently either precedes the move (and is migrated) or follows
        it (and stays on the write path, which reads consult first); it can
        never be silently dropped between the get and the delete.
        """
        self.flush()
        if self.write_backend is None:
            return 0
        n = 0
        for key in list(self.write_backend.keys()):
            with self._lock:
                blob = self.write_backend.get(key)
                if blob is None:
                    continue
                self.read_backend.put(key, blob)
                self.write_backend.delete(key)
            n += 1
        return n

    def stored_keys(self) -> List[Key]:
        self.flush()  # pending write-behind writes count as stored
        keys = set(self.read_backend.keys())
        if self.write_backend is not None:
            keys |= set(self.write_backend.keys())
        return sorted(keys)

    def key_count(self) -> int:
        """Stored-key count *without* the flush barrier: pending
        write-behind puts/deletes are folded in from a queue snapshot.
        The cheap occupancy gauge topology polling wants — a monitoring
        loop must not drain the write-behind queue it is observing."""
        keys = set(self.read_backend.keys())
        if self.write_backend is not None:
            keys |= set(self.write_backend.keys())
        if self.write_behind is not None:
            puts, dels = self.write_behind.pending_keys()
            keys = (keys | puts) - dels
        return len(keys)

    def storage_bytes(self) -> int:
        total = 0
        for key in self.stored_keys():
            blob = (self.write_backend.get(key)
                    if self.write_backend and key in self.write_backend
                    else self.read_backend.get(key))
            total += len(blob or b"")
        return total
