"""Cuboid storage with separated read/write I/O paths (paper §4.1, C4).

The paper directs *reads* to parallel disk arrays and *small random writes*
to SSD nodes, and migrates write-hot databases back to the disk nodes when
they cool. We reproduce the architecture: a `CuboidStore` is backed by a
*read path* (bulk, sequential-friendly, the "database node") and an optional
*write path* (an absorbing write-back store, the "SSD node"). Both paths are
instrumented so the Fig 13 experiment (SSD vs DB small random writes) is a
measurable property of the system rather than prose.

Storage itself is a dict or directory of gzip-compressed cuboids keyed by
(resolution, channel, morton_index). Lazy allocation: a missing cuboid reads
as zeros and occupies no storage (paper §3.2).

The *cold read path* is a pipeline (paper §5: cutout throughput is bound by
assembly — decompress + placement — not disk): ``fetch_blocks`` decodes
blobs in parallel chunks on a shared decode pool, hands each block to the
caller's sink from the worker that decoded it, and (with a cache attached)
prefetches the next curve segments of a planned run schedule into the
hot-cuboid cache while the current one decodes.  :class:`DecodePolicy`
holds the knobs (``REPRO_DECODE_WORKERS`` / ``REPRO_PREFETCH_SEGMENTS``).
"""
from __future__ import annotations

import concurrent.futures as cf
import contextlib
import dataclasses
import os
import threading
import time
import zlib
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..analysis import knobs
from ..analysis.witness import before_submit, ordered_lock
from ..obs import trace
from .cuboid import DatasetSpec

Key = Tuple[int, int, int]  # (resolution, channel, morton index)

# sink(morton, block) — a decoded-cuboid consumer; blocks may arrive from
# decode worker threads, so sinks must be race-free (the cutout engine's
# sink writes disjoint output-buffer slices).
BlockSink = Callable[[int, Optional[np.ndarray]], None]

_MISS = object()  # sentinel: "not in the prefetch handoff" (None = absent)


# -- crash injection (tests only) -----------------------------------------
# The durability contract ("no torn cuboids, acked writes survive") is only
# testable if a crash can be simulated at the exact syscall boundaries the
# contract is about.  Tests install a hook that raises at a named point;
# production never sets one, so crashpoint() is a no-op attribute load.
_CRASH_HOOK: Optional[Callable[[str], None]] = None


def set_crash_hook(hook: Optional[Callable[[str], None]]) -> None:
    """Install (or clear, with ``None``) the process-wide crash hook."""
    global _CRASH_HOOK
    _CRASH_HOOK = hook


def crashpoint(name: str) -> None:
    """Named crash-injection point; calls the installed hook, if any.

    Points on the durable-put path: ``dir.put.written`` (tmp file written,
    not yet synced), ``dir.put.synced`` (tmp durable, not yet published),
    ``dir.put.renamed`` (published, directory entry not yet synced);
    ``wal.append.written`` / ``wal.append.synced`` on the log tier;
    ``compact.copied`` / ``compact.segment-removed`` during compaction.
    """
    hook = _CRASH_HOOK
    if hook is not None:
        hook(name)


@dataclasses.dataclass(frozen=True)
class DecodePolicy:
    """Cold-read pipeline knobs (paper §5: cutouts are assembly-bound).

    ``workers`` sizes the decode thread pool (0/1 = serial decode;
    zlib releases the GIL, so threads buy real decompress parallelism);
    ``chunk`` is the number of cuboids per decode task (amortizes submit
    overhead); ``prefetch_segments`` is how many *future* runs of a
    planned schedule are pulled into the hot-cuboid cache while the
    current one decodes (0 = off; needs a cache as the landing zone).

    ``from_env`` reads the ``REPRO_DECODE_WORKERS`` /
    ``REPRO_PREFETCH_SEGMENTS`` knobs; workers default to the core count.
    """

    workers: int = 0
    chunk: int = 16
    prefetch_segments: int = 0

    @classmethod
    def from_env(cls) -> "DecodePolicy":
        return cls(
            workers=knobs.get_int("REPRO_DECODE_WORKERS", os.cpu_count() or 1),
            prefetch_segments=knobs.get_int("REPRO_PREFETCH_SEGMENTS", 1),
        )


# Decode pools are shared per worker count across every store in the
# process (like numpy's global thread pool): per-store pools would leak
# idle threads for each short-lived store the tests and the cluster
# create, and a ClusterStore's node shards *should* decode into one pool —
# that is exactly the node-parallel pipeline saturating the cores.
_DECODE_POOLS: Dict[int, cf.ThreadPoolExecutor] = {}
_DECODE_POOLS_LOCK = ordered_lock("store.decode_pools", 80)


def _decode_pool(workers: int) -> cf.ThreadPoolExecutor:
    with _DECODE_POOLS_LOCK:
        pool = _DECODE_POOLS.get(workers)
        if pool is None:
            pool = _DECODE_POOLS[workers] = cf.ThreadPoolExecutor(
                max_workers=workers, thread_name_prefix="ocp-decode")
        return pool


@dataclasses.dataclass
class PathStats:
    """Per-path I/O counters.

    ``reads`` counts every cuboid lookup served by the path; with a cache
    attached every lookup also increments exactly one of ``cache_hits`` /
    ``cache_misses`` (on the read path), so for a cache-enabled store
    ``read_stats.reads + write_stats.reads ==
    read_stats.cache_hits + read_stats.cache_misses`` — the coherence
    invariant the stress suite asserts.  ``queue_depth`` / ``queue_peak``
    mirror the write-behind queue occupancy (gauges, updated on enqueue
    and flush); ``queue_retries`` / ``queue_poisoned`` mirror the queue's
    flush-retry and poison-quarantine counters.

    ``decoded_blocks`` / ``decode_s`` measure decompress work on the read
    path (the paper's assembly bound).  ``prefetch_issued`` /
    ``prefetch_cuboids`` count the plan-driven cache prefetcher's
    background work; prefetches are not client reads, so they stay out of
    the reads == hits + misses invariant.
    """

    reads: int = 0
    read_bytes: int = 0
    writes: int = 0
    write_bytes: int = 0
    seeks: int = 0          # discontiguous accesses (run boundaries)
    time_s: float = 0.0
    inflight: int = 0       # cluster read jobs currently on this node (gauge)
    cache_hits: int = 0     # lookups served by the hot-cuboid cache
    cache_misses: int = 0   # lookups that had to go below the cache
    queue_depth: int = 0    # write-behind pending writes (gauge)
    queue_peak: int = 0     # max pending writes observed (gauge)
    queue_retries: int = 0   # write-behind entries applied on a retry pass
    queue_poisoned: int = 0  # write-behind keys quarantined as poison
    decoded_blocks: int = 0  # blobs decompressed on the read path
    decode_s: float = 0.0    # wall time inside decompress (incl. workers)
    prefetch_issued: int = 0    # schedule-lookahead prefetch tasks launched
    prefetch_cuboids: int = 0   # blobs the prefetcher admitted to the cache
    prefetch_errors: int = 0    # lookahead tasks that failed (never fatal)
    tmp_swept: int = 0          # orphaned .tmp files removed on backend open

    def snapshot(self) -> "PathStats":
        return dataclasses.replace(self)


class Backend:
    """Minimal KV backend for compressed cuboids."""

    # Backends that record deletes as durable tombstones (the append-log
    # write tier) set this; a tombstone must *shadow* older data below it
    # in the tier stack until compaction applies the delete for real.
    supports_tombstones = False

    def get(self, key: Key) -> Optional[bytes]:
        raise NotImplementedError

    def put(self, key: Key, blob: bytes) -> None:
        raise NotImplementedError

    def delete(self, key: Key) -> None:
        raise NotImplementedError

    def keys(self) -> Iterable[Key]:
        raise NotImplementedError

    def __contains__(self, key: Key) -> bool:
        return self.get(key) is not None

    # -- batch ops (paper C7: a cutout is few sequential I/Os, not many
    # random ones).  Backends override when they can do better than a loop.
    def get_many(self, keys: Sequence[Key]) -> List[Optional[bytes]]:
        """Fetch many blobs in one backend call (order preserved)."""
        return [self.get(k) for k in keys]

    def put_many(self, items: Sequence[Tuple[Key, bytes]]) -> None:
        """Store many blobs in one backend call."""
        for k, blob in items:
            self.put(k, blob)

    # -- tombstone-aware lookup: (found, blob) ------------------------------
    # ``(True, None)`` means "definitively deleted here" — the merged read
    # view must stop and not fall through to a stale copy below.  For plain
    # backends found == (blob is not None), so these defaults change nothing.
    def probe(self, key: Key) -> Tuple[bool, Optional[bytes]]:
        blob = self.get(key)
        return blob is not None, blob

    def probe_many(
        self, keys: Sequence[Key]
    ) -> List[Tuple[bool, Optional[bytes]]]:
        return [(b is not None, b) for b in self.get_many(keys)]


class MemoryBackend(Backend):
    # Every accessor takes the lock: ``keys()`` snapshotting the dict while
    # the write-behind flusher lands a ``put_many`` raised ``RuntimeError:
    # dictionary changed size during iteration`` mid-rebalance, and the
    # single-op reads ride along for a coherent view (the lock is
    # uncontended in the common case and dict ops are short).

    def __init__(self):
        self._d: Dict[Key, bytes] = {}
        self._lock = ordered_lock("backend.memory", 50)

    def get(self, key):
        with self._lock:
            return self._d.get(key)

    def put(self, key, blob):
        with self._lock:
            self._d[key] = blob

    def delete(self, key):
        with self._lock:
            self._d.pop(key, None)

    def keys(self):
        with self._lock:
            return list(self._d.keys())

    def __contains__(self, key):
        with self._lock:
            return key in self._d

    def get_many(self, keys):
        with self._lock:
            d = self._d
            return [d.get(k) for k in keys]

    def put_many(self, items):
        with self._lock:
            self._d.update(items)


class DirectoryBackend(Backend):
    """One file per cuboid, laid out r/channel/morton.bin.

    Mirrors the paper's CATMAID re-layout (§3.3): grouping by resolution
    first keeps each directory a single access plane and bounds dirsize.

    Durability: ``put`` writes a ``.tmp`` sibling and publishes it with an
    atomic rename.  With ``fsync`` on (explicit arg, else ``REPRO_FSYNC``,
    else off for this bulk read tier) the tmp file is synced *before* the
    rename — so the published name can never point at torn or zero-length
    data — and the directory is synced *after*, so an acked write survives
    a crash.  Orphaned ``.tmp`` files from interrupted puts are swept on
    open and counted in ``swept_tmp`` (surfaced as ``PathStats.tmp_swept``).
    """

    def __init__(self, root: str, fsync: Optional[bool] = None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        if fsync is None:
            fsync = knobs.get_flag("REPRO_FSYNC", default=False)
        self.fsync = bool(fsync)
        self._synced_dirs: set = set()
        self.swept_tmp = self._sweep_tmp()

    def _sweep_tmp(self) -> int:
        swept = 0
        for dirpath, _dirs, files in os.walk(self.root):
            for fn in files:
                if fn.endswith(".tmp"):
                    with contextlib.suppress(OSError):
                        os.remove(os.path.join(dirpath, fn))
                        swept += 1
        return swept

    @staticmethod
    def _sync_dir(path: str) -> None:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _ensure_dir(self, d: str) -> None:
        if d in self._synced_dirs:
            return
        fresh = not os.path.isdir(d)
        os.makedirs(d, exist_ok=True)
        if fresh and self.fsync:
            # first creation: sync the new directory entries up to the root
            # so the r/channel tree itself survives a crash
            step = d
            while True:
                self._sync_dir(step)
                if os.path.samefile(step, self.root):
                    break
                step = os.path.dirname(step)
        self._synced_dirs.add(d)

    def _path(self, key: Key) -> str:
        r, c, m = key
        return os.path.join(self.root, str(r), str(c), f"{m:016x}.bin")

    def get(self, key):
        # EAFP: open directly instead of stat-then-open — the exists()
        # probe was a full extra syscall per cuboid on the cold path
        # (~25% of a cacheless cutout's wall time under profiling).
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except (FileNotFoundError, NotADirectoryError):
            return None

    def put(self, key, blob):
        p = self._path(key)
        self._ensure_dir(os.path.dirname(p))
        tmp = p + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
            crashpoint("dir.put.written")
            if self.fsync:
                f.flush()
                os.fsync(f.fileno())  # data durable BEFORE the name flips
        crashpoint("dir.put.synced")
        os.replace(tmp, p)  # atomic publish
        crashpoint("dir.put.renamed")
        if self.fsync:
            self._sync_dir(os.path.dirname(p))  # make the rename durable

    def delete(self, key):
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self):
        # Tolerate foreign entries anywhere in the tree (editor droppings,
        # .tmp files from interrupted puts, stray data dirs): only
        # <digits>/<digits>/<hex>.bin regular files are cuboids.
        for r in os.listdir(self.root):
            rd = os.path.join(self.root, r)
            if not r.isdigit() or not os.path.isdir(rd):
                continue
            for c in os.listdir(rd):
                cd = os.path.join(rd, c)
                if not c.isdigit() or not os.path.isdir(cd):
                    continue
                for fn in os.listdir(cd):
                    if not fn.endswith(".bin"):
                        continue
                    try:
                        m = int(fn[:-4], 16)
                    except ValueError:
                        continue
                    if not os.path.isfile(os.path.join(cd, fn)):
                        continue
                    yield (int(r), int(c), m)

    def __contains__(self, key):
        return os.path.exists(self._path(key))


def compress(arr: np.ndarray, level: int = 1) -> bytes:
    """gzip/zlib cuboid compression (paper §3.2: labels compress well).

    The codec level is a dataset property (`DatasetSpec.compress_level`,
    overridable via ``REPRO_COMPRESS_LEVEL``); `CuboidStore` resolves it
    once and passes it here on every write.
    """
    return zlib.compress(np.ascontiguousarray(arr).tobytes(), level)


def decompress(blob: bytes, shape, dtype) -> np.ndarray:
    return np.frombuffer(zlib.decompress(blob), dtype=dtype).reshape(shape)


class CuboidStore:
    """Cuboid store for one dataset: lazy, compressed, path-separated.

    ``write_path_backend`` (the "SSD node") absorbs writes when attached;
    reads consult it first (freshest), then the read path. ``migrate()``
    flushes write-path contents into the read path — the paper's
    dump-and-restore migration performed when a project cools down.

    Two optional memory tiers sit in front of the paths (paper §6 vision,
    see ``repro.cluster.cache``):

    * ``cache`` — a `CuboidCache` fronting the *merged* read view.  Every
      lookup is a hit or a miss; writes absorb into it, so it is never
      stale (read-your-writes).  Attach via the constructor or
      ``repro.cluster.cache.attach_cache``.
    * ``write_behind`` — a `WriteBehindQueue` absorbing writes and
      applying them to the backends from a background flusher.  Reads
      consult its pending map below the cache, so data is readable the
      moment a write returns; ``flush()`` is the durability barrier.
      Attach via ``repro.cluster.cache.enable_write_behind``.
    """

    def __init__(self, spec: DatasetSpec,
                 backend: Optional[Backend] = None,
                 write_path_backend: Optional[Backend] = None,
                 compression_level: Optional[int] = None,
                 cache=None,
                 decode_policy: Optional[DecodePolicy] = None):
        self.spec = spec
        self.read_backend = backend or MemoryBackend()
        self.write_backend = write_path_backend
        if compression_level is None:
            # codec level: explicit arg > REPRO_COMPRESS_LEVEL > spec field
            compression_level = knobs.get_int("REPRO_COMPRESS_LEVEL", spec.compress_level)
        self.compression_level = compression_level
        self.decode_policy = decode_policy or DecodePolicy.from_env()
        self.read_stats = PathStats()
        self.write_stats = PathStats()
        self._np_dtype = np.dtype(spec.dtype)
        self._lock = ordered_lock("store.data", 40)
        self.read_stats.tmp_swept = getattr(self.read_backend, "swept_tmp", 0)
        if self.write_backend is not None:
            self.write_stats.tmp_swept = getattr(
                self.write_backend, "swept_tmp", 0)
        # Lifetime compaction totals (log tier → read tier merges); updated
        # by repro.core.compact, surfaced through tier_stats()/GET /stats.
        self.compactions: Dict[str, float] = {
            "runs": 0, "segments": 0, "keys": 0, "tombstones": 0,
            "bytes": 0, "seconds": 0.0}
        self.tier_policy = None           # set by wal.tiered_store
        self._tier_tmpdir = None          # owned scratch root (tiered_store)
        self.cache = cache                # duck-typed CuboidCache | None
        self.write_behind = None          # duck-typed WriteBehindQueue | None
        self.last_prefetch_error: Optional[str] = None  # repr of newest one
        # Serializes same-key write *order* across tiers (queue/backends vs
        # cache) and guards read-absorption against concurrent writes.
        self._order_lock = ordered_lock("store.order", 30)
        self._write_gen = 0
        # Counter updates are batched per call and applied under this lock
        # so the reads == cache_hits + cache_misses invariant survives
        # concurrent clients (bare += would lose updates).
        self._stats_lock = ordered_lock("store.stats", 70)

    @property
    def has_cache(self) -> bool:
        return self.cache is not None

    @contextlib.contextmanager
    def serving(self):
        """Mark one in-flight read job against this node.

        ``read_stats.inflight`` is the instantaneous load gauge a cluster
        uses to pick the least-loaded replica for a read; the cluster wraps
        each per-node fan-out job in this so the signal tracks real
        concurrency, not accumulated history."""
        with self._stats_lock:
            self.read_stats.inflight += 1
        try:
            yield
        finally:
            with self._stats_lock:
                self.read_stats.inflight -= 1

    def flush(self) -> int:
        """Durability barrier: block until pending write-behind writes are
        applied to the backends.  Returns the number drained (0 if no
        queue is attached)."""
        if self.write_behind is None:
            return 0
        n = self.write_behind.flush()
        self.write_stats.queue_depth = self.write_behind.depth
        self.write_stats.queue_retries = self.write_behind.retried
        self.write_stats.queue_poisoned = self.write_behind.poisoned
        return n

    def close(self) -> None:
        """Flush and detach the write-behind queue (stops its flusher);
        release backend file handles (log-tier backends reopen lazily, so
        a closed store's data stays readable) and clean up a scratch root
        owned via ``wal.tiered_store``."""
        if self.write_behind is not None:
            self.write_behind.close()  # flushes; pending stays readable until drained
            self.write_behind = None
        for backend in (self.write_backend, self.read_backend):
            closer = getattr(backend, "close", None)
            if callable(closer):
                closer()
        tmpdir = self._tier_tmpdir
        if tmpdir is not None:
            self._tier_tmpdir = None
            with contextlib.suppress(OSError):
                tmpdir.cleanup()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- helpers ----------------------------------------------------------
    def _cuboid_shape(self, r: int) -> Tuple[int, ...]:
        return self.spec.grid(r).cuboid_shape

    def _zeros(self, r: int) -> np.ndarray:
        return np.zeros(self._cuboid_shape(r), dtype=self._np_dtype)

    # -- the merged view below the cache -----------------------------------
    def _fetch_misses(self, keys: Sequence[Key],
                      record: bool = True) -> List[Optional[bytes]]:
        """Resolve keys below the cache: pending write-behind values first
        (freshest), then the write path, then the read path.  Maintains the
        per-path read counters (pending hits count on the read path);
        ``record=False`` skips them — background prefetches are not client
        reads and must not disturb the reads == hits + misses invariant."""
        blobs: List[Optional[bytes]] = [None] * len(keys)
        resolved = [False] * len(keys)
        pending_hits = 0
        if self.write_behind is not None:
            for i, (found, blob) in enumerate(
                    self.write_behind.peek_many(keys)):
                if found:
                    blobs[i] = blob
                    resolved[i] = True
                    pending_hits += 1
        idx = [i for i in range(len(keys)) if not resolved[i]]
        wp_reads = wp_bytes = rp_reads = rp_bytes = 0
        if idx:
            sub = [keys[i] for i in idx]
            fetched: List[Optional[bytes]] = [None] * len(sub)
            settled = [False] * len(sub)
            if self.write_backend is not None:
                # probe, not get: a log-tier tombstone is (found, None) —
                # a definitive absence that must NOT fall through to a
                # stale copy still sitting on the read tier
                for j, (found, blob) in enumerate(
                        self.write_backend.probe_many(sub)):
                    if found:
                        fetched[j] = blob
                        settled[j] = True
                        wp_reads += 1
                        wp_bytes += len(blob) if blob is not None else 0
            still = [j for j in range(len(sub)) if not settled[j]]
            if still:
                got = self.read_backend.get_many([sub[j] for j in still])
                for j, blob in zip(still, got):
                    fetched[j] = blob
                rp_reads = len(still)
                rp_bytes = sum(len(b) for b in got if b is not None)
            for i, blob in zip(idx, fetched):
                blobs[i] = blob
        if record:
            with self._stats_lock:
                self.read_stats.reads += pending_hits + rp_reads
                self.read_stats.read_bytes += rp_bytes
                self.write_stats.reads += wp_reads
                self.write_stats.read_bytes += wp_bytes
        return blobs

    def _read_gen(self) -> int:
        """Snapshot the write generation for a read-absorb guard.

        Taken under ``_order_lock`` so the snapshot can never land in the
        middle of a writer's critical section: a fetch that starts after
        this either sees the landed write or a generation bump.
        """
        with self._order_lock:
            return self._write_gen

    def _absorb_reads(self, items, gen0: int, blocks=None) -> None:
        """Populate the cache with read results — only if no write raced
        the fetch (``_write_gen`` unchanged since the ``_read_gen``
        snapshot), so a stale blob can never overwrite a fresher absorbed
        write."""
        if self.cache is None:
            return
        with self._order_lock:
            if self._write_gen != gen0:
                return
            for i, (key, blob) in enumerate(items):
                block = blocks[i] if blocks is not None else None
                if blob is not None and block is not None:
                    self.cache.put_block(key, blob, block)
                else:
                    self.cache.put(key, blob)

    def _apply_writes(self, items: Sequence[Tuple[Key, Optional[bytes]]]) -> None:
        """Land compressed writes (``None`` = lazy-zero delete) on every
        tier, in a single serialized order: write-behind queue (or the
        backends directly, under the store lock so ``migrate()`` is
        per-key atomic against us), then the cache — so after this call
        returns the write is readable (read-your-writes)."""
        with self._order_lock:
            self._write_gen += 1
            if self.write_behind is not None:
                self.write_behind.enqueue_many(items)
                self.write_stats.queue_depth = self.write_behind.depth
                self.write_stats.queue_peak = self.write_behind.depth_peak
                self.write_stats.queue_retries = self.write_behind.retried
                self.write_stats.queue_poisoned = self.write_behind.poisoned
            else:
                target = self.write_backend or self.read_backend
                # A tombstone-capable write tier shadows the read path
                # until compaction applies the delete; other targets need
                # the read-path copy cleared immediately.
                shadow = target.supports_tombstones
                puts = [(k, b) for k, b in items if b is not None]
                with self._lock:
                    for k, b in items:
                        if b is None:
                            # lazy allocation: all-zero cuboids occupy no
                            # storage on either path
                            target.delete(k)
                            if not shadow:
                                self.read_backend.delete(k)
                    if puts:
                        target.put_many(puts)
            if self.cache is not None:
                self.cache.put_many(items)

    # -- single-cuboid ops -------------------------------------------------
    def read_cuboid(self, r: int, m: int, channel: int = 0) -> np.ndarray:
        key = (r, channel, m)
        t0 = time.perf_counter()
        hit = False
        blob = None
        if self.cache is not None:
            hit, blob = self.cache.get_blob(key)
            with self._stats_lock:
                if hit:
                    self.read_stats.reads += 1
                    self.read_stats.cache_hits += 1
                else:
                    self.read_stats.cache_misses += 1
        if not hit:
            gen0 = self._read_gen()
            blob = self._fetch_misses([key])[0]
            self._absorb_reads([(key, blob)], gen0)
        if blob is None:
            out = self._zeros(r)  # lazy: absent cuboid reads as zeros
        else:
            out = decompress(blob, self._cuboid_shape(r), self._np_dtype)
        with self._stats_lock:
            self.read_stats.time_s += time.perf_counter() - t0
        return out

    def write_cuboid(self, r: int, m: int, data: np.ndarray,
                     channel: int = 0) -> None:
        if tuple(data.shape) != self._cuboid_shape(r):
            raise ValueError(
                f"cuboid shape {data.shape} != {self._cuboid_shape(r)}")
        key = (r, channel, m)
        t0 = time.perf_counter()
        if not data.any():
            blob = None  # lazy allocation: all-zero cuboids occupy no storage
        else:
            blob = compress(data.astype(self._np_dtype),
                            self.compression_level)
        self._apply_writes([(key, blob)])
        with self._stats_lock:
            self.write_stats.writes += 1
            self.write_stats.write_bytes += len(blob) if blob else 0
            self.write_stats.time_s += time.perf_counter() - t0

    def has_cuboid(self, r: int, m: int, channel: int = 0) -> bool:
        key = (r, channel, m)
        if self.cache is not None:
            hit, blob = self.cache.probe(key)
            if hit:
                return blob is not None
        if self.write_behind is not None:
            found, blob = self.write_behind.peek(key)
            if found:
                return blob is not None
        if self.write_backend is not None:
            found, blob = self.write_backend.probe(key)
            if found:
                return blob is not None  # tombstone = definitively absent
        return key in self.read_backend

    # -- run (batch/sequential) ops ----------------------------------------
    def read_run(self, r: int, start: int, stop: int,
                 channel: int = 0) -> List[np.ndarray]:
        """Read a contiguous morton run — ONE sequential pass (paper C7)."""
        blobs = self.fetch_runs(r, [(start, stop)], channel)
        shape = self._cuboid_shape(r)
        return [self._zeros(r) if blobs[m] is None
                else decompress(blobs[m], shape, self._np_dtype)
                for m in range(start, stop)]

    def fetch_runs(self, r: int, runs: Sequence[Tuple[int, int]],
                   channel: int = 0, decode: bool = False):
        """Batch-fetch compressed blobs for every cuboid in ``runs``.

        Lookup order per key: hot-cuboid cache (when attached), pending
        write-behind values (freshest), then one ``get_many`` per run per
        path — write path first, misses fall through to the read path.
        Absent cuboids come back as ``None`` (lazy zeros) and are cached as
        absences.  Returns {morton_index: blob | None}.

        ``decode=True`` switches to the pipelined cold-read mode: blobs are
        decompressed *here* (chunked across the decode pool per
        :class:`DecodePolicy`) and the result maps morton index to decoded
        block — the mode the cluster's per-node fan-out workers run so
        decompression parallelizes across nodes and cores.
        """
        if decode:
            return self.fetch_blocks(r, runs, channel)
        out: Dict[int, Optional[bytes]] = {}
        cache = self.cache
        for start, stop in runs:
            t0 = time.perf_counter()
            keys = [(r, channel, m) for m in range(start, stop)]
            blobs: List[Optional[bytes]] = [None] * len(keys)
            miss_idx = list(range(len(keys)))
            hits_n = 0
            if cache is not None:
                miss_idx = []
                for i, k in enumerate(keys):
                    hit, blob = cache.get_blob(k)
                    if hit:
                        blobs[i] = blob
                        hits_n += 1
                    else:
                        miss_idx.append(i)
            with self._stats_lock:
                self.read_stats.seeks += 1
                self.read_stats.reads += hits_n
                if cache is not None:
                    self.read_stats.cache_hits += hits_n
                    self.read_stats.cache_misses += len(miss_idx)
            if miss_idx:
                gen0 = self._read_gen()
                sub = [keys[i] for i in miss_idx]
                with trace.span("store.fetch", cuboids=len(sub)):
                    fetched = self._fetch_misses(sub)
                for i, blob in zip(miss_idx, fetched):
                    blobs[i] = blob
                self._absorb_reads(list(zip(sub, fetched)), gen0)
            with self._stats_lock:
                self.read_stats.time_s += time.perf_counter() - t0
            for m, blob in zip(range(start, stop), blobs):
                out[m] = blob
        return out

    def _fetch_decode_chunks(self, cells: Sequence[int],
                             keys: Sequence[Key], shape, dtype,
                             emit: BlockSink) -> None:
        """The chunked cold-read pipeline: fetch + decode + assemble.

        The miss set is split into chunks of ``DecodePolicy.chunk``
        cuboids; each chunk is an independent stage instance — resolve the
        blobs through the merged read view, decompress them, hand every
        block to ``emit`` from the worker that decoded it (sinks write
        disjoint buffer slices, so this is race-free), then absorb into
        the cache under the generation guard.  Chunks drain from a shared
        work list: pool workers *and the calling thread* pull from it, so
        I/O of one chunk overlaps decompression of another even on a
        single-node store, the caller is never idle, and a saturated pool
        degrades to the caller draining everything itself (no deadlock).
        """
        def run_chunk(lo: int, hi: int) -> None:
            sub = list(keys[lo:hi])
            gen0 = self._read_gen()
            with trace.span("store.fetch", cuboids=hi - lo):
                fetched = self._fetch_misses(sub)
            t0 = time.perf_counter()
            decoded: List[Optional[np.ndarray]] = []
            n_blobs = 0
            with trace.span("decode", cuboids=hi - lo) as tmeta:
                for m, blob in zip(cells[lo:hi], fetched):
                    if blob is None:
                        block = None
                    else:
                        block = decompress(blob, shape, dtype)
                        n_blobs += 1
                    decoded.append(block)
                    emit(m, block)
                if tmeta is not None:
                    tmeta["blobs"] = n_blobs
            dt = time.perf_counter() - t0
            self._absorb_reads(list(zip(sub, fetched)), gen0,
                               blocks=decoded)
            with self._stats_lock:
                self.read_stats.decoded_blocks += n_blobs
                self.read_stats.decode_s += dt

        self._drain_chunks(len(keys), run_chunk)

    def _decode_hit_blobs(self, items: Sequence[Tuple[int, Key, bytes]],
                          shape, dtype, emit: BlockSink) -> None:
        """Parallel decode for blobs that need no backend fetch — cache
        hits that are blob-only, or prefetch-handoff blobs the cache
        refused to admit: chunked across the decode pool like misses,
        memoized back onto the cache entry via ``attach_block`` (a
        silent no-op for keys that are not resident)."""
        cache = self.cache

        def run_chunk(lo: int, hi: int) -> None:
            t0 = time.perf_counter()
            with trace.span("decode", cuboids=hi - lo, source="cache"):
                for m, key, blob in items[lo:hi]:
                    block = decompress(blob, shape, dtype)
                    cache.attach_block(key, blob, block)
                    emit(m, block)
            with self._stats_lock:
                self.read_stats.decoded_blocks += hi - lo
                self.read_stats.decode_s += time.perf_counter() - t0

        self._drain_chunks(len(items), run_chunk)

    def _drain_chunks(self, n: int, run_chunk) -> None:
        """Run ``run_chunk(lo, hi)`` over ``n`` items in
        ``DecodePolicy.chunk``-sized pieces, drained from a shared work
        list by pool workers *and the calling thread* — the caller is
        never idle, and a saturated pool degrades to the caller draining
        everything itself (progress is guaranteed, no deadlock)."""
        pol = self.decode_policy
        step = max(1, pol.chunk)
        bounds = [(lo, min(lo + step, n)) for lo in range(0, n, step)]
        if pol.workers <= 1 or len(bounds) <= 1:
            for lo, hi in bounds:
                run_chunk(lo, hi)
            return
        todo = list(reversed(bounds))  # popped back-first = schedule order
        todo_lock = ordered_lock("store.drain", 81)

        def drain() -> None:
            while True:
                with todo_lock:
                    if not todo:
                        return
                    lo, hi = todo.pop()
                run_chunk(lo, hi)

        # The caller drains too and counts toward its own budget: each
        # caller adds at most (workers - 1) pool tasks on top of itself.
        # Under concurrent callers (cluster node fan-out) the shared pool
        # still caps pooled decode at pol.workers threads process-wide;
        # the callers beyond that are the node workers themselves, which
        # IS the intended node-parallel decode.
        # Pool drains carry the caller's active trace span (bind is the
        # identity when nothing is traced), so a sampled request's decode
        # spans nest under the stage that spawned them.
        pool = _decode_pool(pol.workers)
        before_submit()
        futures = [pool.submit(trace.bind(drain))
                   for _ in range(min(pol.workers - 1, len(bounds) - 1))]
        # Always join the pool drains before returning — an exception in
        # the caller's own drain must not strand workers writing into a
        # buffer the (failed) request has already abandoned.  The work
        # list is cleared first so they stop after their current chunk;
        # the first error (caller's preferentially) is re-raised.
        error: Optional[BaseException] = None
        try:
            drain()
        except BaseException as e:
            error = e
            with todo_lock:
                todo.clear()
        for f in futures:
            try:
                f.result()
            except BaseException as e:
                if error is None:
                    error = e
        if error is not None:
            raise error

    def fetch_blocks(self, r: int, runs: Sequence[Tuple[int, int]],
                     channel: int = 0,
                     sink: Optional[BlockSink] = None
                     ) -> Dict[int, Optional[np.ndarray]]:
        """Decoded-cuboid variant of :meth:`fetch_runs` — the cutout
        engine's one read path, pipelined:

        * hot cuboids come straight from the cache (no backend I/O, no
          decompression — read-only arrays memoized by the cache);
        * misses pipeline in chunks across the shared decode pool
          (:class:`DecodePolicy`): every chunk fetches its blobs through
          the merged read view, decompresses them, and assembles from the
          worker that decoded them, with the calling thread draining
          chunks too — so one chunk's backend I/O overlaps another's
          decompression even on a single node;
        * with a cache attached, the next ``prefetch_segments`` runs of
          the schedule stream into the cache *while the current run
          decodes* (the paper's sequential-read doctrine applied to the
          memory tier).

        With ``sink`` every (morton, block) pair is handed over as soon as
        it is available — possibly from a decode worker thread (sinks must
        be race-free; the cutout engine writes disjoint output-buffer
        slices) — and the returned dict is empty.  Without it, returns
        {morton_index: ndarray | None} (None = lazy zeros).
        """
        shape = self._cuboid_shape(r)
        dtype = self._np_dtype
        cache = self.cache
        out: Dict[int, Optional[np.ndarray]] = {}
        emit: BlockSink = sink if sink is not None else out.__setitem__
        runs = list(runs)
        advance = self._prefetch_plan(r, runs, channel)
        if advance is None:
            # No prefetch landing zone / lookahead: flatten the WHOLE
            # schedule into one chunked pipeline, so short runs (a
            # fragmented box decomposes into many few-cuboid runs) still
            # fetch and decode across the pool instead of serializing at
            # run boundaries.
            t0 = time.perf_counter()
            cells: List[int] = []
            keys: List[Key] = []
            hit_blobs: List[Tuple[int, Key, bytes]] = []
            hits_n = 0
            for start, stop in runs:
                for m in range(start, stop):
                    k = (r, channel, m)
                    if cache is not None:
                        hit, blob, block = cache.peek_block(k)
                        if hit:
                            hits_n += 1
                            if blob is None or block is not None:
                                emit(m, block)
                            else:
                                hit_blobs.append((m, k, blob))
                            continue
                    cells.append(m)
                    keys.append(k)
            with self._stats_lock:
                self.read_stats.seeks += len(runs)
                self.read_stats.reads += hits_n
                if cache is not None:
                    self.read_stats.cache_hits += hits_n
                    self.read_stats.cache_misses += len(keys)
            if cache is not None:
                trace.event("cache.lookup", hits=hits_n, misses=len(keys))
            if hit_blobs:
                self._decode_hit_blobs(hit_blobs, shape, dtype, emit)
            if keys:
                self._fetch_decode_chunks(cells, keys, shape, dtype, emit)
            with self._stats_lock:
                self.read_stats.time_s += time.perf_counter() - t0
            return out
        # Segment-pipelined mode: prefetch is engaged, which implies a
        # cache is attached (advance would be None otherwise).
        for i, (start, stop) in enumerate(runs):
            t0 = time.perf_counter()  # includes any wait on the handoff
            handoff = advance(i)
            keys = [(r, channel, m) for m in range(start, stop)]
            miss_idx: List[int] = []
            hit_blobs: List[Tuple[int, Key, bytes]] = []
            hits_n = 0
            for j, k in enumerate(keys):
                hit, blob, block = cache.peek_block(k)
                if not hit:
                    miss_idx.append(j)
                    continue
                hits_n += 1
                if blob is None or block is not None:
                    emit(start + j, block)  # lazy zero / memoized
                else:
                    hit_blobs.append((start + j, k, blob))
            # Cache misses resolve from the prefetch handoff first (its
            # generation was validated in advance(); the cache is
            # consulted first above, so an absorbed fresher write always
            # wins).  Only the remainder pays a backend fetch.
            pf_pairs: List[Tuple[int, Key, bytes]] = []
            still_missing = miss_idx
            if handoff:
                still_missing = []
                for j in miss_idx:
                    blob = handoff.get(keys[j], _MISS)
                    if blob is _MISS:
                        still_missing.append(j)
                    elif blob is None:
                        emit(start + j, None)  # known absent: lazy zeros
                    else:
                        pf_pairs.append((start + j, keys[j], blob))
            n_handoff = len(miss_idx) - len(still_missing)
            with self._stats_lock:
                self.read_stats.seeks += 1
                self.read_stats.reads += hits_n + n_handoff
                self.read_stats.cache_hits += hits_n
                self.read_stats.cache_misses += len(miss_idx)
            trace.event(
                "cache.lookup", hits=hits_n, misses=len(miss_idx), handoff=n_handoff
            )
            if hit_blobs:  # decode-only work (e.g. prefetched segments)
                self._decode_hit_blobs(hit_blobs, shape, dtype, emit)
            if pf_pairs:  # handed-off blobs: decode-only work too
                self._decode_hit_blobs(pf_pairs, shape, dtype, emit)
            if still_missing:
                self._fetch_decode_chunks(
                    [start + j for j in still_missing],
                    [keys[j] for j in still_missing], shape, dtype, emit)
            with self._stats_lock:
                self.read_stats.time_s += time.perf_counter() - t0
        return out

    # -- plan-driven segment prefetch (paper §5 sequential-read doctrine) --
    def _prefetch_plan(self, r: int, runs: Sequence[Tuple[int, int]],
                       channel: int):
        """Build the schedule-lookahead callback for one planned fetch.

        Returns ``None`` when prefetch cannot engage (no cache to land
        in, lookahead disabled, or a single-run schedule) — the caller
        then flattens the schedule into one chunked pipeline instead.

        ``advance(i)`` keeps the next ``prefetch_segments`` runs after
        ``i`` in flight on the decode pool: each task pulls one future
        run's blobs through the merged read view into the hot-cuboid
        cache (admission-guarded — prefetch never evicts resident data),
        so by the time assembly reaches that run it is all cache hits.
        If run ``i`` itself is still being prefetched, the foreground
        rides that task: ``advance(i)`` waits for it and returns its
        fetched ``{key: blob}`` for direct consumption, so the
        prefetcher's I/O is never wasted even when cache admission was
        refused (budget) — the handoff that turns lookahead into a
        pipeline rather than a race.  The wait is skipped (and the
        handoff abandoned) as soon as any write lands: a generation
        check at both ends guarantees a handed-off blob can never mask
        a fresher write, and a write-heavy interleaving degrades to the
        plain foreground fetch instead of blocking on doomed lookahead.
        """
        pol = self.decode_policy
        depth = pol.prefetch_segments
        if depth <= 0 or self.cache is None or len(runs) <= 1:
            return None  # caller flattens the schedule instead
        if sum(stop - start for start, stop in runs) < 2 * max(1, pol.chunk):
            return None  # too small to amortize lookahead startup
        pool = _decode_pool(max(2, pol.workers))
        inflight: Dict[int, Tuple[int, cf.Future]] = {}

        def advance(i: int) -> Optional[Dict[Key, Optional[bytes]]]:
            gen_now = self._read_gen()
            n = 0
            before_submit()
            for j in range(i + 1, min(i + 1 + depth, len(runs))):
                if j not in inflight:
                    trace.event("prefetch.issue", run=j)
                    inflight[j] = (gen_now, pool.submit(
                        trace.bind(self._prefetch_run), r, runs[j], channel))
                    n += 1
            if n:
                with self._stats_lock:
                    self.read_stats.prefetch_issued += n
            ent = inflight.get(i)
            if ent is None:
                return None
            gen_issue, fut = ent
            if self._read_gen() != gen_issue:
                # a write landed since issue: the task's result is (or
                # will be) stale — don't wait on doomed lookahead
                fut.cancel()
                return None
            if fut.cancel():
                return None  # still queued: fetching beats waiting
            try:
                res = fut.result()
            except Exception as e:
                self._note_prefetch_error(e)
                return None
            if res is None:
                return None
            gen0, blobs = res
            if self._read_gen() != gen0:
                return None  # raced by a write mid-fetch: discard
            return blobs

        return advance

    def _prefetch_run(
        self, r: int, run: Tuple[int, int], channel: int
    ) -> Optional[Tuple[int, Dict[Key, Optional[bytes]]]]:
        """Background task: fetch one future run's blobs ahead of the
        foreground, admitting them to the cache when budget allows.

        Returns ``(gen0, {key: blob})`` so ``advance`` can hand the
        fetched blobs straight to the foreground even when the cache
        refused admission — lookahead I/O is consumed either way.
        Coherent on both tiers: keys resolve through the same merged
        view as reads (pending write-behind values first), cache
        admission is generation-guarded under the order lock, and the
        caller re-validates ``gen0`` before consuming the handoff — a
        stale blob can never mask a fresher write.  Failures never break
        the foreground read prefetch is trying to speed up: the task
        returns ``None``, but the error is *recorded* —
        ``prefetch_errors`` counts it and ``last_prefetch_error`` keeps
        the most recent repr for `GET /stats` debugging — rather than
        silently swallowed (lint L005).
        """
        try:
            cache = self.cache
            if cache is None:
                return None
            keys = [(r, channel, m) for m in range(run[0], run[1])
                    if not cache.probe((r, channel, m))[0]]
            if not keys:
                return None
            gen0 = self._read_gen()
            blobs = self._fetch_misses(keys, record=False)
            # Admission precheck gates only the CACHE population: with no
            # spare budget even for entry overheads, put_prefetched would
            # reject wholesale — skip the lock traffic, the handoff still
            # delivers the blobs to the foreground.
            spare = cache.max_bytes - cache.bytes
            if spare > getattr(cache, "entry_overhead", 0) * len(keys):
                with self._order_lock:
                    if self._write_gen == gen0:
                        admitted, _ = cache.put_prefetched(
                            list(zip(keys, blobs)))
                        if admitted:
                            with self._stats_lock:
                                self.read_stats.prefetch_cuboids += admitted
            return gen0, dict(zip(keys, blobs))
        except Exception as e:
            self._note_prefetch_error(e)
            return None

    def _note_prefetch_error(self, exc: BaseException) -> None:
        """Count a failed lookahead task (visible in stats, never fatal)."""
        with self._stats_lock:
            self.read_stats.prefetch_errors += 1
        self.last_prefetch_error = repr(exc)

    def store_cuboids(self, r: int, blocks: Dict[int, np.ndarray],
                      channel: int = 0) -> None:
        """Batch write: compress all blocks, then ONE ``put_many``.

        Keeps the single-cuboid semantics: shape-checked, all-zero cuboids
        are deleted rather than stored (lazy allocation, paper §3.2), writes
        land on the write path when attached.
        """
        shape = self._cuboid_shape(r)
        t0 = time.perf_counter()
        items: List[Tuple[Key, Optional[bytes]]] = []
        blob_bytes = 0
        for m, data in blocks.items():
            if tuple(data.shape) != shape:
                raise ValueError(f"cuboid shape {data.shape} != {shape}")
            key = (r, channel, m)
            if not data.any():
                items.append((key, None))
                continue
            blob = compress(data.astype(self._np_dtype),
                            self.compression_level)
            blob_bytes += len(blob)
            items.append((key, blob))
        if items:
            self._apply_writes(items)
        with self._stats_lock:
            self.write_stats.writes += len(items)
            self.write_stats.write_bytes += blob_bytes
            self.write_stats.time_s += time.perf_counter() - t0

    def ingest_blobs(self, items: Sequence[Tuple[Key, Optional[bytes]]]) -> None:
        """Land pre-compressed blobs on this store (``None`` = lazy-zero
        delete) — the cluster's segment-migration entry point.

        Blobs move between node shards without a decompress/re-compress
        round trip, through the same single write order as normal writes
        (write-behind queue when attached, then the cache), so a moved key
        is readable here the moment this returns (read-your-writes).
        """
        if not items:
            return
        t0 = time.perf_counter()
        self._apply_writes(list(items))
        with self._stats_lock:
            self.write_stats.writes += len(items)
            self.write_stats.write_bytes += sum(
                len(b) for _, b in items if b is not None)
            self.write_stats.time_s += time.perf_counter() - t0

    def migrate(self) -> int:
        """Flush write path into the read path (paper: SSD→DB migration).

        Pending write-behind writes are flushed first (so nothing is in
        flight), and each key moves under the store lock — a write landing
        concurrently either precedes the move (and is migrated) or follows
        it (and stays on the write path, which reads consult first); it can
        never be silently dropped between the get and the delete.
        """
        self.flush()
        if self.write_backend is None:
            return 0
        if self.write_backend.supports_tombstones:
            # log write tier: migration IS compaction — the Morton-ordered
            # merge applies tombstones too (the plain loop below would
            # leave a tombstoned key's stale read-tier copy behind)
            return int(self.compact().keys)
        n = 0
        for key in list(self.write_backend.keys()):
            with self._lock:
                blob = self.write_backend.get(key)
                if blob is None:
                    continue
                self.read_backend.put(key, blob)
                self.write_backend.delete(key)
            n += 1
        return n

    def compact(self, max_segments: Optional[int] = None):
        """Merge flushed log segments into the read tier in Morton order.

        Returns a ``repro.core.compact.CompactionStats`` (all zeros when
        the write tier is not an append log)."""
        from .compact import compact_store  # local: compact imports us
        return compact_store(self, max_segments=max_segments)

    def tier_stats(self) -> Dict[str, object]:
        """Tier gauges for ``GET /stats``: which backend serves each path,
        lifetime compaction totals, and (log tier) segment/index gauges."""
        wb = self.write_backend
        out: Dict[str, object] = {
            "read_tier": type(self.read_backend).__name__,
            "write_tier": type(wb).__name__ if wb is not None else None,
            "compactions": dict(self.compactions),
        }
        log_stats = getattr(wb, "stats", None)
        if callable(log_stats):
            out["log"] = log_stats()
        return out

    def _live_backend_keys(self) -> set:
        """Union of backend keys minus write-tier tombstones (a tombstone
        shadows — and thus un-stores — any read-tier copy below it)."""
        keys = set(self.read_backend.keys())
        if self.write_backend is not None:
            keys |= set(self.write_backend.keys())
            tombs = getattr(self.write_backend, "tombstone_keys", None)
            if callable(tombs):
                keys -= tombs()
        return keys

    def stored_keys(self) -> List[Key]:
        self.flush()  # pending write-behind writes count as stored
        return sorted(self._live_backend_keys())

    def key_count(self) -> int:
        """Stored-key count *without* the flush barrier: pending
        write-behind puts/deletes are folded in from a queue snapshot.
        The cheap occupancy gauge topology polling wants — a monitoring
        loop must not drain the write-behind queue it is observing."""
        keys = self._live_backend_keys()
        if self.write_behind is not None:
            puts, dels = self.write_behind.pending_keys()
            keys = (keys | puts) - dels
        return len(keys)

    def storage_bytes(self) -> int:
        total = 0
        for key in self.stored_keys():
            blob = (self.write_backend.get(key)
                    if self.write_backend and key in self.write_backend
                    else self.read_backend.get(key))
            total += len(blob or b"")
        return total
