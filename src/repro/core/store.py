"""Cuboid storage with separated read/write I/O paths (paper §4.1, C4).

The paper directs *reads* to parallel disk arrays and *small random writes*
to SSD nodes, and migrates write-hot databases back to the disk nodes when
they cool. We reproduce the architecture: a `CuboidStore` is backed by a
*read path* (bulk, sequential-friendly, the "database node") and an optional
*write path* (an absorbing write-back store, the "SSD node"). Both paths are
instrumented so the Fig 13 experiment (SSD vs DB small random writes) is a
measurable property of the system rather than prose.

Storage itself is a dict or directory of gzip-compressed cuboids keyed by
(resolution, channel, morton_index). Lazy allocation: a missing cuboid reads
as zeros and occupies no storage (paper §3.2).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .cuboid import DatasetSpec

Key = Tuple[int, int, int]  # (resolution, channel, morton index)


@dataclasses.dataclass
class PathStats:
    reads: int = 0
    read_bytes: int = 0
    writes: int = 0
    write_bytes: int = 0
    seeks: int = 0          # discontiguous accesses (run boundaries)
    time_s: float = 0.0

    def snapshot(self) -> "PathStats":
        return dataclasses.replace(self)


class Backend:
    """Minimal KV backend for compressed cuboids."""

    def get(self, key: Key) -> Optional[bytes]:
        raise NotImplementedError

    def put(self, key: Key, blob: bytes) -> None:
        raise NotImplementedError

    def delete(self, key: Key) -> None:
        raise NotImplementedError

    def keys(self) -> Iterable[Key]:
        raise NotImplementedError

    def __contains__(self, key: Key) -> bool:
        return self.get(key) is not None

    # -- batch ops (paper C7: a cutout is few sequential I/Os, not many
    # random ones).  Backends override when they can do better than a loop.
    def get_many(self, keys: Sequence[Key]) -> List[Optional[bytes]]:
        """Fetch many blobs in one backend call (order preserved)."""
        return [self.get(k) for k in keys]

    def put_many(self, items: Sequence[Tuple[Key, bytes]]) -> None:
        """Store many blobs in one backend call."""
        for k, blob in items:
            self.put(k, blob)


class MemoryBackend(Backend):
    def __init__(self):
        self._d: Dict[Key, bytes] = {}
        self._lock = threading.Lock()

    def get(self, key):
        return self._d.get(key)

    def put(self, key, blob):
        with self._lock:
            self._d[key] = blob

    def delete(self, key):
        with self._lock:
            self._d.pop(key, None)

    def keys(self):
        return list(self._d.keys())

    def __contains__(self, key):
        return key in self._d

    def get_many(self, keys):
        d = self._d
        return [d.get(k) for k in keys]

    def put_many(self, items):
        with self._lock:
            self._d.update(items)


class DirectoryBackend(Backend):
    """One file per cuboid, laid out r/channel/morton.bin.

    Mirrors the paper's CATMAID re-layout (§3.3): grouping by resolution
    first keeps each directory a single access plane and bounds dirsize.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: Key) -> str:
        r, c, m = key
        return os.path.join(self.root, str(r), str(c), f"{m:016x}.bin")

    def get(self, key):
        p = self._path(key)
        if not os.path.exists(p):
            return None
        with open(p, "rb") as f:
            return f.read()

    def put(self, key, blob):
        p = self._path(key)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        tmp = p + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, p)  # atomic

    def delete(self, key):
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self):
        for r in os.listdir(self.root):
            rd = os.path.join(self.root, r)
            if not os.path.isdir(rd):
                continue
            for c in os.listdir(rd):
                cd = os.path.join(rd, c)
                for fn in os.listdir(cd):
                    if fn.endswith(".bin"):
                        yield (int(r), int(c), int(fn[:-4], 16))

    def __contains__(self, key):
        return os.path.exists(self._path(key))


def compress(arr: np.ndarray, level: int = 1) -> bytes:
    """gzip/zlib cuboid compression (paper §3.2: labels compress well)."""
    return zlib.compress(np.ascontiguousarray(arr).tobytes(), level)


def decompress(blob: bytes, shape, dtype) -> np.ndarray:
    return np.frombuffer(zlib.decompress(blob), dtype=dtype).reshape(shape)


class CuboidStore:
    """Cuboid store for one dataset: lazy, compressed, path-separated.

    ``write_path_backend`` (the "SSD node") absorbs writes when attached;
    reads consult it first (freshest), then the read path. ``migrate()``
    flushes write-path contents into the read path — the paper's
    dump-and-restore migration performed when a project cools down.
    """

    def __init__(self, spec: DatasetSpec,
                 backend: Optional[Backend] = None,
                 write_path_backend: Optional[Backend] = None,
                 compression_level: int = 1):
        self.spec = spec
        self.read_backend = backend or MemoryBackend()
        self.write_backend = write_path_backend
        self.compression_level = compression_level
        self.read_stats = PathStats()
        self.write_stats = PathStats()
        self._np_dtype = np.dtype(spec.dtype)
        self._lock = threading.Lock()

    # -- helpers ----------------------------------------------------------
    def _cuboid_shape(self, r: int) -> Tuple[int, ...]:
        return self.spec.grid(r).cuboid_shape

    def _zeros(self, r: int) -> np.ndarray:
        return np.zeros(self._cuboid_shape(r), dtype=self._np_dtype)

    # -- single-cuboid ops -------------------------------------------------
    def read_cuboid(self, r: int, m: int, channel: int = 0) -> np.ndarray:
        key = (r, channel, m)
        t0 = time.perf_counter()
        blob = None
        if self.write_backend is not None:
            blob = self.write_backend.get(key)
        from_write_path = blob is not None
        if blob is None:
            blob = self.read_backend.get(key)
        stats = self.write_stats if from_write_path else self.read_stats
        if blob is None:
            out = self._zeros(r)  # lazy: absent cuboid reads as zeros
        else:
            out = decompress(blob, self._cuboid_shape(r), self._np_dtype)
            stats.read_bytes += len(blob)
        stats.reads += 1
        stats.time_s += time.perf_counter() - t0
        return out

    def write_cuboid(self, r: int, m: int, data: np.ndarray,
                     channel: int = 0) -> None:
        if tuple(data.shape) != self._cuboid_shape(r):
            raise ValueError(
                f"cuboid shape {data.shape} != {self._cuboid_shape(r)}")
        key = (r, channel, m)
        t0 = time.perf_counter()
        if not data.any():
            # lazy allocation: all-zero cuboids occupy no storage
            (self.write_backend or self.read_backend).delete(key)
            self.read_backend.delete(key)
            self.write_stats.writes += 1
            self.write_stats.time_s += time.perf_counter() - t0
            return
        blob = compress(data.astype(self._np_dtype), self.compression_level)
        target = self.write_backend or self.read_backend
        target.put(key, blob)
        self.write_stats.writes += 1
        self.write_stats.write_bytes += len(blob)
        self.write_stats.time_s += time.perf_counter() - t0

    def has_cuboid(self, r: int, m: int, channel: int = 0) -> bool:
        key = (r, channel, m)
        if self.write_backend is not None and key in self.write_backend:
            return True
        return key in self.read_backend

    # -- run (batch/sequential) ops ----------------------------------------
    def read_run(self, r: int, start: int, stop: int,
                 channel: int = 0) -> List[np.ndarray]:
        """Read a contiguous morton run — ONE sequential pass (paper C7)."""
        blobs = self.fetch_runs(r, [(start, stop)], channel)
        shape = self._cuboid_shape(r)
        return [self._zeros(r) if blobs[m] is None
                else decompress(blobs[m], shape, self._np_dtype)
                for m in range(start, stop)]

    def fetch_runs(self, r: int, runs: Sequence[Tuple[int, int]],
                   channel: int = 0) -> Dict[int, Optional[bytes]]:
        """Batch-fetch compressed blobs for every cuboid in ``runs``.

        One ``get_many`` per run per path (the planned-cutout substrate):
        the write path is consulted first (freshest), misses fall through to
        the read path, absent cuboids come back as ``None`` (lazy zeros).
        Returns {morton_index: blob | None}.
        """
        out: Dict[int, Optional[bytes]] = {}
        for start, stop in runs:
            t0 = time.perf_counter()
            self.read_stats.seeks += 1
            keys = [(r, channel, m) for m in range(start, stop)]
            blobs: List[Optional[bytes]] = [None] * len(keys)
            if self.write_backend is not None:
                blobs = list(self.write_backend.get_many(keys))
                hits = [b for b in blobs if b is not None]
                self.write_stats.reads += len(hits)
                self.write_stats.read_bytes += sum(len(b) for b in hits)
            miss = [i for i, b in enumerate(blobs) if b is None]
            if miss:
                fetched = self.read_backend.get_many([keys[i] for i in miss])
                for i, blob in zip(miss, fetched):
                    blobs[i] = blob
                self.read_stats.reads += len(miss)
                self.read_stats.read_bytes += sum(
                    len(b) for b in fetched if b is not None)
            self.read_stats.time_s += time.perf_counter() - t0
            for m, blob in zip(range(start, stop), blobs):
                out[m] = blob
        return out

    def store_cuboids(self, r: int, blocks: Dict[int, np.ndarray],
                      channel: int = 0) -> None:
        """Batch write: compress all blocks, then ONE ``put_many``.

        Keeps the single-cuboid semantics: shape-checked, all-zero cuboids
        are deleted rather than stored (lazy allocation, paper §3.2), writes
        land on the write path when attached.
        """
        shape = self._cuboid_shape(r)
        t0 = time.perf_counter()
        target = self.write_backend or self.read_backend
        puts: List[Tuple[Key, bytes]] = []
        for m, data in blocks.items():
            if tuple(data.shape) != shape:
                raise ValueError(f"cuboid shape {data.shape} != {shape}")
            key = (r, channel, m)
            self.write_stats.writes += 1
            if not data.any():
                target.delete(key)
                self.read_backend.delete(key)
                continue
            blob = compress(data.astype(self._np_dtype),
                            self.compression_level)
            self.write_stats.write_bytes += len(blob)
            puts.append((key, blob))
        if puts:
            target.put_many(puts)
        self.write_stats.time_s += time.perf_counter() - t0

    def migrate(self) -> int:
        """Flush write path into the read path (paper: SSD→DB migration)."""
        if self.write_backend is None:
            return 0
        n = 0
        for key in list(self.write_backend.keys()):
            blob = self.write_backend.get(key)
            if blob is not None:
                self.read_backend.put(key, blob)
                self.write_backend.delete(key)
                n += 1
        return n

    def stored_keys(self) -> List[Key]:
        keys = set(self.read_backend.keys())
        if self.write_backend is not None:
            keys |= set(self.write_backend.keys())
        return sorted(keys)

    def storage_bytes(self) -> int:
        total = 0
        for key in self.stored_keys():
            blob = (self.write_backend.get(key)
                    if self.write_backend and key in self.write_backend
                    else self.read_backend.get(key))
            total += len(blob or b"")
        return total
