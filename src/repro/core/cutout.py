"""The cutout engine (paper §4.2, C2): arbitrary sub-volume read/write.

A *cutout* specifies a resolution and a range in every dimension; the engine
decomposes the box into Morton runs of cuboids (few long sequential reads),
assembles the dense array in memory, and returns it. Unaligned requests are
rounded up to cuboid boundaries and trimmed (the paper measures exactly this
cost in Fig 10). Writes apply a conflict discipline per voxel (paper §3.2):
``overwrite`` / ``preserve`` / ``exception``.

Both directions are *planned*: :func:`plan_cutout` computes every
(cuboid, destination-slice) pair up front with one vectorized Morton decode,
and the read direction is a *pipeline* (§5: throughput is assembly-bound,
not I/O-bound).  The store's ``fetch_blocks`` drives the whole cold path —
blobs fetched in `DecodePolicy.chunk`-sized ``get_many`` batches so one
chunk's backend I/O overlaps another's decompression, the next curve
segments prefetching into the hot-cuboid cache while the current one
decodes (``read_stats.seeks`` still counts *run boundaries*, the paper's
spatial-discontiguity metric, not these temporal batches) — and each
decoded block is
assembled **directly into the shared output buffer** by the worker that
decoded it, through the plan's precomputed disjoint ``buf_slices`` (no
intermediate per-key dict, no second pass; disjointness makes the
concurrent writes race-free).  Absent (lazy-zero) cuboids skip both
decompression and assembly.  :func:`cutout_loop` preserves the original
per-cuboid loop as the reference implementation and correctness oracle.

Lower-dimensional projections (§3.3 tiles) are cutouts with singleton dims.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import trace
from . import morton
from .cuboid import CuboidGrid
from .store import CuboidStore, decompress

Box = Tuple[Sequence[int], Sequence[int]]  # (lo, hi) half-open


@dataclasses.dataclass
class CutoutStats:
    cuboids_read: int = 0
    runs: int = 0
    bytes_assembled: int = 0
    bytes_discarded: int = 0   # read-and-discarded due to misalignment
    zero_copy: int = 0         # aligned requests returned without a copy


def _aligned_box(grid: CuboidGrid, lo, hi):
    alo = [l - l % c for l, c in zip(lo, grid.cuboid_shape)]
    ahi = [min(-(-h // c) * c, g * c) for h, c, g in
           zip(hi, grid.cuboid_shape, grid.grid_shape)]
    return alo, ahi


@dataclasses.dataclass(frozen=True)
class CutoutPlan:
    """Everything a batch cutout needs, computed before any I/O.

    ``cells[i]`` is assembled into ``buf[buf_slices[i]]`` from the leading
    ``keep_shapes[i]`` corner of its cuboid.  ``runs`` is the I/O schedule
    (contiguous Morton runs, the paper's few-sequential-reads property);
    cells outside the volume (pow2 padding) or outside the box (run
    coarsening) are already excluded.
    """
    r: int
    lo: Tuple[int, ...]
    hi: Tuple[int, ...]
    alo: Tuple[int, ...]              # cuboid-aligned box lo
    buf_shape: Tuple[int, ...]
    runs: morton.Runs
    cells: np.ndarray                 # (n,) int64 morton indices to assemble
    origins: np.ndarray               # (n, rank) voxel origin per cell
    buf_slices: List[Tuple[slice, ...]]
    keep_shapes: List[Tuple[int, ...]]

    @property
    def trim(self) -> Tuple[slice, ...]:
        return tuple(slice(l - a, h - a)
                     for l, h, a in zip(self.lo, self.hi, self.alo))


def plan_cutout(grid: CuboidGrid, r: int, lo: Sequence[int],
                hi: Sequence[int],
                max_runs: Optional[int] = None) -> CutoutPlan:
    """Plan the batch assembly of clamped box [lo, hi) — no I/O, no loops
    over cuboid *contents*: cell origins come from one vectorized decode."""
    runs = grid.box_to_runs(lo, hi, max_runs=max_runs)
    alo, ahi = _aligned_box(grid, lo, hi)
    cs = np.asarray(grid.cuboid_shape)
    cells = morton.runs_to_indices(runs)
    origins = morton.morton_decode(cells, grid.bits) * cs  # (n, rank)
    vol = np.asarray(grid.volume_shape)
    # runs may cover cells outside the box (coarsening) or outside the
    # volume (pow2 padding): mask those out of the assembly.
    keep = ((origins < vol).all(axis=1)
            & (origins + cs > np.asarray(alo)).all(axis=1)
            & (origins < np.asarray(ahi)).all(axis=1))
    cells, origins = cells[keep], origins[keep]
    buf_shape = tuple(h - l for l, h in zip(alo, ahi))
    rel = origins - np.asarray(alo)
    ends = np.minimum(rel + cs, np.asarray(buf_shape))
    buf_slices = [tuple(slice(int(a), int(b)) for a, b in zip(row_lo, row_hi))
                  for row_lo, row_hi in zip(rel, ends)]
    keep_shapes = [tuple(int(x) for x in row) for row in (ends - rel)]
    return CutoutPlan(r=r, lo=tuple(lo), hi=tuple(hi), alo=tuple(alo),
                      buf_shape=buf_shape, runs=runs, cells=cells,
                      origins=origins, buf_slices=buf_slices,
                      keep_shapes=keep_shapes)


def cutout(store: CuboidStore, r: int, lo: Sequence[int], hi: Sequence[int],
           channel: int = 0, stats: Optional[CutoutStats] = None,
           max_runs: Optional[int] = None) -> np.ndarray:
    """Read the dense sub-volume [lo, hi) at resolution ``r`` (planned)."""
    grid = store.spec.grid(r)
    lo, hi = grid.clamp_box(lo, hi)
    dtype = np.dtype(store.spec.dtype)
    if any(l >= h for l, h in zip(lo, hi)):
        return np.zeros([max(0, h - l) for l, h in zip(lo, hi)], dtype=dtype)
    with trace.span("plan", r=r):
        plan = plan_cutout(grid, r, lo, hi, max_runs=max_runs)
    buf = np.zeros(plan.buf_shape, dtype=dtype)
    targets = {int(m): (sl, keep) for m, sl, keep in
               zip(plan.cells, plan.buf_slices, plan.keep_shapes)}

    def assemble(m: int, block: Optional[np.ndarray]) -> None:
        # Called from decode workers / node fan-out threads: buf_slices
        # are pairwise disjoint, so concurrent writes never race.
        if block is None:
            return  # lazy cuboid: buffer is already zeros
        t = targets.get(m)
        if t is None:
            return  # outside box/volume (run coarsening / pow2 padding)
        sl, keep = t
        buf[sl] = block[tuple(slice(0, s) for s in keep)]

    # One span covers fetch + decode + assembly — the whole pipelined
    # read (per-node fetch and decode spans nest inside it).
    with trace.span("assemble", cuboids=len(plan.cells), runs=len(plan.runs)):
        store.fetch_blocks(r, plan.runs, channel, sink=assemble)
    # Cuboid-aligned requests assemble the answer exactly: hand the buffer
    # over as-is instead of copying the whole volume through a no-op trim.
    aligned = (plan.lo == plan.alo
               and plan.buf_shape == tuple(h - l for l, h
                                           in zip(plan.lo, plan.hi)))
    out = buf if aligned else np.ascontiguousarray(buf[plan.trim])
    if stats is not None:
        stats.cuboids_read += len(plan.cells)
        stats.runs += len(plan.runs)
        stats.bytes_assembled += out.nbytes
        stats.bytes_discarded += buf.nbytes - out.nbytes
        stats.zero_copy += int(aligned)
    return out


def cutout_loop(store: CuboidStore, r: int, lo: Sequence[int],
                hi: Sequence[int], channel: int = 0,
                stats: Optional[CutoutStats] = None,
                max_runs: Optional[int] = None) -> np.ndarray:
    """Reference cutout: the original per-cuboid Python loop.

    Kept as the correctness oracle for the planned path and as the baseline
    the benchmark suite measures the planned speedup against.
    """
    grid = store.spec.grid(r)
    lo, hi = grid.clamp_box(lo, hi)
    if any(l >= h for l, h in zip(lo, hi)):
        return np.zeros([max(0, h - l) for l, h in zip(lo, hi)],
                        dtype=np.dtype(store.spec.dtype))
    runs = grid.box_to_runs(lo, hi, max_runs=max_runs)
    alo, ahi = _aligned_box(grid, lo, hi)
    buf = np.zeros([h - l for l, h in zip(alo, ahi)],
                   dtype=np.dtype(store.spec.dtype))
    cs = grid.cuboid_shape
    n_read = 0
    for start, stop in runs:
        blocks = store.read_run(r, start, stop, channel)
        for m, block in zip(range(start, stop), blocks):
            origin = grid.cuboid_origin(m)
            # runs may cover morton cells outside the box (coarsening) or
            # outside the volume (pow2 padding): skip those.
            if any(o >= v for o, v in zip(origin, grid.volume_shape)):
                continue
            if any(o + c <= l or o >= h
                   for o, c, l, h in zip(origin, cs, alo, ahi)):
                continue
            sl = tuple(slice(o - a, o - a + c)
                       for o, a, c in zip(origin, alo, cs))
            view_shape = buf[sl].shape
            buf[sl] = block[tuple(slice(0, s) for s in view_shape)]
            n_read += 1
    trim = tuple(slice(l - a, h - a) for l, h, a in zip(lo, hi, alo))
    out = buf[trim]
    if stats is not None:
        stats.cuboids_read += n_read
        stats.runs += len(runs)
        stats.bytes_assembled += out.nbytes
        stats.bytes_discarded += buf.nbytes - out.nbytes
    return np.ascontiguousarray(out)


WriteDiscipline = str  # 'overwrite' | 'preserve' | 'exception'


def write_cutout(store: CuboidStore, r: int, lo: Sequence[int],
                 data: np.ndarray, channel: int = 0,
                 discipline: WriteDiscipline = "overwrite",
                 on_conflict: Optional[Callable[[int, Tuple[int, ...],
                                                 np.ndarray, np.ndarray],
                                                None]] = None) -> None:
    """Write dense ``data`` at offset ``lo`` (read-modify-write per cuboid).

    Mirrors the paper's annotation upload path (§5/Fig 12): (1) read prior
    cuboids, (2) resolve per-voxel conflicts by ``discipline``, (3) write
    back.  ``on_conflict(morton, origin, old_block, new_block)`` is invoked
    for ``exception`` discipline so the annotation layer can record
    multi-label exceptions (paper §3.2).
    """
    if discipline not in ("overwrite", "preserve", "exception"):
        raise ValueError(f"unknown discipline {discipline!r}")
    grid = store.spec.grid(r)
    hi = [l + s for l, s in zip(lo, data.shape)]
    clo, chi = grid.clamp_box(lo, hi)
    if any(l >= h for l, h in zip(clo, chi)):
        return
    cs = grid.cuboid_shape
    dtype = np.dtype(store.spec.dtype)
    plan = plan_cutout(grid, r, clo, chi)
    # read-modify-write, planned: ONE batch fetch of all prior blobs
    # (compressed, cheap to hold), merge per cuboid, batch write-back in
    # bounded chunks so peak decompressed memory stays O(chunk) rather
    # than O(region) — bulk ingest routes whole volumes through here.
    with trace.span("write.fetch", runs=len(plan.runs)):
        blobs = store.fetch_runs(r, plan.runs, channel)
    flush_every = 64  # ~16 MB of 256K-voxel uint8 cuboids per chunk
    out_blocks: Dict[int, np.ndarray] = {}
    for cell, origin in zip(plan.cells, plan.origins):
        m = int(cell)
        blob = blobs.get(m)
        block = (np.zeros(cs, dtype=dtype) if blob is None
                 else decompress(blob, cs, dtype).copy())
        # overlap of this cuboid with the data box, in both frames
        b_lo = [max(0, l - int(o)) for l, o in zip(clo, origin)]
        b_hi = [min(c, h - int(o)) for c, h, o in zip(cs, chi, origin)]
        d_lo = [int(o) + bl - l for o, bl, l in zip(origin, b_lo, lo)]
        d_hi = [int(o) + bh - l for o, bh, l in zip(origin, b_hi, lo)]
        bsl = tuple(slice(a, b) for a, b in zip(b_lo, b_hi))
        dsl = tuple(slice(a, b) for a, b in zip(d_lo, d_hi))
        new = data[dsl]
        old = block[bsl]
        if discipline == "overwrite":
            merged = np.where(new != 0, new, old)
        elif discipline == "preserve":
            merged = np.where(old != 0, old, new)
        else:  # exception
            merged = np.where(old != 0, old, new)
            if on_conflict is not None:
                conflict = (old != 0) & (new != 0) & (old != new)
                if conflict.any():
                    # report in full-cuboid frame so flat voxel offsets
                    # are stable keys for the exceptions list (§3.2)
                    old_full = np.zeros(cs, dtype=block.dtype)
                    new_full = np.zeros(cs, dtype=block.dtype)
                    old_full[bsl] = old * conflict
                    new_full[bsl] = new * conflict
                    on_conflict(m, tuple(int(o) for o in origin),
                                old_full, new_full)
        block[bsl] = merged.astype(block.dtype)
        out_blocks[m] = block
        if len(out_blocks) >= flush_every:
            with trace.span("write.store", cuboids=len(out_blocks)):
                store.store_cuboids(r, out_blocks, channel)
            out_blocks = {}
    if out_blocks:
        with trace.span("write.store", cuboids=len(out_blocks)):
            store.store_cuboids(r, out_blocks, channel)


def project(store: CuboidStore, r: int, lo: Sequence[int],
            hi: Sequence[int], axis: int, reduce: str = "slice",
            channel: int = 0) -> np.ndarray:
    """Lower-dimensional projection (paper §3.3: dynamic tile building).

    ``slice`` takes the first plane along ``axis`` (a tile request);
    ``max``/``mean`` reduce along it (e.g. MIP renderings). The engine reads
    3-d cuboid runs and discards what the projection does not need — this is
    exactly the read-amplification trade the paper accepts to avoid storing
    redundant tile stacks.
    """
    vol = cutout(store, r, lo, hi, channel)
    if reduce == "slice":
        return np.take(vol, 0, axis=axis)
    if reduce == "max":
        return vol.max(axis=axis)
    if reduce == "mean":
        return vol.mean(axis=axis).astype(vol.dtype)
    raise ValueError(f"unknown reduce {reduce!r}")


def batch_cutout(store: CuboidStore, r: int,
                 boxes: List[Box], channel: int = 0) -> List[np.ndarray]:
    """Batch interface (paper §4.2): amortize fixed costs over requests.

    Over a cluster the boxes *overlap*: each box's plan, node fan-out, and
    decode chunks run as one job on the cluster's request-level pool, so
    box B's I/O pipelines with box A's assembly instead of queuing behind
    it.  Results keep request order.  Stores without a ``run_batch``
    (single `CuboidStore`) execute serially, as before.
    """
    jobs = [functools.partial(cutout, store, r, lo, hi, channel)
            for lo, hi in boxes]
    runner = getattr(store, "run_batch", None)
    if runner is None:
        return [job() for job in jobs]
    return list(runner(jobs))


def ingest(store: CuboidStore, r: int, volume: np.ndarray,
           channel: int = 0, offset: Optional[Sequence[int]] = None) -> None:
    """Bulk-load a dense volume (instrument → store ingest path)."""
    off = list(offset or [0] * volume.ndim)
    write_cutout(store, r, off, volume, channel, discipline="overwrite")


def build_hierarchy(store: CuboidStore, channel: int = 0,
                    labels: bool = False) -> None:
    """Propagate level r -> r+1 for the whole dataset (background job, §3.2).

    Image data average-pools the scaled dims; label data stride-samples so
    identifiers survive (no blending of ids).
    """
    from .cuboid import downsample_block, downsample_labels
    spec = store.spec
    for r in range(spec.n_resolutions - 1):
        src, dst = spec.grid(r), spec.grid(r + 1)
        # iterate destination cuboids; pull the source region for each
        for m in range(dst.n_cells):
            origin = dst.cuboid_origin(m)
            if any(o >= v for o, v in zip(origin, dst.volume_shape)):
                continue
            dhi = [min(o + c, v) for o, c, v in
                   zip(origin, dst.cuboid_shape, dst.volume_shape)]
            # source box: scale up the scaled dims by 2
            slo = [o * 2 if d in spec.scaled_dims else o
                   for d, o in enumerate(origin)]
            shi = [h * 2 if d in spec.scaled_dims else h
                   for d, h in enumerate(dhi)]
            block = cutout(store, r, slo, shi, channel)
            if not block.any():
                continue
            down = (downsample_labels(block, spec.scaled_dims) if labels
                    else downsample_block(block, spec.scaled_dims))
            write_cutout(store, r + 1, list(origin), down, channel)
