"""Per-object sparse spatial index (paper §4.2, C7).

The index maps annotation identifier -> list of Morton locations of the
cuboids containing that object's voxels.  Maintenance is append-mostly and
batched: a write transaction collects the cuboids newly touched per id and
appends them in one operation.  Retrieval sorts the list into curve order so
the object's voxels are read in a single sequential pass (paper Fig 9).
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Sequence, Set, Tuple

import numpy as np

from . import morton
from .cuboid import CuboidGrid


class ObjectIndex:
    def __init__(self):
        self._idx: Dict[int, Set[int]] = {}
        self._lock = threading.Lock()
        self.append_batches = 0  # instrumentation (Fig 12 contention story)

    def append_batch(self, updates: Dict[int, Iterable[int]]) -> None:
        """One write transaction appends all new cuboid locations (§4.2)."""
        with self._lock:
            for ann_id, cubes in updates.items():
                self._idx.setdefault(int(ann_id), set()).update(
                    int(c) for c in cubes)
            self.append_batches += 1

    def remove(self, ann_id: int) -> None:
        with self._lock:
            self._idx.pop(int(ann_id), None)

    def cuboids(self, ann_id: int) -> List[int]:
        """Morton locations for an object, sorted into curve order."""
        return sorted(self._idx.get(int(ann_id), ()))

    def ids(self) -> List[int]:
        return sorted(self._idx.keys())

    def __contains__(self, ann_id: int) -> bool:
        return int(ann_id) in self._idx

    def runs(self, ann_id: int) -> List[Tuple[int, int]]:
        """Collapse the sorted cuboid list into contiguous morton runs."""
        return morton.indices_to_runs(self.cuboids(ann_id))

    def partitioned_runs(self, ann_id: int,
                         segments: Sequence[Tuple[int, int]]
                         ) -> Dict[int, List[Tuple[int, int]]]:
        """Object runs grouped by curve segment (cluster object reads).

        ``segments`` is a curve partition (`morton.partition_curve` order:
        segment i = node i).  Each object run is clipped at segment
        boundaries, so every returned run is wholly owned by one node and
        node-local reads stay sequential — the paper's object retrieval
        (Fig 9) routed across the cluster.
        """
        by_part: Dict[int, List[Tuple[int, int]]] = {}
        for start, stop in self.runs(ann_id):
            for i, (seg_lo, seg_hi) in enumerate(segments):
                a, b = max(start, seg_lo), min(stop, seg_hi)
                if a < b:
                    by_part.setdefault(i, []).append((a, b))
        return by_part

    def bounding_box(self, ann_id: int,
                     grid: CuboidGrid) -> Tuple[List[int], List[int]] | None:
        """Cuboid-resolution bounding box from the index alone (no voxel IO).

        Paper §4.2: a boundingbox query "queries a spatial index but does
        not access voxel data".
        """
        cubes = self.cuboids(ann_id)
        if not cubes:
            return None
        origins = np.array([grid.cuboid_origin(m) for m in cubes])
        lo = origins.min(axis=0)
        hi = origins.max(axis=0) + np.array(grid.cuboid_shape)
        hi = np.minimum(hi, np.array(grid.volume_shape))
        return list(int(x) for x in lo), list(int(x) for x in hi)
