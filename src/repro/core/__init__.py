"""Core paper contribution: Morton-indexed cuboid spatial database.

See DESIGN.md §1 for the mapping from paper mechanisms (C1-C8) to modules.
"""
from . import morton, cuboid, store, wal, compact, cutout, spatial_index, annotations  # noqa: F401
