"""Append-log write tier: sequential segments + an in-memory index.

The paper's I/O split sends "small random writes to solid-state storage"
while reads stream from the disk arrays (§4.1, Fig 13).  This module is
the write half of that split as an LSM-for-cuboids: `LogBackend` turns a
batch of cuboid writes into ONE sequential append (plus at most one
fsync), keyed by an in-memory ``key -> (segment, offset)`` index that is
rebuilt by scanning the segments on open.  Deletes append *tombstones* —
kept in the index so they shadow older read-tier data until compaction
(`repro.core.compact`) merges sealed segments into the compacted
`DirectoryBackend` in Morton order.

Record format (little-endian), one per cuboid::

    MAGIC 'OCWL' | r u32 | c u32 | m u64 | length i64 | crc u32 | payload

``length == -1`` marks a tombstone (no payload).  ``crc`` covers the
header prefix and the payload, so recovery can detect a torn tail —
a partially-written final record is truncated away, never served.

`TierPolicy` is the pluggable-backend seam: it names which `Backend`
serves each path (``REPRO_WRITE_TIER=log|dir|none``) and builds the
pair; `tiered_store` wires a `CuboidStore` on top of it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import re
import struct
import tempfile
import threading
import zlib
from typing import BinaryIO, Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

from ..analysis import knobs
from ..analysis.witness import ordered_rlock
from .cuboid import DatasetSpec
from .store import (
    Backend,
    CuboidStore,
    DirectoryBackend,
    Key,
    crashpoint,
)

MAGIC = b"OCWL"
_FIELDS = struct.Struct("<IIQq")  # r, c, m, payload length (-1 = tombstone)
_CRC = struct.Struct("<I")
HEADER_BYTES = len(MAGIC) + _FIELDS.size + _CRC.size

_SEGMENT_RE = re.compile(r"^(\d{8})\.log$")

TOMBSTONE = -1


class _Loc(NamedTuple):
    """Where a key's newest record lives: payload offset within a segment.

    ``length == TOMBSTONE`` marks a delete; the entry stays in the index
    (shadowing lower tiers) until compaction applies it."""

    seg: int
    offset: int
    length: int


def _encode(key: Key, blob: Optional[bytes]) -> bytes:
    r, c, m = key
    payload = blob if blob is not None else b""
    length = len(payload) if blob is not None else TOMBSTONE
    head = MAGIC + _FIELDS.pack(r, c, m, length)
    crc = zlib.crc32(payload, zlib.crc32(head))
    return head + _CRC.pack(crc) + payload


class LogBackend(Backend):
    """Append-only segmented log with an in-memory key index.

    * ``put_many`` concatenates records into ONE sequential write on the
      active segment (and one fsync when enabled) — the SSD-node write
      path, O(1) syscalls per flush batch instead of per cuboid.
    * ``delete`` appends a tombstone; the index keeps it so lookups see a
      definitive absence (``probe -> (True, None)``) instead of falling
      through to a stale compacted copy.
    * Open scans segments in sequence order to rebuild the index; a torn
      tail (short record, bad magic, or crc mismatch — a crash mid-append)
      is truncated at the last whole record and counted in
      ``torn_truncated``.  Replay is idempotent: later records simply
      re-point the index.
    * The active segment rotates at ``segment_bytes``; sealed segments are
      immutable and are what the compactor merges and removes.

    All index and file access is serialized by one lock — this tier only
    sees flusher batches and the rare read that misses both the cache and
    the pending-write map, so contention is not the bottleneck; crash
    consistency is.
    """

    supports_tombstones = True

    def __init__(self, root: str, segment_bytes: int = 4 << 20,
                 fsync: Optional[bool] = None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        if fsync is None:
            # the write tier defaults to durable: it is the ack boundary
            fsync = knobs.get_flag("REPRO_FSYNC", default=True)
        self.fsync = bool(fsync)
        self.segment_bytes = int(segment_bytes)
        self._lock = ordered_rlock("wal.log", 50)
        self._index: Dict[Key, _Loc] = {}
        self._seg_refs: Dict[int, int] = {}   # index entries per segment
        self._sizes: Dict[int, int] = {}      # bytes per segment
        self._read_fds: Dict[int, int] = {}
        self._append_f: Optional[BinaryIO] = None
        self._active: int = 0
        self.torn_truncated = 0
        self.appends = 0
        self.syncs = 0
        self._recover()

    # -- recovery -----------------------------------------------------------
    def _segment_path(self, seg: int) -> str:
        return os.path.join(self.root, f"{seg:08d}.log")

    def _recover(self) -> None:
        segs = sorted(
            int(m.group(1))
            for m in (_SEGMENT_RE.match(fn) for fn in os.listdir(self.root))
            if m is not None
        )
        for seg in segs:
            self._sizes[seg] = self._scan_segment(seg)
        self._active = segs[-1] if segs else 1
        self._sizes.setdefault(self._active, 0)

    def _scan_segment(self, seg: int) -> int:
        """Replay one segment into the index; truncate a torn tail.

        Returns the post-truncation size.  Records replay in append order,
        so the newest version of a key wins — exactly the write order the
        flusher applied."""
        path = self._segment_path(seg)
        good = 0
        with open(path, "rb") as f:
            while True:
                head = f.read(HEADER_BYTES)
                if not head:
                    break
                if len(head) < HEADER_BYTES or head[:4] != MAGIC:
                    break  # torn/garbage tail
                r, c, m, length = _FIELDS.unpack(head[4:4 + _FIELDS.size])
                (crc,) = _CRC.unpack(head[4 + _FIELDS.size:])
                if length < TOMBSTONE:
                    break
                payload = f.read(length) if length > 0 else b""
                if length > 0 and len(payload) < length:
                    break  # crashed mid-payload
                if zlib.crc32(payload, zlib.crc32(head[:-_CRC.size])) != crc:
                    break  # bit-rot or an unsynced partial overwrite
                self._set_loc(
                    (r, c, m),
                    _Loc(seg, good + HEADER_BYTES, length))
                good += HEADER_BYTES + max(length, 0)
        if good < os.path.getsize(path):
            with open(path, "r+b") as f:
                f.truncate(good)
            self.torn_truncated += 1
        return good

    def _set_loc(self, key: Key, loc: _Loc) -> None:
        old = self._index.get(key)
        if old is not None:
            self._seg_refs[old.seg] -= 1
        self._index[key] = loc
        self._seg_refs[loc.seg] = self._seg_refs.get(loc.seg, 0) + 1

    # -- append path --------------------------------------------------------
    def _active_file(self) -> BinaryIO:
        if self._append_f is None or self._append_f.closed:
            # unbuffered append: bytes reach the page cache immediately, so
            # a pread on the same segment sees them without a flush
            self._append_f = open(
                self._segment_path(self._active), "ab", buffering=0)
        return self._append_f

    def _rotate(self) -> None:
        if self._append_f is not None and not self._append_f.closed:
            self._append_f.close()
        self._append_f = None
        self._active += 1
        self._sizes[self._active] = 0
        open(self._segment_path(self._active), "ab").close()
        if self.fsync:
            self._sync_root()

    def _sync_root(self) -> None:
        fd = os.open(self.root, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _append(self, items: Sequence[Tuple[Key, Optional[bytes]]]) -> None:
        with self._lock:
            f = self._active_file()
            base = self._sizes[self._active]
            buf = bytearray()
            locs: List[Tuple[Key, _Loc]] = []
            for key, blob in items:
                rec = _encode(key, blob)
                offset = base + len(buf) + HEADER_BYTES
                length = len(blob) if blob is not None else TOMBSTONE
                locs.append((key, _Loc(self._active, offset, length)))
                buf += rec
            f.write(bytes(buf))
            crashpoint("wal.append.written")
            if self.fsync:
                os.fsync(f.fileno())
                self.syncs += 1
            crashpoint("wal.append.synced")
            # index only after the bytes are durable: an unsynced append
            # is not acked, and recovery replays whatever did survive
            self._sizes[self._active] = base + len(buf)
            for key, loc in locs:
                self._set_loc(key, loc)
            self.appends += len(items)
            if self._sizes[self._active] >= self.segment_bytes:
                self._rotate()

    def put(self, key, blob):
        self._append([(key, blob)])

    def put_many(self, items):
        if items:
            self._append(list(items))

    def delete(self, key):
        self._append([(key, None)])  # tombstone

    # -- lookup path --------------------------------------------------------
    def _read_fd(self, seg: int) -> int:
        fd = self._read_fds.get(seg)
        if fd is None:
            fd = os.open(self._segment_path(seg), os.O_RDONLY)
            self._read_fds[seg] = fd
        return fd

    def _read_loc(self, loc: _Loc) -> bytes:
        data = os.pread(self._read_fd(loc.seg), loc.length, loc.offset)
        if len(data) != loc.length:
            raise IOError(
                f"short log read: segment {loc.seg} offset {loc.offset} "
                f"wanted {loc.length} got {len(data)}")
        return data

    def get(self, key):
        with self._lock:
            loc = self._index.get(key)
            if loc is None or loc.length == TOMBSTONE:
                return None
            return self._read_loc(loc)

    def get_many(self, keys):
        with self._lock:
            return [
                None if (loc := self._index.get(k)) is None
                or loc.length == TOMBSTONE
                else self._read_loc(loc)
                for k in keys
            ]

    def probe(self, key):
        with self._lock:
            loc = self._index.get(key)
            if loc is None:
                return False, None
            if loc.length == TOMBSTONE:
                return True, None  # definitive absence — shadow lower tiers
            return True, self._read_loc(loc)

    def probe_many(self, keys):
        with self._lock:
            return [
                (False, None) if (loc := self._index.get(k)) is None
                else (True, None) if loc.length == TOMBSTONE
                else (True, self._read_loc(loc))
                for k in keys
            ]

    def __contains__(self, key):
        with self._lock:
            loc = self._index.get(key)
            return loc is not None and loc.length != TOMBSTONE

    def keys(self):
        with self._lock:
            return [k for k, loc in self._index.items()
                    if loc.length != TOMBSTONE]

    def tombstone_keys(self) -> Set[Key]:
        with self._lock:
            return {k for k, loc in self._index.items()
                    if loc.length == TOMBSTONE}

    # -- compaction interface ----------------------------------------------
    def seal_active(self) -> None:
        """Rotate a non-empty active segment so its records become
        compactable (sealed segments are immutable)."""
        with self._lock:
            if self._sizes.get(self._active, 0) > 0:
                if self.fsync and self._append_f is not None \
                        and not self._append_f.closed:
                    os.fsync(self._append_f.fileno())
                    self.syncs += 1
                self._rotate()

    def sealed_segments(self) -> List[int]:
        """Ascending — compaction MUST process (and remove) in this order
        so the surviving log is always a suffix: replay after a crash can
        then never resurrect an older version over a compacted newer one."""
        with self._lock:
            return sorted(s for s in self._sizes if s != self._active)

    def segment_entries(self, seg: int) -> List[Tuple[Key, _Loc]]:
        """Index entries currently pointing into ``seg``, Morton-sorted
        (key order (r, c, m) == curve order within each channel plane)."""
        with self._lock:
            return sorted(
                (k, loc) for k, loc in self._index.items() if loc.seg == seg)

    def entry_value(self, key: Key, loc: _Loc
                    ) -> Tuple[bool, Optional[bytes]]:
        """CAS read for the compactor: ``(still_current, blob)``.

        ``still_current`` is False when the index has moved past ``loc``
        (a newer write superseded it mid-compaction) — the caller must
        skip the entry, a later segment owns the key now."""
        with self._lock:
            if self._index.get(key) != loc:
                return False, None
            if loc.length == TOMBSTONE:
                return True, None
            return True, self._read_loc(loc)

    def drop_entries(self, pairs: Sequence[Tuple[Key, _Loc]]) -> int:
        """Remove index entries that still match (CAS) — after their
        values landed on the read tier.  Returns how many dropped."""
        n = 0
        with self._lock:
            for key, loc in pairs:
                if self._index.get(key) == loc:
                    del self._index[key]
                    self._seg_refs[loc.seg] -= 1
                    n += 1
        return n

    def remove_segment(self, seg: int) -> bool:
        """Unlink a fully-compacted sealed segment (no index refs left)."""
        with self._lock:
            if seg == self._active or self._seg_refs.get(seg, 0) > 0:
                return False
            fd = self._read_fds.pop(seg, None)
            if fd is not None:
                os.close(fd)
            with contextlib.suppress(FileNotFoundError):
                os.remove(self._segment_path(seg))
            self._sizes.pop(seg, None)
            self._seg_refs.pop(seg, None)
            if self.fsync:
                self._sync_root()
            return True

    # -- gauges / lifecycle -------------------------------------------------
    def stats(self) -> Dict[str, int]:
        with self._lock:
            live = sum(1 for loc in self._index.values()
                       if loc.length != TOMBSTONE)
            return {
                "segments": len(self._sizes),
                "sealed": len(self._sizes) - 1,
                "active_bytes": self._sizes.get(self._active, 0),
                "log_bytes": sum(self._sizes.values()),
                "live_keys": live,
                "tombstones": len(self._index) - live,
                "appends": self.appends,
                "syncs": self.syncs,
                "torn_truncated": self.torn_truncated,
            }

    def close(self) -> None:
        """Release file handles.  Safe to keep using the backend — the
        append handle and read fds reopen lazily."""
        with self._lock:
            if self._append_f is not None and not self._append_f.closed:
                if self.fsync:
                    os.fsync(self._append_f.fileno())
                self._append_f.close()
            self._append_f = None
            for fd in self._read_fds.values():
                os.close(fd)
            self._read_fds.clear()


@dataclasses.dataclass(frozen=True)
class TierPolicy:
    """Which `Backend` serves each I/O path — the pluggable-backend seam.

    ``write_tier``: ``"log"`` (append-log segments, the paper's SSD write
    node), ``"dir"`` (a second `DirectoryBackend`), or ``"none"`` (single
    shared backend, no separation).  ``fsync`` of ``None`` defers to
    ``REPRO_FSYNC`` (default: ON for the write tier — it is the ack
    boundary — and always off for the compacted read tier, whose writes
    re-derive from the log).  ``from_env`` reads ``REPRO_WRITE_TIER``.
    """

    write_tier: str = "dir"
    fsync: Optional[bool] = None
    segment_bytes: int = 4 << 20

    def __post_init__(self):
        if self.write_tier not in ("log", "dir", "none"):
            raise ValueError(
                f"write_tier must be log|dir|none, got {self.write_tier!r}")

    @classmethod
    def from_env(cls) -> "TierPolicy":
        return cls(write_tier=knobs.get_str("REPRO_WRITE_TIER", "dir"))

    def build(self, root: str) -> Tuple[Backend, Optional[Backend]]:
        """Materialize ``(read_backend, write_backend | None)`` under
        ``root`` (``read/`` and ``wal/`` or ``write/`` subtrees)."""
        read = DirectoryBackend(os.path.join(root, "read"), fsync=False)
        fsync = (self.fsync if self.fsync is not None
                 else knobs.get_flag("REPRO_FSYNC", default=True))
        if self.write_tier == "log":
            return read, LogBackend(
                os.path.join(root, "wal"),
                segment_bytes=self.segment_bytes, fsync=fsync)
        if self.write_tier == "dir":
            return read, DirectoryBackend(
                os.path.join(root, "write"), fsync=fsync)
        return read, None


def tiered_store(spec: DatasetSpec, root: Optional[str] = None,
                 policy: Optional[TierPolicy] = None, **kwargs) -> CuboidStore:
    """Build a `CuboidStore` with `TierPolicy`-wired backends.

    ``root=None`` creates a temp directory the store owns: ``close()``
    removes it (the shape the cluster's default node factory uses under
    ``REPRO_WRITE_TIER=log``).  Extra kwargs pass through to `CuboidStore`.
    """
    policy = policy or TierPolicy.from_env()
    tmpdir = None
    if root is None:
        tmpdir = tempfile.TemporaryDirectory(prefix="ocp-tier-")
        root = tmpdir.name
    read, write = policy.build(root)
    store = CuboidStore(
        spec, backend=read, write_path_backend=write, **kwargs)
    store.tier_policy = policy
    store._tier_tmpdir = tmpdir
    return store
