#!/usr/bin/env python
"""Project lint driver: AST lint suite (L001-L005) + README knob table.

Usage:

    python tools/check.py [paths ...]      # default: src/
    python tools/check.py --report out.json
    python tools/check.py --fix-readme     # rewrite README's knob table

Exits non-zero on any finding (CI fails the build on that).  The
``--report`` JSON is uploaded as a CI artifact so a red build carries
the full finding list without re-running locally.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import knobs, lints  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint (default: src/)")
    ap.add_argument("--report", metavar="FILE", help="write findings as JSON")
    ap.add_argument("--readme", default=str(REPO / "README.md"),
                    help="README to check the knob table in ('' to skip)")
    ap.add_argument("--fix-readme", action="store_true",
                    help="rewrite the README knob table from the registry")
    args = ap.parse_args(argv)

    paths = args.paths or [str(REPO / "src")]
    findings = lints.run_paths(paths)

    table_findings = []
    if args.readme:
        readme = pathlib.Path(args.readme)
        text = readme.read_text()
        try:
            stale = knobs.readme_stale(text)
        except ValueError as e:
            stale, note = True, str(e)
        else:
            note = "knob table out of date; run `python tools/check.py --fix-readme`"
        if stale and args.fix_readme:
            readme.write_text(knobs.splice_readme(text))
            print(f"rewrote knob table in {readme}")
        elif stale:
            table_findings.append(
                {"rule": "K001", "path": str(readme), "line": 0, "message": note})

    rows = [f.__dict__ for f in findings] + table_findings
    if args.report:
        pathlib.Path(args.report).write_text(json.dumps(rows, indent=2) + "\n")

    for f in findings:
        print(f.format())
    for t in table_findings:
        print(f"{t['path']}:0: K001 {t['message']}")

    if rows:
        print(f"\n{len(rows)} finding(s)")
        return 1
    print(f"check clean: {len(paths)} path(s), "
          f"{len(knobs.REGISTRY)} registered knobs")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
