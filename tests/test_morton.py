"""Property tests for the Morton curve (paper §3 invariants)."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import morton


def bits_strategy(max_rank=4, max_bits=6):
    return st.lists(st.integers(0, max_bits), min_size=1,
                    max_size=max_rank).map(tuple).filter(
                        lambda b: sum(b) > 0 and sum(b) <= 18)


@given(bits=bits_strategy(), data=st.data())
@settings(max_examples=200, deadline=None)
def test_roundtrip(bits, data):
    coords = [data.draw(st.integers(0, (1 << b) - 1)) for b in bits]
    idx = morton.morton_encode(np.array(coords), bits)
    back = morton.morton_decode(idx, bits)
    assert list(back) == coords


@given(bits=bits_strategy())
@settings(max_examples=50, deadline=None)
def test_bijective_on_grid(bits):
    n = 1 << morton.total_bits(bits)
    if n > 1 << 14:
        n = 1 << 14
    idx = np.arange(n)
    coords = morton.morton_decode(idx, bits)
    again = morton.morton_encode(coords, bits)
    np.testing.assert_array_equal(idx, again)


@given(bits=bits_strategy(max_rank=3, max_bits=4), data=st.data())
@settings(max_examples=100, deadline=None)
def test_monotone_nondecreasing_per_dim(bits, data):
    """Paper: 'cube addresses are strictly non-decreasing in each dimension'."""
    d = len(bits)
    coords = np.array([data.draw(st.integers(0, (1 << b) - 1)) for b in bits])
    dim = data.draw(st.integers(0, d - 1))
    if bits[dim] == 0 or coords[dim] == (1 << bits[dim]) - 1:
        return
    bumped = coords.copy()
    bumped[dim] += 1
    assert morton.morton_encode(bumped, bits) > morton.morton_encode(
        coords, bits)


@given(bits=bits_strategy(max_rank=3, max_bits=4), data=st.data())
@settings(max_examples=150, deadline=None)
def test_range_decompose_exact_cover(bits, data):
    lo, hi = [], []
    for b in bits:
        a = data.draw(st.integers(0, (1 << b) - 1))
        z = data.draw(st.integers(a + 1, 1 << b))
        lo.append(a)
        hi.append(z)
    runs = morton.range_decompose(lo, hi, bits)
    # runs are disjoint, sorted, merged
    for (a1, b1), (a2, b2) in zip(runs, runs[1:]):
        assert b1 < a2
    got = set(morton.runs_to_indices(runs).tolist())
    expect = set()
    grids = np.meshgrid(*[np.arange(l, h) for l, h in zip(lo, hi)],
                        indexing="ij")
    coords = np.stack([g.ravel() for g in grids], axis=-1)
    expect = set(morton.morton_encode(coords, bits).tolist())
    assert got == expect


@given(bits=bits_strategy(max_rank=3, max_bits=4), data=st.data())
@settings(max_examples=80, deadline=None)
def test_aligned_pow2_box_is_one_run(bits, data):
    """Paper: any power-of-two aligned subregion is wholly contiguous."""
    lo, hi = [], []
    # pick a morton-aligned cell: choose a level split consistent with the
    # interleave by choosing per-dim sizes via a common prefix cut
    k = data.draw(st.integers(0, morton.total_bits(bits)))
    placement = morton.bit_placement(bits)
    nbits = len(placement)
    rem = [0] * len(bits)
    for p in range(k, nbits):
        dim, _ = placement[nbits - 1 - p]
        rem[dim] += 1
    size = [1 << r for r in rem]
    for d, b in enumerate(bits):
        n_cells = (1 << b) // size[d]
        c = data.draw(st.integers(0, n_cells - 1))
        lo.append(c * size[d])
        hi.append((c + 1) * size[d])
    runs = morton.range_decompose(lo, hi, bits)
    assert len(runs) == 1
    assert runs[0][1] - runs[0][0] == int(np.prod(size))


def test_coarsen_runs_superset():
    runs = [(0, 2), (4, 6), (10, 12), (20, 22)]
    co = morton.coarsen_runs(list(runs), 2)
    assert len(co) == 2
    orig = set(morton.runs_to_indices(runs).tolist())
    new = set(morton.runs_to_indices(co).tolist())
    assert orig <= new


@given(n_cells=st.integers(1, 10_000), n_parts=st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_partition_and_owner(n_cells, n_parts):
    parts = morton.partition_curve(n_cells, n_parts)
    assert parts[0][0] == 0 and parts[-1][1] == n_cells
    sizes = [b - a for a, b in parts]
    assert max(sizes) - min(sizes) <= 1
    idx = np.arange(n_cells)
    owner = morton.owner_of(idx, n_cells, n_parts)
    for p, (a, b) in enumerate(parts):
        assert (owner[a:b] == p).all()


def test_decode_traced_matches_numpy():
    import jax
    bits = (3, 2, 4)
    idx = np.arange(1 << 9)
    ref = morton.morton_decode(idx, bits)
    traced = jax.jit(lambda i: morton.morton_decode_traced(i, bits))(idx)
    for d in range(3):
        np.testing.assert_array_equal(np.asarray(traced[d]), ref[..., d])
