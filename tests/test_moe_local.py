"""shard_map local MoE dispatch == global gspmd dispatch (subprocess with
4 host devices; the main test process must keep its single real device)."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
import numpy as np
from repro.models.config import ModelConfig
from repro.models.moe import moe
from repro.models.params import init_params
from repro.models.moe import moe_specs
from repro.train.sharding import make_plan, use_plan
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
# capacity_factor = E/k: capacity == T, no token is ever dropped, so the
# local and global dispatch must agree numerically (addition order aside)
cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=16, vocab=64,
                  n_experts=4, top_k=2, capacity_factor=2.0,
                  dtype="float32")
specs = moe_specs(cfg)
params = init_params(specs, jax.random.key(0))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(4, 8, 32)), jnp.float32)

plan = make_plan(mesh)
with mesh, use_plan(plan):
    xs = jax.device_put(x, NamedSharding(mesh, P(("data",), None, None)))
    outg, auxg = jax.jit(lambda p, x: moe(p, cfg, x))(params, xs)
    cfg_l = cfg.scaled(moe_dispatch="local")
    outl, auxl = jax.jit(lambda p, x: moe(p, cfg_l, x))(params, xs)

np.testing.assert_allclose(np.asarray(outg), np.asarray(outl),
                           atol=1e-5, rtol=1e-5)
# aux estimators differ (global mean vs mean-of-local) but both are O(1)
assert np.isfinite(float(auxg)) and np.isfinite(float(auxl))
print("OK")
"""


def test_local_dispatch_matches_gspmd():
    # JAX_PLATFORMS=cpu: the script wants 4 *host* devices; without it jax
    # may probe for an accelerator (e.g. TPU metadata) and hang.
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
