"""Cluster-vs-single-store equivalence, routing, and the service verbs."""
import zlib

import numpy as np
import pytest

from repro.cluster import ClusterStore, Router, VolumeService, dispatch
from repro.core.annotations import AnnotationProject
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import (CutoutStats, cutout, cutout_loop, ingest,
                               plan_cutout, write_cutout)
from repro.core.store import CuboidStore

SHAPE = (64, 64, 32)
CUBOID = (16, 16, 8)


def spec(shape=SHAPE, dtype="uint8", **kw):
    return DatasetSpec(name="c", volume_shape=shape, dtype=dtype,
                       base_cuboid=CUBOID, **kw)


def volume(shape=SHAPE, seed=0):
    return np.random.default_rng(seed).integers(
        1, 255, size=shape, dtype=np.uint8)


BOXES = [
    ((0, 0, 0), SHAPE),                    # full volume
    ((0, 0, 0), (32, 32, 16)),             # pow2-aligned (single run)
    ((3, 5, 1), (61, 59, 31)),             # unaligned interior
    ((17, 1, 9), (18, 2, 10)),             # single voxel, unaligned
    ((48, 48, 24), (64, 64, 32)),          # corner-touching
]


@pytest.mark.parametrize("n_nodes", [1, 2, 4])
def test_cluster_cutouts_bit_identical(n_nodes):
    vol = volume()
    single = CuboidStore(spec())
    ingest(single, 0, vol)
    cluster = ClusterStore(spec(), n_nodes=n_nodes)
    ingest(cluster, 0, vol)
    for lo, hi in BOXES:
        want = cutout(single, 0, lo, hi)
        got = cutout(cluster, 0, lo, hi)
        np.testing.assert_array_equal(got, want)
        sl = tuple(slice(l, h) for l, h in zip(lo, hi))
        np.testing.assert_array_equal(got, vol[sl])


@pytest.mark.parametrize("n_nodes", [2, 4])
def test_cluster_write_then_migrate_path(n_nodes):
    """Writes land on each node's write path; cutouts are identical before
    and after SSD->DB migration (the paper's dump-and-restore)."""
    vol = volume(seed=3)
    cluster = ClusterStore(spec(), n_nodes=n_nodes)
    write_cutout(cluster, 0, (0, 0, 0), vol)
    assert cluster.write_stats.writes > 0
    before = cutout(cluster, 0, (2, 3, 4), (62, 60, 30))
    migrated = cluster.migrate()
    assert migrated == len(cluster.stored_keys())
    after = cutout(cluster, 0, (2, 3, 4), (62, 60, 30))
    np.testing.assert_array_equal(before, after)
    # write paths fully drained
    for node in cluster.nodes:
        assert len(list(node.write_backend.keys())) == 0


def test_cluster_partition_is_spatially_balanced():
    cluster = ClusterStore(spec(), n_nodes=4)
    ingest(cluster, 0, volume())
    per_node = cluster.keys_per_node()
    assert sum(per_node) == 64  # 4x4x4 cuboid grid
    assert max(per_node) - min(per_node) <= 1  # contiguous curve segments


def test_cluster_unaligned_write_roundtrip():
    vol = volume()
    single = CuboidStore(spec())
    cluster = ClusterStore(spec(), n_nodes=4)
    for store in (single, cluster):
        ingest(store, 0, vol)
        patch = np.full((7, 9, 5), 200, np.uint8)
        write_cutout(store, 0, (13, 22, 9), patch)
    np.testing.assert_array_equal(cutout(cluster, 0, (0, 0, 0), SHAPE),
                                  cutout(single, 0, (0, 0, 0), SHAPE))


def test_planned_cutout_matches_seed_loop():
    """The planned batch path is bit-identical to the per-cuboid loop."""
    vol = volume(seed=7)
    store = CuboidStore(spec())
    ingest(store, 0, vol)
    for lo, hi in BOXES:
        s_plan, s_loop = CutoutStats(), CutoutStats()
        got = cutout(store, 0, lo, hi, stats=s_plan)
        want = cutout_loop(store, 0, lo, hi, stats=s_loop)
        np.testing.assert_array_equal(got, want)
        assert s_plan.cuboids_read == s_loop.cuboids_read
        assert s_plan.runs == s_loop.runs
        assert s_plan.bytes_discarded == s_loop.bytes_discarded


def test_plan_covers_exact_cells():
    grid = spec().grid(0)
    plan = plan_cutout(grid, 0, [0, 0, 0], [32, 32, 16])
    assert len(plan.runs) == 1          # pow2-aligned: one sequential run
    assert len(plan.cells) == 8         # 2x2x2 cuboids
    # every cell's buffer slice stays inside the buffer
    for sl, keep in zip(plan.buf_slices, plan.keep_shapes):
        for s, k, b in zip(sl, keep, plan.buf_shape):
            assert 0 <= s.start < s.stop <= b
            assert s.stop - s.start == k


def test_router_split_runs_cover_and_stay_sorted():
    router = Router(spec(), 3)
    grid = spec().grid(0)
    runs = grid.box_to_runs([0, 0, 0], SHAPE)
    by_node = router.split_runs(0, runs)
    cells = []
    for node, node_runs in by_node.items():
        seg_lo, seg_hi = router.segments(0)[node]
        for a, b in node_runs:
            assert seg_lo <= a < b <= seg_hi  # pieces never cross nodes
            cells.extend(range(a, b))
    assert sorted(cells) == sorted(
        m for a, b in runs for m in range(a, b))


def test_cluster_store_read_write_cuboid_routing():
    cluster = ClusterStore(spec(), n_nodes=4, max_workers=1)
    grid = spec().grid(0)
    block = np.full(grid.cuboid_shape, 9, np.uint8)
    for m in (0, 17, 63):
        cluster.write_cuboid(0, m, block)
        owner = cluster.router.owner(0, m)
        assert cluster.has_cuboid(0, m)
        assert cluster.nodes[owner].has_cuboid(0, m)
        np.testing.assert_array_equal(cluster.read_cuboid(0, m), block)


def test_annotation_project_over_cluster():
    """Object queries (index-routed reads) agree across shard counts."""
    image = spec(dtype="uint8")
    results = {}
    for n_nodes in (1, 2, 4):
        proj = AnnotationProject(
            f"c{n_nodes}", image,
            store_factory=lambda s: ClusterStore(s, n_nodes=n_nodes))
        a = proj.meta.create(ann_type="synapse")
        labels = np.zeros((20, 20, 10), np.uint32)
        labels[3:9, 4:12, 2:7] = a.ann_id
        proj.write(0, (10, 30, 11), labels)
        results[n_nodes] = (proj.bounding_box(a.ann_id, 0),
                            proj.voxel_list(a.ann_id, 0),
                            proj.object_cutout(a.ann_id, 0))
    bbox1, vox1, (lo1, cut1) = results[1]
    for n in (2, 4):
        bbox, vox, (lo, cut) = results[n]
        assert bbox == bbox1
        np.testing.assert_array_equal(vox, vox1)
        assert lo == lo1
        np.testing.assert_array_equal(cut, cut1)


# ---------------------------------------------------------- service verbs --


@pytest.fixture
def service():
    svc = VolumeService()
    store = ClusterStore(spec(), n_nodes=2)
    ingest(store, 0, volume())
    svc.add_dataset("kasthuri11", store)
    proj = AnnotationProject(
        "anno", spec(), store_factory=lambda s: ClusterStore(s, n_nodes=2))
    a = proj.meta.create(ann_type="synapse", confidence=0.99)
    labels = np.zeros((8, 8, 4), np.uint32)
    labels[1:7, 2:8, 1:4] = a.ann_id
    proj.write(0, (16, 16, 8), labels)
    svc.add_project("anno", proj)
    svc.ann_id = a.ann_id
    return svc


def test_get_cutout_verb(service):
    req = {"verb": "GET /cutout", "dataset": "kasthuri11",
           "lo": (5, 6, 7), "hi": (25, 20, 15)}
    resp = dispatch(service, req)
    assert resp["status"] == 200
    assert resp["shape"] == (20, 14, 8)
    want = cutout(service.datasets["kasthuri11"], 0, (5, 6, 7), (25, 20, 15))
    np.testing.assert_array_equal(resp["data"], want)
    assert resp["cuboids_read"] > 0


def test_put_then_get_cutout_verbs(service):
    data = np.full((6, 6, 6), 123, np.uint8)
    put = dispatch(service, {"verb": "PUT /cutout", "dataset": "kasthuri11",
                             "lo": (40, 40, 20), "data": data})
    assert put["status"] == 200
    got = dispatch(service, {"verb": "GET /cutout", "dataset": "kasthuri11",
                             "lo": (40, 40, 20), "hi": (46, 46, 26)})
    np.testing.assert_array_equal(got["data"], data)


def test_cutout_verb_zlib_encoding(service):
    req = {"verb": "GET /cutout", "dataset": "kasthuri11",
           "lo": (0, 0, 0), "hi": (16, 16, 8), "encode": "zlib"}
    resp = dispatch(service, req)
    assert resp["status"] == 200 and resp["encode"] == "zlib"
    vol = np.frombuffer(zlib.decompress(resp["data"]),
                        np.dtype(resp["dtype"])).reshape(resp["shape"])
    want = cutout(service.datasets["kasthuri11"], 0, (0, 0, 0), (16, 16, 8))
    np.testing.assert_array_equal(vol, want)


def test_put_cutout_verb_zlib_payload(service):
    """Regression: zlib-encoded PUT payloads decode via np.frombuffer to
    read-only arrays; the write path must receive a writable block (any
    in-place normalize/pad raised 'assignment destination is read-only')."""
    from repro.cluster.handlers import _decode_volume

    data = np.random.default_rng(13).integers(1, 255, (8, 8, 4), np.uint8)
    req = {"verb": "PUT /cutout", "dataset": "kasthuri11",
           "lo": (32, 32, 16), "encode": "zlib",
           "data": zlib.compress(data.tobytes(), 1),
           "dtype": "uint8", "shape": (8, 8, 4)}
    decoded = _decode_volume(req)
    assert decoded.flags.writeable  # the historical failure mode
    decoded[0, 0, 0] = decoded[0, 0, 0]  # in-place write must not raise
    put = dispatch(service, req)
    assert put["status"] == 200 and put["written_shape"] == (8, 8, 4)
    got = dispatch(service, {"verb": "GET /cutout", "dataset": "kasthuri11",
                             "lo": (32, 32, 16), "hi": (40, 40, 20)})
    np.testing.assert_array_equal(got["data"], data)
    # corrupt zlib payload is a 400, never an exception
    bad = dispatch(service, {**req, "data": b"not zlib"})
    assert bad["status"] == 400


def test_annotation_verbs(service):
    bbox = dispatch(service, {"verb": "GET /objects/boundingbox",
                              "project": "anno", "id": service.ann_id})
    assert bbox["status"] == 200
    assert bbox["lo"] == [16, 16, 8]  # cuboid-resolution bbox
    obj = dispatch(service, {"verb": "GET /objects/cutout",
                             "project": "anno", "id": service.ann_id})
    assert obj["status"] == 200
    ids = np.unique(obj["data"])
    assert set(int(i) for i in ids) <= {0, service.ann_id}
    assert (obj["data"] == service.ann_id).sum() == 6 * 6 * 3


def test_error_statuses(service):
    assert dispatch(service, {"verb": "GET /cutout",
                              "dataset": "nope"})["status"] == 404
    assert dispatch(service, {"verb": "GET /objects/boundingbox",
                              "project": "anno", "id": 999})["status"] == 404
    assert dispatch(service, {"verb": "DELETE /everything"})["status"] == 405
    bad = dispatch(service, {"verb": "GET /cutout", "dataset": "kasthuri11",
                             "lo": (0, 0), "hi": (4, 4, 4)})
    assert bad["status"] == 400
