"""Synapse-detection pipeline tests (paper §2 application)."""
import numpy as np
import jax.numpy as jnp

from repro.core.annotations import AnnotationProject
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import ingest
from repro.core.store import CuboidStore, MemoryBackend
from repro.vision import (connected_components, detect_synapses,
                          gaussian_blur, run_parallel_detection)


def test_gaussian_blur_preserves_mean():
    rng = np.random.default_rng(0)
    vol = rng.random((16, 16, 8), dtype=np.float32)
    out = np.asarray(gaussian_blur(jnp.asarray(vol), (1.0, 1.0, 0.5)))
    assert out.shape == vol.shape
    assert abs(out.mean() - vol.mean()) < 0.02


def test_connected_components_two_blobs():
    mask = np.zeros((12, 12, 4), dtype=bool)
    mask[1:4, 1:4, 1:3] = True
    mask[8:11, 8:11, 1:3] = True
    lab = np.asarray(connected_components(jnp.asarray(mask)))
    ids = set(np.unique(lab)) - {0}
    assert len(ids) == 2
    a = lab[2, 2, 1]
    b = lab[9, 9, 1]
    assert a != b
    assert (lab[1:4, 1:4, 1:3] == a).all()
    assert (lab[8:11, 8:11, 1:3] == b).all()


def test_connected_components_diagonal_not_connected():
    mask = np.zeros((6, 6, 2), dtype=bool)
    mask[0, 0, 0] = True
    mask[1, 1, 0] = True  # diagonal neighbor: 6-connectivity keeps separate
    lab = np.asarray(connected_components(jnp.asarray(mask)))
    assert lab[0, 0, 0] != lab[1, 1, 0]


def synthetic_volume(shape=(48, 48, 16), n_blobs=5, seed=3):
    rng = np.random.default_rng(seed)
    vol = rng.normal(100, 3, size=shape).astype(np.float32)
    centers = []
    for _ in range(n_blobs):
        c = [rng.integers(6, s - 6) for s in shape]
        centers.append(c)
        xx, yy, zz = np.ogrid[:shape[0], :shape[1], :shape[2]]
        d2 = ((xx - c[0]) ** 2 + (yy - c[1]) ** 2 + ((zz - c[2]) * 2) ** 2)
        vol += 80.0 * np.exp(-d2 / 8.0)
    return vol, centers


def test_detect_synapses_finds_planted_blobs():
    vol, centers = synthetic_volume()
    dets, labels = detect_synapses(vol, threshold=2.0, min_voxels=4)
    assert len(dets) >= len(centers) - 1  # allow one merge/miss
    # every detection is near a planted center
    for d in dets:
        dist = min(np.linalg.norm(np.array(d.centroid) - np.array(c))
                   for c in centers)
        assert dist < 6.0
    assert labels.max() == len(dets)


def test_parallel_detection_end_to_end():
    vol, centers = synthetic_volume(shape=(64, 64, 16), n_blobs=6)
    spec = DatasetSpec(name="em", volume_shape=vol.shape, dtype="float32",
                       base_cuboid=(16, 16, 8))
    store = CuboidStore(spec)
    ingest(store, 0, vol)
    proj = AnnotationProject("syn", spec,
                             write_path_backend=MemoryBackend())
    n = run_parallel_detection(store, proj, r=0, tile=(32, 32, 16),
                               n_workers=3, threshold=2.0, min_voxels=4)
    assert n >= 4
    # written through the write path (SSD node), queryable by predicate
    ids = proj.meta.query(("ann_type", "eq", "synapse"))
    assert len(ids) == n
    hi_conf = proj.meta.query(("ann_type", "eq", "synapse"),
                              ("confidence", "geq", 0.5))
    assert set(hi_conf) <= set(ids)
    # spatial index lets us pull each object back
    some = ids[0]
    vox = proj.voxel_list(some, 0)
    assert len(vox) >= 4
