"""Observability tier: trace spans, latency histograms, /metrics + /trace.

Covers the contracts the ISSUE pins down:

* counter invariants under concurrency — ``reads == cache_hits +
  cache_misses`` after a storm of concurrent replicated reads racing a
  live ``rebalance()``, and the ``inflight`` gauge back at 0 after,
* histogram merge is associative and commutative with conserved counts
  (what makes per-node histograms aggregatable by a scraper),
* a traced request's span tree, fetched over real HTTP via
  ``GET /trace/<id>``, covers queue-wait, per-node fetch, decode, and
  assembly,
* ``GET /metrics`` serves Prometheus text whose request histograms
  merge across 1/2/4-shard runs,
* the flat ``dispatch()`` shim warns ``DeprecationWarning`` and returns
  envelopes identical to ``url_dispatch``,
* the structured access log / slow-request dump (silent by default,
  ``REPRO_ACCESS_LOG=1`` / ``REPRO_SLOW_MS`` enable).
"""
import json
import logging
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.cluster import ClusterStore, VolumeService
from repro.cluster.api import url_dispatch
from repro.cluster.handlers import dispatch
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest
from repro.core.store import CuboidStore
from repro.ft import ClusterWatch
from repro.obs import log as obs_log
from repro.obs import trace
from repro.obs.hist import Histogram
from repro.obs.registry import REGISTRY, Registry, metric
from repro.serve.http_front import FrontDoor

SHAPE = (32, 32, 16)
CUBOID = (8, 8, 4)


def spec(name="obs", **kw):
    return DatasetSpec(name=name, volume_shape=SHAPE, dtype="uint8",
                       base_cuboid=CUBOID, **kw)


def volume(seed=0):
    return np.random.default_rng(seed).integers(1, 255, size=SHAPE,
                                                dtype=np.uint8)


def http(method, url, body=None, headers=None):
    req = urllib.request.Request(url, data=body, method=method,
                                 headers=headers or {})
    try:
        with urllib.request.urlopen(req, timeout=30) as resp:
            return resp.status, dict(resp.headers), resp.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


@pytest.fixture
def front():
    base = volume(seed=1)
    store = ClusterStore(spec(), n_nodes=3, replication=2,
                         cache_bytes=8 << 20)
    ingest(store, 0, base)
    service = VolumeService()
    service.add_dataset("obs", store)
    with FrontDoor(service) as door:
        yield door, base, store
    store.close()


# ------------------------------------------------------------- histograms --


def test_histogram_merge_laws():
    rng = np.random.default_rng(0)
    a, b, c = Histogram(), Histogram(), Histogram()
    for h, loc in ((a, -9.0), (b, -6.0), (c, -3.0)):
        for v in rng.lognormal(loc, 2.0, size=200):
            h.observe(float(v))
    ab = a.merge(b)
    assert ab.counts == b.merge(a).counts                      # commutative
    assert ab.merge(c).counts == a.merge(b.merge(c)).counts    # associative
    total = ab.merge(c)
    assert total.count == 600 == sum(total.counts)             # conserved
    assert total.sum == pytest.approx(a.sum + b.sum + c.sum)
    assert a.count == b.count == c.count == 200                # inputs intact
    # quantiles are monotone in q and bracket the merged mass
    assert total.percentile(0.5) <= total.percentile(0.99)


def test_histogram_exposition_is_cumulative():
    reg = Registry()
    h = reg.histogram("t_seconds", {"k": "v"}, "a histogram")
    h.observe(0.001)
    h.observe(1e9)  # overflow bucket still lands in +Inf
    text = reg.prometheus_text(
        extra=[metric("g", "gauge", "a gauge", [({"n": "0"}, 2.5)])])
    assert "# TYPE t_seconds histogram" in text
    assert 't_seconds_bucket{k="v",le="+Inf"} 2' in text
    assert 't_seconds_count{k="v"} 2' in text
    assert 'g{n="0"} 2.5' in text
    buckets = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
               if line.startswith("t_seconds_bucket")]
    assert buckets == sorted(buckets)  # cumulative: nondecreasing in le


def test_request_histograms_merge_across_shard_counts():
    """The same workload on 1/2/4-shard clusters produces per-run request
    histograms a scraper can merge with conserved counts."""
    reqs = 6
    names = []
    for n_nodes in (1, 2, 4):
        name = f"obs_merge_{n_nodes}"
        names.append(name)
        store = ClusterStore(spec(name=name), n_nodes=n_nodes)
        ingest(store, 0, volume(seed=2))
        service = VolumeService()
        service.add_dataset(name, store)
        for _ in range(reqs):
            env = url_dispatch(service, "GET", f"/{name}/cutout/0/0,16/0,16/0,8")
            assert env["status"] == 200
        store.close()
    series = REGISTRY.histograms("repro_request_seconds")
    hists = [series[(("dataset", n), ("path", "cutout"))] for n in names]
    merged = hists[0].merge(hists[1]).merge(hists[2])
    assert merged.count == 3 * reqs == sum(merged.counts)
    lines = merged.prometheus_lines("repro_request_seconds", 'shard="all"')
    assert lines[-1].endswith(str(3 * reqs))  # _count conserves the total


# ---------------------------------------------------------------- tracing --


def test_untraced_instrumentation_is_inert():
    appended = trace.RING.counters()["appended"]
    with trace.span("x", k=1) as meta:
        assert meta is None  # the shared null span
        trace.event("y")

    def fn():
        return 41

    assert trace.bind(fn) is fn  # identity off-trace: no wrapper allocation
    assert trace.RING.counters()["appended"] == appended


def test_sampling_decision(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE_SAMPLE", raising=False)
    assert trace.maybe_start(None) is None           # default: never sample
    assert trace.maybe_start("feedface00000001") is not None  # explicit: always
    monkeypatch.setenv("REPRO_TRACE_SAMPLE", "1")
    assert trace.maybe_start(None) is not None       # 1: every request


def test_trace_tree_over_http(front):
    door, _base, _store = front
    tid = "0b5000000000c0de"
    status, headers, _ = http(
        "GET", f"{door.url}/v1/obs/cutout/0/0,16/8,24/0,8",
        headers={"X-Trace-Id": tid})
    assert status == 200
    assert headers["X-Trace-Id"] == tid  # echoed so clients can correlate

    status, _h, payload = http("GET", f"{door.url}/trace/{tid}")
    env = json.loads(payload.decode())
    assert status == 200 and env["trace"] == tid

    names = []

    def walk(spans, depth):
        for s in spans:
            names.append((depth, s["name"]))
            assert s["dur_s"] >= 0
            walk(s["children"], depth + 1)

    walk(env["spans"], 0)
    assert (0, "request") in names
    flat = {n for _, n in names}
    # queue wait -> plan -> per-node fetch -> decode -> assembly
    # (store.fetch appears only below a cache miss; this read may be warm)
    assert {"queue.wait", "plan", "assemble", "node.fetch", "decode"} <= flat
    # node.fetch nests under assemble, decode under node.fetch
    assert (2, "node.fetch") in names and (3, "decode") in names

    status, _h, _p = http("GET", f"{door.url}/trace/ffffffffffffffff")
    assert status == 404  # never sampled (or evicted)
    status, _h, _p = http("POST", f"{door.url}/trace/{tid}", body=b"{}")
    assert status == 405


def test_metrics_over_http(front):
    door, _base, _store = front
    http("GET", f"{door.url}/v1/obs/cutout/0/0,8/0,8/0,4")
    status, headers, payload = http("GET", f"{door.url}/metrics")
    assert status == 200
    assert headers["Content-Type"].startswith("text/plain; version=0.0.4")
    text = payload.decode()
    for family in ("repro_request_seconds_bucket", "repro_reads_total",
                   "repro_cache_hits_total", "repro_nodes",
                   "repro_replication", "repro_segment_heat_total",
                   "repro_trace_ring"):
        assert family in text, family
    assert 'repro_replication{dataset="obs"} 2' in text
    # dataset-scoped scrape and the guards
    status, _h, scoped = http("GET", f"{door.url}/v1/obs/metrics")
    assert status == 200 and b"repro_reads_total" in scoped
    assert http("GET", f"{door.url}/nope/metrics")[0] == 404
    assert http("POST", f"{door.url}/metrics", body=b"{}")[0] == 405


# ------------------------------------------------- counters + concurrency --


def test_counter_invariants_race_live_rebalance():
    """Concurrent replicated reads racing a live rebalance: afterwards
    every read was a cache hit or a miss, and inflight drains to 0."""
    store = ClusterStore(spec(name="race"), n_nodes=3, replication=2,
                         cache_bytes=8 << 20)
    base = volume(seed=3)
    ingest(store, 0, base)
    store.flush()
    errors = []

    def reader(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(25):
                lo = [int(rng.integers(0, s - 8)) for s in SHAPE]
                hi = [a + 8 for a in lo]
                got = cutout(store, 0, lo, hi)
                sl = tuple(slice(a, b) for a, b in zip(lo, hi))
                np.testing.assert_array_equal(got, base[sl])
        except Exception as e:  # surface on the main thread
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(50 + i,))
               for i in range(4)]
    for t in threads:
        t.start()
    store.rebalance(target=4)
    store.rebalance(target=3)
    for t in threads:
        t.join(timeout=120)
    try:
        assert not errors, errors
        rs, ws = store.read_stats, store.write_stats
        assert rs.reads + ws.reads == rs.cache_hits + rs.cache_misses
        assert rs.inflight == 0
        assert all(n.read_stats.inflight == 0 for n in store.nodes)
        heat = store.access_heat()
        assert sum(n for _r, _b, n in heat["read"]) > 0
    finally:
        store.close()


# ------------------------------------------------------------- satellites --


def test_stats_reports_nodes_replication_partitions(front):
    door, _base, store = front
    http("GET", f"{door.url}/obs/cutout/0/0,16/0,16/0,8")
    status, _h, payload = http("GET", f"{door.url}/obs/stats")
    env = json.loads(payload.decode())
    assert status == 200
    assert len(env["nodes"]) == 3
    agg = env["read"]["reads"]
    assert agg == sum(n["read"]["reads"] for n in env["nodes"])
    assert env["replication"] == 2
    bounds = env["partitions"]["0"]
    assert bounds == sorted(bounds) and len(bounds) == 4  # 3 nodes -> 4 cuts
    assert env["heat"]["bits"] == store.heat_bits


def test_dispatch_shim_warns_and_matches_url_router():
    store = CuboidStore(spec(name="shim"))
    ingest(store, 0, volume(seed=4))
    service = VolumeService()
    service.add_dataset("shim", store)
    via_url = url_dispatch(service, "GET", "/shim/topology")
    with pytest.warns(DeprecationWarning, match="url_dispatch"):
        via_shim = dispatch(service, {"dataset": "shim",
                                      "verb": "GET /topology"})
    assert via_shim == via_url
    with pytest.warns(DeprecationWarning):
        assert dispatch(service, {}, "NO /verb")["status"] == 405


def test_access_log_and_slow_request(front, monkeypatch):
    door, _base, _store = front
    records = []

    class Capture(logging.Handler):
        def emit(self, record):
            records.append(json.loads(record.getMessage()))

    handler = Capture()
    obs_log.LOGGER.addHandler(handler)
    try:
        # silent by default: no access record without the env gate
        http("GET", f"{door.url}/obs/topology")
        assert not any(r["kind"] == "access" for r in records)
        monkeypatch.setenv("REPRO_ACCESS_LOG", "1")
        monkeypatch.setenv("REPRO_SLOW_MS", "0")  # everything is "slow"
        tid = "51000000000000de"
        http("GET", f"{door.url}/obs/cutout/0/0,8/0,8/0,4",
             headers={"X-Trace-Id": tid})
        access = [r for r in records if r["kind"] == "access"]
        assert access and access[-1]["status"] == 200
        assert access[-1]["trace"] == tid
        slow = [r for r in records if r["kind"] == "slow_request"]
        assert slow and slow[-1]["trace"] == tid
        roots = [s["name"] for s in slow[-1]["spans"]]
        assert "request" in roots  # the dump carries the span tree
    finally:
        obs_log.LOGGER.removeHandler(handler)


def test_cluster_watch_advises_from_gauges():
    store = ClusterStore(spec(name="watch"), n_nodes=2, write_behind=1 << 20)
    ingest(store, 0, volume(seed=5))
    try:
        watch = ClusterWatch(store, skew=1.01, max_queue_depth=0)
        actions = watch.step()
        snap = watch.history[-1]
        assert snap["n_nodes"] == 2 and sum(snap["keys_per_node"]) > 0
        if snap["queue_depth"] > 0:  # ingest rode the write-behind queue
            assert any(a["action"] == "flush" for a in actions)
        store.flush()
        assert all(a["action"] != "flush" for a in watch.step())
    finally:
        store.close()
