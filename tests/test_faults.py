"""Chaos suite: fault injection, the node-health machine, degraded data
paths, and automatic failover (ISSUE 10's acceptance bar).

The contract under test is the paper's always-on service story (§4.2 "no
single point of failure") made executable: under seeded injected faults —
errors, latency, hangs, and killing a live owner mid-traffic — the
replicated cluster loses **zero acked writes**, serves reads
**bit-identical to a single-store oracle**, and the supervisor heals
replication back to target with no operator call.
"""
import threading
import time

import numpy as np
import pytest

from repro.cluster import ClusterStore
from repro.cluster.store import NoLiveReplica, RebalanceInFlight
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest
from repro.core.store import CuboidStore, set_crash_hook
from repro.ft import (ClusterWatch, FaultInjected, FaultPlan, FaultyNode,
                      NodeCrashed, StorageSupervisor, crash_schedule_hook,
                      faulty_factory)

SHAPE = (32, 32, 16)
CUBOID = (8, 8, 4)
N_CELLS = 64  # 4x4x4 grid


def spec(shape=SHAPE, **kw):
    return DatasetSpec(name="ft", volume_shape=shape, dtype="uint8",
                       base_cuboid=CUBOID, **kw)


def volume(seed=0, shape=SHAPE):
    return np.random.default_rng(seed).integers(
        1, 255, size=shape, dtype=np.uint8)


def crash_only_cluster(n_nodes=3, replication=2, **kw):
    """(cluster, factory) with per-node plans that only fault when a test
    crashes them explicitly — deterministic failure placement."""
    plans = {i: FaultPlan(seed=i) for i in range(n_nodes)}
    fac = faulty_factory(plans=plans)
    store = ClusterStore(spec(), n_nodes=n_nodes, replication=replication,
                         node_factory=fac, **kw)
    return store, fac


# ----------------------------------------------------------- the harness --


def test_fault_plan_is_deterministic_under_seed():
    ops = 200

    def run(seed):
        plan = FaultPlan(seed=seed, error_rate=0.3)
        hits = []
        for n in range(ops):
            try:
                plan.before_op("op")
                hits.append(0)
            except FaultInjected:
                hits.append(1)
        return hits

    assert run(7) == run(7)          # replayable
    assert run(7) != run(8)          # the seed matters
    assert 0 < sum(run(7)) < ops     # rate actually injects


def test_fault_plan_schedule_and_crash_cycle():
    plan = FaultPlan(schedule={1: "error", 3: "crash", 5: "restart"})
    node = FaultyNode(CuboidStore(spec()), plan)
    block = np.full(CUBOID, 7, np.uint8)
    node.write_cuboid(0, 0, block)                   # op 0: clean
    with pytest.raises(FaultInjected):
        node.write_cuboid(0, 1, block)               # op 1: scheduled error
    node.write_cuboid(0, 1, block)                   # op 2: clean
    with pytest.raises(NodeCrashed):
        node.read_cuboid(0, 0)                       # op 3: crash fires
    with pytest.raises(NodeCrashed):
        node.read_cuboid(0, 0)                       # op 4: still down
    np.testing.assert_array_equal(node.read_cuboid(0, 0), block)  # op 5: back
    c = plan.counters()
    assert c["crashes"] == 1 and c["restarts"] == 1 and c["errors"] == 1
    # data survived the crash (machines fail, disks persist)
    np.testing.assert_array_equal(node.read_cuboid(0, 1), block)


def test_faulty_node_passthrough_and_attribute_delegation():
    inner = CuboidStore(spec())
    node = FaultyNode(inner, FaultPlan())
    node.crash()
    # the migration/repair plumbing is NOT intercepted: healing must work
    # on a node whose serving path is down
    assert len(node.stored_keys()) == 0
    node.flush()
    # attribute writes delegate to the wrapped store
    node.some_attr = 42
    assert inner.some_attr == 42
    node.restart()
    assert not node.plan.crashed


def test_crash_schedule_hook_composes_with_crashpoints(tmp_path):
    """The harness can tear the durable-put path at a named syscall
    boundary via the storage tier's own crash hooks."""
    from repro.core.store import DirectoryBackend
    store = CuboidStore(spec(), backend=DirectoryBackend(str(tmp_path)))
    set_crash_hook(crash_schedule_hook({"dir.put.written": 2}))
    try:
        block = np.full(CUBOID, 5, np.uint8)
        store.write_cuboid(0, 0, block)              # hit 1: survives
        with pytest.raises(FaultInjected):
            store.write_cuboid(0, 1, block)          # hit 2: torn mid-put
        store.write_cuboid(0, 2, block)              # hit 3: back to normal
    finally:
        set_crash_hook(None)
    np.testing.assert_array_equal(store.read_cuboid(0, 0), block)
    np.testing.assert_array_equal(store.read_cuboid(0, 2), block)


# ------------------------------------------------------ health machine --


def test_health_machine_transitions_and_export():
    store, fac = crash_only_cluster()
    try:
        vol = volume(1)
        ingest(store, 0, vol)
        assert store.topology()["health"] == ["alive"] * 3
        fac.built[1].crash()
        # consecutive probe failures walk alive -> suspect -> dead
        for _ in range(6):
            store.probe_health()
        assert store.topology()["health"][1] == "dead"
        health = store.node_health()
        assert health[1]["state"] == "dead"
        assert health[1]["consecutive_errors"] >= 6
        assert health[1]["last_error"]
        # a dead node comes back as recovering (not straight to alive):
        # it must resync before serving reads again
        fac.built[1].restart()
        store.probe_health()
        assert store.topology()["health"][1] == "recovering"
        store.resync_node(1)
        assert store.topology()["health"][1] == "alive"
    finally:
        store.close()


def test_prober_background_tick_marks_dead():
    store, fac = crash_only_cluster()
    try:
        fac.built[2].crash()
        store.start_prober(interval=0.01)
        deadline = time.monotonic() + 5.0
        while (store.topology()["health"][2] != "dead"
               and time.monotonic() < deadline):
            time.sleep(0.02)
        assert store.topology()["health"][2] == "dead"
    finally:
        store.close()  # stops the prober


# ------------------------------------------------- degraded data paths --


def test_reads_survive_dead_node_oracle_identical():
    """/cutout under a dead node returns correct data from survivors —
    both before the health machine notices and after it marks it dead."""
    oracle = CuboidStore(spec())
    store, fac = crash_only_cluster()
    try:
        vol = volume(2)
        ingest(oracle, 0, vol)
        ingest(store, 0, vol)
        fac.built[0].crash()
        # before: the data path eats the errors and fails over per-op
        np.testing.assert_array_equal(
            cutout(store, 0, (0, 0, 0), SHAPE), cutout(oracle, 0, (0, 0, 0), SHAPE))
        np.testing.assert_array_equal(
            cutout(store, 0, (3, 5, 1), (29, 31, 15)),
            cutout(oracle, 0, (3, 5, 1), (29, 31, 15)))
        for _ in range(6):
            store.probe_health()
        assert store.topology()["health"][0] == "dead"
        # after: dead members are routed around entirely
        np.testing.assert_array_equal(
            cutout(store, 0, (0, 0, 0), SHAPE), cutout(oracle, 0, (0, 0, 0), SHAPE))
    finally:
        store.close()
        oracle.close()


def test_read_with_no_live_replica_raises():
    store, fac = crash_only_cluster(n_nodes=2, replication=1)
    try:
        block = np.full(CUBOID, 3, np.uint8)
        store.write_cuboid(0, 0, block)
        for node in fac.built.values():
            node.crash()
        with pytest.raises((NoLiveReplica, NodeCrashed, FaultInjected)):
            store.read_cuboid(0, 0)
    finally:
        for node in fac.built.values():
            node.restart()
        store.close()


def test_deadline_budget_bounds_a_hung_node():
    """A hung replica may delay a budgeted read, never stall it: the read
    fails over to the surviving member within the deadline budget."""
    from repro.cluster import deadline
    hang_s = 1.5
    plans = {i: FaultPlan(seed=i) for i in range(3)}
    fac = faulty_factory(plans=plans)
    store = ClusterStore(spec(), n_nodes=3, replication=2, node_factory=fac)
    try:
        block = np.full(CUBOID, 9, np.uint8)
        store.write_cuboid(0, 0, block)
        owners = store.router.replica_set(0, 0)
        first = store._pick_replica(store._topo, tuple(owners))
        # every op on the preferred replica hangs from now on
        fac.built[first].plan.hang_s = hang_s
        fac.built[first].plan.hang_rate = 1.0
        t0 = time.monotonic()
        with deadline.budget(0.3):
            got = store.read_cuboid(0, 0)
        elapsed = time.monotonic() - t0
        np.testing.assert_array_equal(got, block)
        assert elapsed < hang_s  # failed over, did not wait out the hang
        fac.built[first].plan.hang_rate = 0.0
    finally:
        store.close()


def test_degraded_writes_ack_at_quorum_and_queue_repair():
    store, fac = crash_only_cluster()
    try:
        vol = volume(3)
        ingest(store, 0, vol)
        fac.built[1].crash()
        for _ in range(6):
            store.probe_health()
        assert store.topology()["health"][1] == "dead"
        # writes ack at the quorum of live replicas, misses queue as repair
        block = np.full(CUBOID, 77, np.uint8)
        for m in range(N_CELLS):
            store.write_cuboid(0, m, block)
        assert store.topology()["repair_pending"] > 0
        for m in range(N_CELLS):
            np.testing.assert_array_equal(store.read_cuboid(0, m), block)
        # recovery: restart, probe to recovering, resync, healed
        fac.built[1].restart()
        store.probe_health()
        report = store.resync_node(1)
        assert report["healed"]
        assert store.topology()["repair_pending"] == 0
        assert store.topology()["health"][1] == "alive"
        # the healed node's own shard now holds the repaired writes
        inner = fac.built[1].inner
        for r, c, m in inner.stored_keys():
            np.testing.assert_array_equal(inner.read_cuboid(r, m, c), block)
        assert inner.stored_keys()  # it does own something after resync
    finally:
        store.close()


# ------------------------------------------------------------- failover --


def test_supervisor_auto_failover_loses_no_acked_write():
    """A dead node triggers replica promotion + re-replication with no
    operator call; every acked write stays readable and oracle-identical."""
    oracle = CuboidStore(spec())
    store, fac = crash_only_cluster()
    sup = StorageSupervisor(store, watch=ClusterWatch(store, dead_ticks=2))
    try:
        vol = volume(4)
        ingest(oracle, 0, vol)
        ingest(store, 0, vol)           # every one of these writes is acked
        fac.built[1].crash()            # kill a live owner; never restarts
        deadline = time.monotonic() + 30.0
        while store.topology()["n_nodes"] != 2 and time.monotonic() < deadline:
            sup.step()
            time.sleep(0.02)
        topo = store.topology()
        assert topo["n_nodes"] == 2, "supervisor never failed the node over"
        assert topo["health"] == ["alive", "alive"]
        # keep ticking until replication is healed back to target
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            sup.step()
            topo = store.topology()
            if (topo.get("replication") == topo.get("replication_target")
                    and not topo["rebalancing"]):
                break
            time.sleep(0.02)
        assert topo.get("replication") == topo.get("replication_target")
        np.testing.assert_array_equal(
            cutout(store, 0, (0, 0, 0), SHAPE), cutout(oracle, 0, (0, 0, 0), SHAPE))
        assert store.stored_keys() == oracle.stored_keys()
    finally:
        sup.stop()
        store.close()
        oracle.close()


def test_failover_is_debounced_and_not_double_promoted():
    """Stale failover advice re-verifies against live health: one removal
    happens, a second attempt is a no-op, an operator race loses cleanly."""
    store, fac = crash_only_cluster()
    sup = StorageSupervisor(store)
    try:
        ingest(store, 0, volume(5))
        fac.built[2].crash()
        for _ in range(6):
            store.probe_health()
        assert store.node_health()[2]["state"] == "dead"
        action = {"action": "failover", "node": 2}
        assert sup._execute(dict(action))
        store.synchronize(timeout=30)
        while store.topology()["rebalancing"]:
            time.sleep(0.01)
        assert store.topology()["n_nodes"] == 2
        # the same (now stale) advice again: health re-verification skips it
        assert not sup._execute(dict(action))
        assert store.topology()["n_nodes"] == 2
        # an operator remove_node racing a failover either wins or raises
        # RebalanceInFlight/ValueError — never a second silent promotion
        with pytest.raises((ValueError, IndexError)):
            store.remove_node(5, wait=False)
        assert store.topology()["n_nodes"] == 2
    finally:
        sup.stop()
        store.close()


# ----------------------------------------------- satellite: admin races --


def test_synchronize_timeout_expires_loudly():
    store = ClusterStore(spec(), n_nodes=2, replication=2)
    entered = threading.Event()
    release = threading.Event()

    def holder():
        with store._gate.op():
            entered.set()
            release.wait(10)

    t = threading.Thread(target=holder, daemon=True)
    t.start()
    try:
        assert entered.wait(5)
        with pytest.raises(TimeoutError):
            store.synchronize(timeout=0.2)
    finally:
        release.set()
        t.join(5)
    store.synchronize(timeout=10)  # clean once the op drains
    store.close()


def test_remove_node_races_health_prober():
    """Topology shrink while the background prober ticks: no deadlock, no
    stale-index explosion, data intact (runs under the lock witness)."""
    store = ClusterStore(spec(), n_nodes=4, replication=2)
    try:
        vol = volume(6)
        ingest(store, 0, vol)
        store.start_prober(interval=0.01)
        store.remove_node(3)
        store.remove_node(0)
        topo = store.topology()
        assert topo["n_nodes"] == 2
        assert topo["health"] == ["alive", "alive"]
        np.testing.assert_array_equal(cutout(store, 0, (0, 0, 0), SHAPE), vol)
    finally:
        store.close()


# ------------------------------------------------- chaos coherence walk --


def test_chaos_coherence_walk():
    """The acceptance bar: seeded faults (injected errors + latency on
    every node, a live owner killed mid-traffic, then restarted) under
    concurrent replicated reads and writes — zero acked writes lost, all
    reads bit-identical to the single-store oracle, and the cluster heals
    back to every-node-alive with no operator call."""
    rng = np.random.default_rng(42)
    plans = {
        i: FaultPlan(seed=100 + i, error_rate=0.04, latency_s=0.0005)
        for i in range(3)
    }
    fac = faulty_factory(plans=plans)
    oracle = CuboidStore(spec())
    store = ClusterStore(spec(), n_nodes=3, replication=2, node_factory=fac)
    sup = StorageSupervisor(store, watch=ClusterWatch(store, dead_ticks=3),
                            allow_failover=False)  # heal-in-place walk

    def retrying(fn, attempts=60):
        last = None
        for _ in range(attempts):
            try:
                return fn()
            except Exception as e:  # injected faults + quorum misses
                last = e
                time.sleep(0.002)
        raise last

    stop = threading.Event()
    read_errors = []

    def reader():
        r = np.random.default_rng(7)
        while not stop.is_set():
            m = int(r.integers(0, N_CELLS))
            try:
                retrying(lambda: store.read_cuboid(0, m))
            except KeyError:
                pass  # not written yet — fine
            except Exception as e:
                read_errors.append(repr(e))

    try:
        # seed both stores identically (acked = applied to oracle too)
        for m in range(N_CELLS):
            blk = rng.integers(1, 255, size=CUBOID, dtype=np.uint8)
            retrying(lambda b=blk, mm=m: store.write_cuboid(0, mm, b))
            oracle.write_cuboid(0, m, blk)

        t = threading.Thread(target=reader, daemon=True)
        t.start()

        # mid-traffic: kill a live owner, keep writing through the outage
        fac.built[1].crash()
        for step in range(80):
            m = int(rng.integers(0, N_CELLS))
            blk = rng.integers(1, 255, size=CUBOID, dtype=np.uint8)
            retrying(lambda b=blk, mm=m: store.write_cuboid(0, mm, b))
            oracle.write_cuboid(0, m, blk)  # acked -> the oracle gets it
            sup.step()
            if step == 40:
                fac.built[1].restart()  # the machine comes back
        # heal: supervisor resyncs the recovered node on its own ticks
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            sup.step()
            topo = store.topology()
            if (topo["health"] == ["alive"] * 3
                    and topo["repair_pending"] == 0):
                break
            time.sleep(0.01)
        stop.set()
        t.join(10)

        assert not read_errors, f"reader saw terminal errors: {read_errors[:3]}"
        topo = store.topology()
        assert topo["health"] == ["alive"] * 3
        assert topo["repair_pending"] == 0
        # zero acked writes lost; every read oracle-identical
        for node in fac.built.values():  # no faults during verification
            node.plan.error_rate = 0.0
            node.plan.latency_s = 0.0
        np.testing.assert_array_equal(
            cutout(store, 0, (0, 0, 0), SHAPE), cutout(oracle, 0, (0, 0, 0), SHAPE))
        store.flush()
        assert store.stored_keys() == oracle.stored_keys()
    finally:
        stop.set()
        sup.stop()
        store.close()
        oracle.close()
