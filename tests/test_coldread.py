"""Cold-read pipeline suite (node-parallel decode, shared-buffer assembly,
plan-driven segment prefetch).

The contract under test: the pipelined cold path — decode fanned out
across worker threads, blocks assembled straight into the shared output
buffer through the plan's disjoint ``buf_slices``, future curve segments
prefetching into the hot-cuboid cache mid-assembly — is **bit-identical to
``cutout_loop``** for every policy, shard count, and cache configuration,
under seeded and property-based op interleavings, and under concurrent
cold readers racing a live ``rebalance()``.

Also here: the satellite regressions — zero-copy aligned cutouts,
``batch_cutout`` overlap, the `DatasetSpec.compress_level` /
``REPRO_COMPRESS_LEVEL`` plumbing, the `DecodePolicy` env knobs, and the
cache's prefetch admission guard.
"""
import threading

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.cluster import ClusterStore, CuboidCache, attach_cache
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import (CutoutStats, batch_cutout, cutout,
                               cutout_loop, ingest, plan_cutout,
                               write_cutout)
from repro.core.store import CuboidStore, DecodePolicy, MemoryBackend

SHAPE = (32, 32, 16)
CUBOID = (8, 8, 4)

SERIAL = DecodePolicy(workers=0, prefetch_segments=0)
PARALLEL = DecodePolicy(workers=4, chunk=2, prefetch_segments=0)
PIPELINED = DecodePolicy(workers=4, chunk=2, prefetch_segments=2)
POLICIES = {"serial": SERIAL, "parallel": PARALLEL, "pipelined": PIPELINED}


def spec(**kw):
    return DatasetSpec(name="cr", volume_shape=SHAPE, dtype="uint8",
                       base_cuboid=CUBOID, **kw)


def volume(seed=0):
    return np.random.default_rng(seed).integers(
        1, 255, size=SHAPE, dtype=np.uint8)


def seeded_boxes(n, seed=5):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        lo = [int(rng.integers(0, s - 1)) for s in SHAPE]
        hi = [int(rng.integers(l + 1, s + 1)) for l, s in zip(lo, SHAPE)]
        out.append((lo, hi))
    return out


def reference(vol):
    ref = CuboidStore(spec(), decode_policy=SERIAL)
    ingest(ref, 0, vol)
    return ref


# -- bit-identity: pipelined cold reads vs the cutout_loop oracle ----------

@pytest.mark.parametrize("policy", list(POLICIES), ids=list(POLICIES))
@pytest.mark.parametrize("n_nodes", [1, 2, 4])
@pytest.mark.parametrize("cached", [False, True])
def test_cold_reads_match_loop_oracle(n_nodes, cached, policy):
    vol = volume()
    ref = reference(vol)
    sub = ClusterStore(spec(), n_nodes=n_nodes,
                       cache_bytes=(64 << 20) if cached else 0,
                       write_behind=False,
                       decode_policy=POLICIES[policy])
    ingest(sub, 0, vol)
    if cached:  # cold = cache-empty, not just disk-cold
        for node in sub.nodes:
            if node.cache is not None:
                node.cache.clear()
    try:
        for lo, hi in seeded_boxes(8):
            want = cutout_loop(ref, 0, lo, hi)
            np.testing.assert_array_equal(cutout(sub, 0, lo, hi), want)
            # second (warm / prefetch-primed) pass stays identical
            np.testing.assert_array_equal(cutout(sub, 0, lo, hi), want)
    finally:
        sub.close()


def test_parallel_decode_single_store_matches():
    """`CuboidStore` alone benefits: chunked parallel decode, no cluster."""
    vol = volume(seed=3)
    store = CuboidStore(spec(), decode_policy=PARALLEL)
    ingest(store, 0, vol)
    ref = reference(vol)
    for lo, hi in seeded_boxes(6, seed=7):
        np.testing.assert_array_equal(cutout(store, 0, lo, hi),
                                      cutout_loop(ref, 0, lo, hi))
    assert store.read_stats.decoded_blocks > 0
    assert store.read_stats.decode_s > 0.0


def test_fetch_runs_decode_mode():
    """`fetch_runs(decode=True)` returns decoded blocks on both store
    kinds, equal to decompressing the blob mode's result."""
    vol = volume(seed=4)
    single = CuboidStore(spec(), decode_policy=PARALLEL)
    cluster = ClusterStore(spec(), n_nodes=2, decode_policy=PARALLEL)
    ingest(single, 0, vol)
    ingest(cluster, 0, vol)
    runs = plan_cutout(single.spec.grid(0), 0, (0, 0, 0), SHAPE).runs
    try:
        for store in (single, cluster):
            blobs = store.fetch_runs(0, runs)
            blocks = store.fetch_runs(0, runs, decode=True)
            assert set(blobs) == set(blocks)
            for m, blob in blobs.items():
                if blob is None:
                    assert blocks[m] is None
                else:
                    np.testing.assert_array_equal(
                        blocks[m],
                        single.read_cuboid(0, m))
    finally:
        cluster.close()


if HAVE_HYPOTHESIS:
    op_strategy = st.lists(
        st.one_of(
            st.tuples(st.just("write"), st.integers(0, 7),
                      st.integers(0, 254)),
            st.tuples(st.just("cutout"), st.integers(0, 5)),
            st.tuples(st.just("clear_cache"), st.just(0)),
            st.tuples(st.just("write_cutout"), st.integers(0, 5),
                      st.integers(1, 254)),
        ),
        min_size=1, max_size=12)
else:  # decoration-time stand-in; the test is skipped
    op_strategy = st.nothing()


@given(ops=op_strategy)
@settings(max_examples=25, deadline=None)
def test_interleavings_match_reference(ops):
    """Random read/write/cutout/cache-drop interleavings over the
    pipelined cluster stay bit-identical to an uncached reference."""
    vol = volume(seed=9)
    ref = reference(vol)
    sub = ClusterStore(spec(), n_nodes=2, cache_bytes=8 << 10,
                       write_behind=False, decode_policy=PIPELINED)
    ingest(sub, 0, vol)
    boxes = seeded_boxes(6, seed=11)
    grid = ref.spec.grid(0)
    try:
        for op in ops:
            if op[0] == "write":
                m = op[1] % grid.n_cuboids
                data = np.full(grid.cuboid_shape, op[2], dtype=np.uint8)
                ref.write_cuboid(0, m, data)
                sub.write_cuboid(0, m, data)
            elif op[0] == "cutout":
                lo, hi = boxes[op[1]]
                np.testing.assert_array_equal(
                    cutout(sub, 0, lo, hi), cutout_loop(ref, 0, lo, hi))
            elif op[0] == "clear_cache":
                for node in sub.nodes:
                    if node.cache is not None:
                        node.cache.clear()
            else:  # write_cutout
                lo, hi = boxes[op[1]]
                patch = np.full([h - l for l, h in zip(lo, hi)], op[2],
                                dtype=np.uint8)
                write_cutout(ref, 0, lo, patch)
                write_cutout(sub, 0, lo, patch)
        for lo, hi in boxes:
            np.testing.assert_array_equal(
                cutout(sub, 0, lo, hi), cutout_loop(ref, 0, lo, hi))
    finally:
        sub.close()


def test_concurrent_cold_readers_and_rebalance():
    """Concurrent cold readers never corrupt the shared buffer and never
    deadlock against a live rebalance: every cutout, before/during/after
    the 2→4→3 walk, is bit-identical to the immutable ingested volume."""
    vol = volume(seed=13)
    sub = ClusterStore(spec(), n_nodes=2, cache_bytes=32 << 10,
                       write_behind=True, decode_policy=PIPELINED)
    ingest(sub, 0, vol)
    boxes = seeded_boxes(6, seed=17)
    errors = []
    stop = threading.Event()

    def reader(seed):
        rng = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                lo, hi = boxes[int(rng.integers(0, len(boxes)))]
                got = cutout(sub, 0, lo, hi)
                want = vol[tuple(slice(l, h) for l, h in zip(lo, hi))]
                if not np.array_equal(got, want):
                    errors.append((lo, hi))
                    return
                if rng.integers(0, 4) == 0:  # periodically go cold again
                    for node in sub.nodes:
                        if node.cache is not None:
                            node.cache.clear()
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(repr(e))

    threads = [threading.Thread(target=reader, args=(31 + i,))
               for i in range(4)]
    for t in threads:
        t.start()
    try:
        sub.rebalance(target=4)
        sub.rebalance(target=3)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    assert not errors, errors[:3]
    assert not any(t.is_alive() for t in threads), "reader deadlocked"
    for lo, hi in boxes:
        np.testing.assert_array_equal(
            cutout(sub, 0, lo, hi),
            vol[tuple(slice(l, h) for l, h in zip(lo, hi))])
    sub.close()


# -- satellite: zero-copy aligned cutouts ----------------------------------

def test_aligned_cutout_is_zero_copy():
    vol = volume(seed=19)
    store = CuboidStore(spec(), decode_policy=SERIAL)
    ingest(store, 0, vol)
    stats = CutoutStats()
    out = cutout(store, 0, (8, 8, 4), (24, 24, 12), stats=stats)
    np.testing.assert_array_equal(out, vol[8:24, 8:24, 4:12])
    assert stats.zero_copy == 1
    assert stats.bytes_discarded == 0
    assert out.base is None  # the assembly buffer itself, not a trim copy
    assert out.flags.c_contiguous


def test_unaligned_cutout_still_copies():
    vol = volume(seed=19)
    store = CuboidStore(spec(), decode_policy=SERIAL)
    ingest(store, 0, vol)
    stats = CutoutStats()
    out = cutout(store, 0, (7, 8, 4), (24, 24, 12), stats=stats)
    np.testing.assert_array_equal(out, vol[7:24, 8:24, 4:12])
    assert stats.zero_copy == 0
    assert stats.bytes_discarded > 0
    assert out.flags.c_contiguous


# -- satellite: batch_cutout overlap ---------------------------------------

def test_batch_cutout_overlaps_and_matches():
    vol = volume(seed=23)
    sub = ClusterStore(spec(), n_nodes=2, decode_policy=PARALLEL)
    ingest(sub, 0, vol)
    ref = reference(vol)
    boxes = seeded_boxes(5, seed=29)
    try:
        got = batch_cutout(sub, 0, boxes)
        assert len(got) == len(boxes)
        for (lo, hi), arr in zip(boxes, got):
            np.testing.assert_array_equal(arr, cutout_loop(ref, 0, lo, hi))
        # single stores have no request pool and stay serial — same answers
        got_single = batch_cutout(ref, 0, boxes)
        for a, b in zip(got, got_single):
            np.testing.assert_array_equal(a, b)
    finally:
        sub.close()


def test_batch_cutout_serial_cluster():
    """max_workers=0 disables request parallelism; results unchanged."""
    vol = volume(seed=23)
    sub = ClusterStore(spec(), n_nodes=2, max_workers=0,
                       decode_policy=SERIAL)
    ingest(sub, 0, vol)
    boxes = seeded_boxes(3, seed=29)
    ref = reference(vol)
    try:
        for (lo, hi), arr in zip(boxes, batch_cutout(sub, 0, boxes)):
            np.testing.assert_array_equal(arr, cutout_loop(ref, 0, lo, hi))
    finally:
        sub.close()


# -- satellite: codec level plumbing ---------------------------------------

def test_compress_level_spec_field():
    flat = np.zeros(SHAPE, dtype=np.uint8)
    flat[:16] = 7  # very compressible
    stored = {}
    for level in (0, 9):
        store = CuboidStore(spec(compress_level=level))
        assert store.compression_level == level
        ingest(store, 0, flat)
        stored[level] = store.storage_bytes()
    assert stored[9] < stored[0]  # level really reached the codec


def test_compress_level_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_COMPRESS_LEVEL", "6")
    store = CuboidStore(spec(compress_level=1))
    assert store.compression_level == 6
    # explicit constructor arg beats both env and spec
    store = CuboidStore(spec(compress_level=1), compression_level=2)
    assert store.compression_level == 2


def test_decode_policy_env_knobs(monkeypatch):
    monkeypatch.setenv("REPRO_DECODE_WORKERS", "5")
    monkeypatch.setenv("REPRO_PREFETCH_SEGMENTS", "3")
    pol = DecodePolicy.from_env()
    assert pol.workers == 5
    assert pol.prefetch_segments == 3
    store = CuboidStore(spec())
    assert store.decode_policy.workers == 5
    cluster = ClusterStore(spec(), n_nodes=2)
    try:
        assert all(n.decode_policy.workers == 5 for n in cluster.nodes)
    finally:
        cluster.close()


# -- prefetch: admission guard + counters ----------------------------------

def test_prefetch_admission_never_evicts():
    cache = CuboidCache(max_bytes=2048, segment_bits=2)
    hot = [(0, 0, m) for m in range(4)]
    for key in hot:
        cache.put(key, b"x" * 128)
    resident = cache.bytes
    # a "giant scan" prefetch: far larger than the whole budget
    items = [((0, 0, 64 + m), b"y" * 256) for m in range(64)]
    admitted, rejected = cache.put_prefetched(items)
    assert rejected > 0
    assert cache.bytes <= cache.max_bytes
    assert cache.counters()["prefetch_rejected"] == rejected
    # every hot key survived: prefetch only filled the spare budget
    for key in hot:
        assert cache.probe(key)[0]
    assert cache.bytes >= resident


def test_prefetched_segments_evict_first():
    """Untouched prefetched segments sit at the LRU end: the next normal
    insert pressure drops them before any real resident segment."""
    cache = CuboidCache(max_bytes=4096, segment_bits=1)
    cache.put((0, 0, 0), b"h" * 512)  # hot resident
    admitted, _ = cache.put_prefetched([((0, 0, 32), b"p" * 512)])
    assert admitted == 1
    # normal inserts push just past the budget: the first eviction must
    # eat the prefetched segment, not any real resident
    m = 2
    while cache.evictions == 0 and m < 64:
        cache.put((0, 0, m), b"n" * 512)
        m += 2
    assert cache.evictions > 0
    assert not cache.probe((0, 0, 32))[0]  # prefetched dropped first
    assert cache.probe((0, 0, 0))[0]       # hot key survived


def test_prefetch_hit_counted_once():
    cache = CuboidCache(max_bytes=64 << 10)
    cache.put_prefetched([((0, 0, 1), b"z" * 64)])
    assert cache.counters()["prefetch_insertions"] == 1
    hit, blob = cache.get_blob((0, 0, 1))
    assert hit and blob == b"z" * 64
    cache.get_blob((0, 0, 1))
    assert cache.counters()["prefetch_hits"] == 1  # first touch only


def test_prefetch_pipeline_populates_cache_and_counters():
    vol = volume(seed=37)
    store = CuboidStore(spec(), decode_policy=PIPELINED)
    attach_cache(store, 64 << 20)
    ingest(store, 0, vol)
    store.cache.clear()
    lo, hi = (8, 8, 0), (32, 32, 16)  # multi-run schedule
    plan = plan_cutout(store.spec.grid(0), 0, lo, hi)
    assert len(plan.runs) > 1
    np.testing.assert_array_equal(cutout(store, 0, lo, hi),
                                  vol[8:32, 8:32, 0:16])
    assert store.read_stats.prefetch_issued > 0
    # the counters surface through the stats dataclass (GET /stats body)
    snap = store.read_stats.snapshot()
    assert snap.prefetch_issued == store.read_stats.prefetch_issued


def test_stats_verb_surfaces_decode_and_prefetch():
    from repro.cluster import VolumeService, dispatch

    vol = volume(seed=41)
    service = VolumeService()
    cluster = ClusterStore(spec(), n_nodes=2, cache_bytes=1 << 20,
                           decode_policy=PIPELINED)
    ingest(cluster, 0, vol)
    for node in cluster.nodes:
        node.cache.clear()
    service.add_dataset("ds", cluster)
    try:
        resp = dispatch(service, {"verb": "GET /cutout", "dataset": "ds",
                                  "lo": (8, 8, 0), "hi": (32, 32, 16)})
        assert resp["status"] == 200
        assert resp["zero_copy"] is True
        stats = dispatch(service, {"verb": "GET /stats", "dataset": "ds"})
        assert stats["status"] == 200
        assert stats["read"]["decoded_blocks"] > 0
        assert "prefetch_issued" in stats["read"]
        assert "prefetch_hits" in stats["cache"]
        assert stats["decode"] == {"workers": 4, "chunk": 2,
                                   "prefetch_segments": 2}
    finally:
        cluster.close()


def test_prefetch_handoff_when_admission_rejected():
    """A cache too small to admit prefetched blobs still gets correct
    pipelined reads: the foreground consumes the prefetcher's fetched
    blobs directly (the handoff) instead of refetching or stalling."""
    vol = volume(seed=47)
    store = CuboidStore(spec(), decode_policy=DecodePolicy(
        workers=2, chunk=2, prefetch_segments=2))
    cache = attach_cache(store, 2048)  # a few entries at most
    ingest(store, 0, vol)
    cache.clear()
    lo, hi = (8, 8, 0), (32, 32, 16)  # multi-run schedule
    for _ in range(3):
        cache.clear()
        np.testing.assert_array_equal(cutout(store, 0, lo, hi),
                                      vol[8:32, 8:32, 0:16])
    assert store.read_stats.prefetch_issued > 0
    rs = store.read_stats
    assert rs.reads + store.write_stats.reads == \
        rs.cache_hits + rs.cache_misses  # invariant survives the handoff


def test_write_behind_pending_beats_prefetch():
    """A write pending in the write-behind queue is never masked by a
    racing prefetch admitting the (stale) backend value."""
    vol = volume(seed=43)
    store = CuboidStore(spec(), backend=MemoryBackend(),
                        decode_policy=PIPELINED)
    attach_cache(store, 64 << 20)
    from repro.cluster import enable_write_behind
    enable_write_behind(store, max_items=256)
    ingest(store, 0, vol)
    store.flush()
    store.cache.clear()
    block = np.full(CUBOID, 211, dtype=np.uint8)
    store.write_cuboid(0, 0, block)  # pending in the queue + cache
    np.testing.assert_array_equal(store.read_cuboid(0, 0), block)
    cutout(store, 0, (0, 0, 0), SHAPE)  # prefetch fires mid-read
    np.testing.assert_array_equal(store.read_cuboid(0, 0), block)
    store.close()
