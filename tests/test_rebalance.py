"""Elastic rebalancing: movable partitions, live segment migration, and
the router edge cases rebalancing exercises hardest.

The contract under test (paper §6 "dynamically redistribute data"): a
`ClusterStore` may grow, shrink, and re-cut its curve partitions at any
time — including *while* cutout reads and writes are in flight — and stay
bit-identical to an uncached single `CuboidStore` reference throughout.
Moved keys must land on their new owners per `Router.segments`, and the
old owners must end up clean (backends and caches).

Also here: the tiny-grid regression tests for `Partition.owner`/`split`
(empty curve segments — `n_nodes > n_cells` and rebalanced mid-list empty
segments used to mis-assign owners or walk off the segment list).
"""
import threading

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.cluster import ClusterStore, Partition, Router, VolumeService, dispatch
from repro.core import morton
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest, write_cutout
from repro.core.store import CuboidStore

SHAPE = (32, 32, 16)
CUBOID = (8, 8, 4)
N_CELLS = 64  # 4x4x4 grid


def spec(shape=SHAPE, **kw):
    return DatasetSpec(name="rb", volume_shape=shape, dtype="uint8",
                       base_cuboid=CUBOID, **kw)


def volume(seed=0, shape=SHAPE):
    return np.random.default_rng(seed).integers(
        1, 255, size=shape, dtype=np.uint8)


def rand_box(rng, shape=SHAPE):
    lo = [int(rng.integers(0, s - 1)) for s in shape]
    hi = [int(rng.integers(l + 1, s + 1)) for l, s in zip(lo, shape)]
    return lo, hi


# ------------------------------------------------ partition / router edges --


def test_owner_of_matches_partition_curve_brute_force():
    """owner_of == the segment list, including n_parts > n_cells (the old
    ``idx // max(base, 1)`` arithmetic had base == 0 there)."""
    for n_cells in range(1, 18):
        for n_parts in range(1, 12):
            parts = morton.partition_curve(n_cells, n_parts)
            for idx in range(n_cells):
                want = next(i for i, (a, b) in enumerate(parts) if a <= idx < b)
                assert int(morton.owner_of(idx, n_cells, n_parts)) == want
            got = morton.owner_of(np.arange(n_cells), n_cells, n_parts)
            want = [next(i for i, (a, b) in enumerate(parts) if a <= x < b)
                    for x in range(n_cells)]
            np.testing.assert_array_equal(got, want)


def test_partition_skips_empty_segments():
    """Empty segments anywhere in the boundary list (what occupancy-based
    rebalancing produces) never own cells and never break split()."""
    part = Partition((0, 0, 2, 2, 4, 4))  # parts 0, 2, 4 empty
    assert part.owner(0) == 1 and part.owner(1) == 1
    assert part.owner(2) == 3 and part.owner(3) == 3
    assert part.split(0, 4) == [(1, 0, 2), (3, 2, 4)]
    # no zero-length pieces, ever
    for start in range(4):
        for stop in range(start, 5):
            pieces = part.split(start, stop)
            assert all(a < b for _, a, b in pieces)
            assert [m for _, a, b in pieces for m in range(a, b)] == \
                list(range(start, stop))


def test_partition_validation_and_constructors():
    with pytest.raises(ValueError):
        Partition((1, 4))        # must start at 0
    with pytest.raises(ValueError):
        Partition((0, 3, 2))     # must be non-decreasing
    with pytest.raises(ValueError):
        Partition((0,))          # needs one segment
    part = Partition.from_segments([(0, 2), (2, 2), (2, 4)])
    assert part.bounds == (0, 2, 2, 4)
    with pytest.raises(ValueError):
        Partition.from_segments([(0, 2), (3, 4)])  # gap
    with pytest.raises(ValueError):
        part.owner(4)            # out of range
    assert Partition.even(4, 8).segments()[:4] == [(0, 1), (1, 2), (2, 3), (3, 4)]


def test_partition_balanced_and_moves():
    # skewed occupancy: all keys in the first quarter of the curve
    cells = [0, 1, 1, 2, 3, 3, 3, 4]
    part = Partition.balanced(cells, 64, 4)
    assert part.n_parts == 4 and part.n_cells == 64
    counts = [sum(1 for c in cells if a <= c < b) for a, b in part.segments()]
    assert max(counts) - min(counts) <= 2  # roughly equal keys per part
    # empty occupancy falls back to the even split
    assert Partition.balanced([], 64, 4).bounds == Partition.even(64, 4).bounds
    # moves() diffs owners over every boundary-crossing range
    old, new = Partition((0, 32, 64)), Partition((0, 16, 64))
    assert old.moves(new) == [(16, 32, 0, 1)]
    assert new.moves(old) == [(16, 32, 1, 0)]
    assert old.moves(old) == []
    with pytest.raises(ValueError):
        old.moves(Partition((0, 8)))  # different curves


@pytest.mark.parametrize("n_nodes", [3, 5, 9])
def test_router_tiny_grid_more_nodes_than_cells(n_nodes):
    """Tiny grids at coarse resolutions: n_nodes > n_cells leaves most
    nodes owning nothing; routing must stay total and exact."""
    tiny = spec(shape=CUBOID)  # single cuboid -> n_cells == 1
    router = Router(tiny, n_nodes)
    assert router.n_cells(0) == 1
    assert router.owner(0, 0) == 0
    assert router.split_run(0, 0, 1) == [(0, 0, 1)]
    assert sum(b - a for a, b in router.segments(0)) == 1
    cluster = ClusterStore(tiny, n_nodes=n_nodes)
    vol = volume(seed=2, shape=CUBOID)
    ingest(cluster, 0, vol)
    np.testing.assert_array_equal(cutout(cluster, 0, (0, 0, 0), CUBOID), vol)
    assert sum(cluster.keys_per_node()) == 1
    cluster.close()


def test_router_with_explicit_partitions_covers_all_runs():
    """A Router holding rebalanced (empty-mid-segment) partitions splits
    every run exactly — the regression the old ``node += 1`` walk failed."""
    router = Router(spec(), 3, {0: Partition((0, 10, 10, 64))})
    n = router.n_cells(0)
    for start in range(0, n, 7):
        for stop in range(start + 1, n + 1, 11):
            pieces = router.split_run(0, start, stop)
            assert all(a < b for _, a, b in pieces)
            assert [m for _, a, b in pieces for m in range(a, b)] == \
                list(range(start, stop))
            for node, a, b in pieces:
                seg_lo, seg_hi = router.segments(0)[node]
                assert seg_lo <= a < b <= seg_hi
    with pytest.raises(ValueError):
        Router(spec(), 2, {0: Partition((0, 10, 10, 64))})  # 3 parts, 2 nodes


# ------------------------------------------------------- elasticity basics --


def test_rebalance_grow_moves_keys_to_new_owners():
    vol = volume()
    ref = CuboidStore(spec())
    ingest(ref, 0, vol)
    cluster = ClusterStore(spec(), n_nodes=2)
    ingest(cluster, 0, vol)
    before = cluster.keys_per_node()
    stats = cluster.rebalance(target=4)
    assert stats["n_nodes"] == 4 and stats["moved_keys"] > 0
    after = cluster.keys_per_node()
    assert len(after) == 4 and sum(after) == sum(before)
    assert max(after) - min(after) <= 1  # occupancy-balanced
    # every stored key sits on the node Router.segments assigns it to
    for r, c, m in cluster.stored_keys():
        owner = cluster.router.owner(r, m)
        assert cluster.nodes[owner].has_cuboid(r, m, c)
        for i, node in enumerate(cluster.nodes):
            if i != owner:
                assert not node.has_cuboid(r, m, c)
    np.testing.assert_array_equal(cutout(cluster, 0, (0, 0, 0), SHAPE),
                                  cutout(ref, 0, (0, 0, 0), SHAPE))
    assert cluster.stored_keys() == ref.stored_keys()
    cluster.close()


def test_add_and_remove_node_roundtrip():
    vol = volume(seed=5)
    cluster = ClusterStore(spec(), n_nodes=2)
    ingest(cluster, 0, vol)
    idx = cluster.add_node()
    assert idx == 2 and cluster.n_nodes == 3
    assert min(cluster.keys_per_node()) > 0  # the new node took keys
    np.testing.assert_array_equal(cutout(cluster, 0, (0, 0, 0), SHAPE), vol)
    stats = cluster.remove_node(1)  # a *middle* node
    assert stats["n_nodes"] == 2 and cluster.n_nodes == 2
    assert sum(cluster.keys_per_node()) == N_CELLS
    np.testing.assert_array_equal(cutout(cluster, 0, (0, 0, 0), SHAPE), vol)
    with pytest.raises(ValueError):
        cluster.remove_node(7)
    cluster.remove_node()
    with pytest.raises(ValueError):
        cluster.remove_node()  # cannot drop the last node
    np.testing.assert_array_equal(cutout(cluster, 0, (0, 0, 0), SHAPE), vol)
    cluster.close()


def test_rebalance_after_skewed_writes_balances_occupancy():
    """Write only one spatial corner, then rebalance: boundary cuts follow
    the keys, not the raw curve (occupancy, not geometry)."""
    cluster = ClusterStore(spec(), n_nodes=4)
    corner = volume(seed=6)[:16, :16, :8]
    write_cutout(cluster, 0, (0, 0, 0), corner)
    skewed = cluster.keys_per_node()
    cluster.rebalance()
    balanced = cluster.keys_per_node()
    assert sum(balanced) == sum(skewed)
    assert max(balanced) - min(balanced) <= max(skewed) - min(skewed)
    assert max(balanced) - min(balanced) <= 1
    np.testing.assert_array_equal(
        cutout(cluster, 0, (0, 0, 0), (16, 16, 8)), corner)
    cluster.close()


def test_rebalance_updates_partition_for_every_resolution():
    multi = spec(shape=(64, 64, 16), n_resolutions=2)
    cluster = ClusterStore(multi, n_nodes=2)
    vols = {r: volume(seed=r, shape=tuple(multi.grid(r).volume_shape))
            for r in range(2)}
    for r, vol in vols.items():
        ingest(cluster, r, vol)
    cluster.rebalance(target=3)
    assert cluster.router.partition(0).n_parts == 3
    assert cluster.router.partition(1).n_parts == 3
    for r, vol in vols.items():
        got = cutout(cluster, r, (0, 0, 0), tuple(multi.grid(r).volume_shape))
        np.testing.assert_array_equal(got, vol)
        for key_r, c, m in cluster.stored_keys():
            if key_r == r:
                assert cluster.nodes[cluster.router.owner(r, m)].has_cuboid(r, m, c)
    cluster.close()


# ------------------------------------------- coherence under interleavings --


def apply_op(store, op):
    kind = op[0]
    if kind == "read_cuboid":
        return store.read_cuboid(0, op[1])
    if kind == "write_cuboid":
        store.write_cuboid(0, op[1], op[2])
        return None
    if kind == "cutout":
        return cutout(store, 0, op[1], op[2])
    if kind == "write_cutout":
        write_cutout(store, 0, op[1], op[2])
        return None
    if kind == "migrate":
        store.migrate()
        return None
    if kind == "flush":
        if hasattr(store, "flush"):
            store.flush()
        return None
    if kind == "rebalance":
        # subject-only: the reference store is not elastic
        if isinstance(store, ClusterStore):
            store.rebalance(target=op[1])
        return None
    raise AssertionError(f"unknown op {kind}")


def random_ops(rng, n_ops):
    """Random interleaving including topology changes (1->2->4->3 style)."""
    ops = []
    targets = rng.permutation([1, 2, 3, 4]).tolist()
    for _ in range(n_ops):
        roll = rng.random()
        if roll < 0.18:
            ops.append(("read_cuboid", int(rng.integers(0, N_CELLS))))
        elif roll < 0.36:
            data = rng.integers(0, 4, size=CUBOID).astype(np.uint8)
            if rng.random() < 0.2:
                data[:] = 0  # lazy-zero delete path
            ops.append(("write_cuboid", int(rng.integers(0, N_CELLS)), data))
        elif roll < 0.54:
            ops.append(("cutout", *rand_box(rng)))
        elif roll < 0.72:
            lo, hi = rand_box(rng)
            shape = [h - l for l, h in zip(lo, hi)]
            data = rng.integers(0, 255, size=shape).astype(np.uint8)
            ops.append(("write_cutout", lo, data))
        elif roll < 0.80:
            ops.append(("migrate",))
        elif roll < 0.86:
            ops.append(("flush",))
        else:
            target = targets[int(rng.integers(0, len(targets)))]
            ops.append(("rebalance", target))
    return ops


def run_interleaving(n_nodes, ops, **cluster_kw):
    ref = CuboidStore(spec())
    sub = ClusterStore(spec(), n_nodes=n_nodes, **cluster_kw)
    try:
        for op in ops:
            want = apply_op(ref, op)
            got = apply_op(sub, op)
            if want is not None:
                np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(
            cutout(sub, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))
        sub.flush()
        assert sub.stored_keys() == ref.stored_keys()
        for r, c, m in sub.stored_keys():
            assert sub.nodes[sub.router.owner(r, m)].has_cuboid(r, m, c)
        if sub.has_cache:  # every read is a cache hit or a cache miss
            rs, ws = sub.read_stats, sub.write_stats
            assert rs.reads + ws.reads == rs.cache_hits + rs.cache_misses
    finally:
        sub.close()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_rebalancing_cluster_matches_reference(seed):
    """1->2->4->3-style topology walks interleaved with reads, writes,
    flushes, and migrations: bit-identical to the uncached reference.
    Runs under whatever REPRO_CACHE_BYTES / REPRO_WRITE_BEHIND the
    environment sets (the CI cached matrix leg covers the tiered case)."""
    rng = np.random.default_rng(seed * 13 + 1)
    ops = [("write_cutout", [0, 0, 0], volume(seed=seed))]
    ops += random_ops(rng, 50)
    ops += [("rebalance", 2), ("rebalance", 4), ("rebalance", 3)]
    run_interleaving(1, ops)


@pytest.mark.parametrize("seed", [0, 1])
def test_rebalancing_cluster_matches_reference_tiered(seed):
    """Same walk with the cache + write-behind tiers forced on and an
    eviction-heavy budget (coherence must survive all three tiers)."""
    rng = np.random.default_rng(seed * 7 + 3)
    ops = [("write_cutout", [0, 0, 0], volume(seed=seed + 10))]
    ops += random_ops(rng, 40)
    ops += [("rebalance", 2), ("rebalance", 4), ("rebalance", 3)]
    run_interleaving(1, ops, cache_bytes=6 << 10, write_behind=True,
                     write_behind_items=16)


if HAVE_HYPOTHESIS:

    @given(st.integers(min_value=0, max_value=2 ** 31 - 1),
           st.sampled_from([1, 2, 4]),
           st.integers(min_value=5, max_value=30))
    @settings(max_examples=15, deadline=None)
    def test_rebalance_coherence_property(seed, n_nodes, n_ops):
        rng = np.random.default_rng(seed)
        run_interleaving(n_nodes, random_ops(rng, n_ops))


# ------------------------------------------------- live migration coherence --


def test_live_rebalance_under_concurrent_traffic():
    """The acceptance scenario: a 2->4 rebalance completes while reader
    and writer threads hammer the cluster; every read observed *during*
    the move is bit-identical to the reference, and afterwards all keys
    sit on their new owners with nothing lost or stale."""
    base = volume(seed=11)
    sub = ClusterStore(spec(), n_nodes=2, cache_bytes=32 << 10,
                       write_behind=True, write_behind_items=32)
    ingest(sub, 0, base)  # channel 0: read-only shared ground truth
    n_writers, n_rounds = 3, 6
    refs = {t: CuboidStore(spec()) for t in range(n_writers)}
    failures = []
    stop = threading.Event()

    def reader(tid):
        rng = np.random.default_rng(500 + tid)
        try:
            while not stop.is_set():
                lo, hi = rand_box(rng)
                got = cutout(sub, 0, lo, hi)
                sl = tuple(slice(l, h) for l, h in zip(lo, hi))
                np.testing.assert_array_equal(got, base[sl])
        except Exception as e:  # pragma: no cover - surfaced via failures
            failures.append(("reader", tid, e))

    def writer(tid):
        rng = np.random.default_rng(900 + tid)
        ch = tid + 1  # each writer owns a channel
        try:
            for _ in range(n_rounds):
                lo, hi = rand_box(rng)
                shape = [h - l for l, h in zip(lo, hi)]
                data = rng.integers(1, 255, size=shape).astype(np.uint8)
                write_cutout(sub, 0, lo, data, channel=ch)
                write_cutout(refs[tid], 0, lo, data, channel=ch)
        except Exception as e:  # pragma: no cover
            failures.append(("writer", tid, e))

    readers = [threading.Thread(target=reader, args=(t,)) for t in range(2)]
    writers = [threading.Thread(target=writer, args=(t,))
               for t in range(n_writers)]
    for t in readers + writers:
        t.start()
    try:
        stats = sub.rebalance(target=4)
        assert stats["n_nodes"] == 4 and stats["moved_keys"] > 0
        # keep the boundaries moving while traffic is still in flight
        for target in (2, 3, 4):
            sub.rebalance(target=target)
        for t in writers:
            t.join(timeout=60)
            assert not t.is_alive(), "writer deadlocked"
    finally:
        stop.set()
        for t in readers:
            t.join(timeout=60)
    assert not [f for f in readers if f.is_alive()]
    assert not failures, failures
    sub.flush()
    # nothing lost: every writer channel equals its serial replay
    for tid in range(n_writers):
        np.testing.assert_array_equal(
            cutout(sub, 0, (0, 0, 0), SHAPE, channel=tid + 1),
            cutout(refs[tid], 0, (0, 0, 0), SHAPE, channel=tid + 1))
    # nothing stale: shared channel still the untouched ground truth
    np.testing.assert_array_equal(cutout(sub, 0, (0, 0, 0), SHAPE), base)
    # every key on its post-move owner, and only there
    assert sub.n_nodes == 4
    for r, c, m in sub.stored_keys():
        owner = sub.router.owner(r, m)
        assert sub.nodes[owner].has_cuboid(r, m, c)
        for i, node in enumerate(sub.nodes):
            if i != owner:
                assert not node.has_cuboid(r, m, c)
    rs, ws = sub.read_stats, sub.write_stats
    assert rs.reads + ws.reads == rs.cache_hits + rs.cache_misses
    sub.close()


def test_failed_migration_rolls_back_clean():
    """A migration that dies mid-copy must not strand blobs on the
    destinations or leave the move set published: ownership stays on the
    old boundaries, the cluster remains consistent, and a retry works."""
    vol = volume(seed=21)
    cluster = ClusterStore(spec(), n_nodes=2)
    ingest(cluster, 0, vol)

    victim = cluster.nodes[0]
    real_fetch = victim.fetch_runs

    def failing_fetch(*a, **kw):
        raise RuntimeError("node lost mid-copy")

    victim.fetch_runs = failing_fetch
    try:
        with pytest.raises(RuntimeError, match="mid-copy"):
            cluster.rebalance(target=4)
    finally:
        victim.fetch_runs = real_fetch
    # move set retired, the widened shards dropped again (no phantom
    # nodes leaked per failed attempt), nothing stranded off-owner
    assert cluster.n_nodes == 2
    assert not cluster.topology()["rebalancing"]
    for r, c, m in cluster.stored_keys():
        owner = cluster.router.owner(r, m)
        assert cluster.nodes[owner].has_cuboid(r, m, c)
        for i, node in enumerate(cluster.nodes):
            if i != owner:
                assert not node.has_cuboid(r, m, c)
    np.testing.assert_array_equal(cutout(cluster, 0, (0, 0, 0), SHAPE), vol)
    # the retry completes and balances
    stats = cluster.rebalance(target=4)
    assert stats["n_nodes"] == 4
    after = cluster.keys_per_node()
    assert max(after) - min(after) <= 1 and sum(after) == N_CELLS
    np.testing.assert_array_equal(cutout(cluster, 0, (0, 0, 0), SHAPE), vol)
    cluster.close()


# ------------------------------------------------------------ service verbs --


@pytest.fixture
def service():
    svc = VolumeService()
    store = ClusterStore(spec(), n_nodes=2)
    ingest(store, 0, volume())
    svc.add_dataset("d", store)
    svc.add_dataset("single", CuboidStore(spec()))
    return svc


def test_topology_verb(service):
    topo = dispatch(service, {"verb": "GET /topology", "dataset": "d"})
    assert topo["status"] == 200 and topo["elastic"]
    assert topo["n_nodes"] == 2 and not topo["rebalancing"]
    assert sum(topo["keys_per_node"]) == N_CELLS
    segs = topo["segments"][0]
    assert len(segs) == 2 and segs[0][0] == 0 and segs[-1][1] == N_CELLS
    single = dispatch(service, {"verb": "GET /topology", "dataset": "single"})
    assert single["status"] == 200 and not single["elastic"]
    assert single["n_nodes"] == 1
    assert dispatch(service, {"verb": "GET /topology",
                              "dataset": "nope"})["status"] == 404


def test_rebalance_verb(service):
    store = service.datasets["d"]
    want = cutout(store, 0, (3, 5, 1), (31, 29, 15))
    resp = dispatch(service, {"verb": "POST /rebalance", "dataset": "d",
                              "target": 4})
    assert resp["status"] == 200
    assert resp["topology"]["n_nodes"] == 4 and resp["moved_keys"] > 0
    np.testing.assert_array_equal(
        cutout(store, 0, (3, 5, 1), (31, 29, 15)), want)
    # boundary-only rebalance (no target) is a 200 too
    assert dispatch(service, {"verb": "POST /rebalance",
                              "dataset": "d"})["status"] == 200
    assert dispatch(service, {"verb": "POST /rebalance",
                              "dataset": "nope"})["status"] == 404
    assert dispatch(service, {"verb": "POST /rebalance", "dataset": "d",
                              "target": 0})["status"] == 400
    assert dispatch(service, {"verb": "POST /rebalance", "dataset": "d",
                              "target": "many"})["status"] == 400
    assert dispatch(service, {"verb": "POST /rebalance",
                              "dataset": "single"})["status"] == 400
    store.close()
