"""End-to-end behaviour tests for the full system."""
import subprocess
import sys
import os

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def test_train_driver_loss_decreases(tmp_path):
    """Full stack: pipeline -> train step -> ckpt -> recovery, via CLI."""
    from repro.launch.train import main
    out = main(["--arch", "smollm-135m", "--smoke", "--steps", "16",
                "--seq-len", "64", "--batch", "4", "--lr", "3e-3",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
                "--inject-failure-at", "8"])
    losses = out["losses"]
    assert losses[-1] < losses[0]
    # a checkpoint was committed and recovery replayed steps
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))


def test_train_with_microbatching_and_compression(tmp_path):
    from repro.launch.train import main
    out = main(["--arch", "granite-moe-1b-a400m", "--smoke",
                "--steps", "10", "--seq-len", "32", "--batch", "4",
                "--microbatches", "2", "--grad-compression", "bf16"])
    assert out["losses"][-1] < out["losses"][0] * 1.1


def test_serve_driver_runs():
    from repro.launch.serve import main
    out = main(["--arch", "smollm-135m", "--smoke", "--batch", "2",
                "--prompt-len", "6", "--gen", "6"])
    assert out.shape == (2, 6)
    assert not np.isnan(np.asarray(out, dtype=np.float64)).any()


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """The real multi-pod dry-run path (512 placeholder devices) in a
    subprocess so the 512-device jax init never leaks into this process."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-moe-1b-a400m", "--shape", "decode_32k", "--mesh",
         "both"],
        env=ENV, cwd=REPO, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count("OK") == 2