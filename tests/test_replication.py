"""Per-segment replication: replica rings, fan-out writes, balanced reads,
and zero-loss node removal (paper §6's availability story).

The contract under test: with ``replication=N`` every curve segment lives
on N successor nodes; writes fan out to all members, reads pick the
least-loaded replica, and the cluster stays bit-identical to an uncached
single `CuboidStore` reference through the same interleaved walks the
rebalance suite runs — including `remove_node()` of a live owner, which
must promote surviving replicas with zero lost or stale keys.
"""
import threading

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.cluster import (
    ClusterStore,
    RebalanceInFlight,
    Router,
    VolumeService,
    dispatch,
)
from repro.core.cutout import cutout, ingest

from test_rebalance import (
    SHAPE,
    rand_box,
    random_ops,
    run_interleaving,
    spec,
    volume,
)


def make_service(store, name="rb"):
    service = VolumeService()
    service.add_dataset(name, store)
    return service


# ------------------------------------------------------------ replica rings --


def test_replica_ring_shape():
    router = Router(spec(), n_nodes=4, replication=2)
    for primary in range(4):
        ring = router.replicas_of(primary)
        assert ring == (primary, (primary + 1) % 4)
    assert router.n_replicas == 2
    # replica_set resolves through the partition owner
    for r in range(router.spec.n_resolutions):
        for m in range(router.n_cells(r)):
            assert router.replica_set(r, m) == router.replicas_of(router.owner(r, m))


def test_replication_capped_at_n_nodes():
    router = Router(spec(), n_nodes=2, replication=5)
    assert router.n_replicas == 2
    assert router.replicas_of(1) == (1, 0)
    store = ClusterStore(spec(), n_nodes=2, replication=5)
    try:
        assert store.topology()["replication"] == 2
    finally:
        store.close()


def test_replication_survives_repartition():
    router = Router(spec(), n_nodes=3, replication=2)
    repart = router.with_partitions({r: router.partition(r)
                                     for r in range(router.spec.n_resolutions)})
    assert repart.replication == 2 and repart.n_replicas == 2


def test_split_run_replicas_covers_run():
    router = Router(spec(), n_nodes=3, replication=2)
    pieces = router.split_run_replicas(0, 0, router.n_cells(0))
    covered = [m for _, a, b in pieces for m in range(a, b)]
    assert covered == list(range(router.n_cells(0)))
    for members, _a, _b in pieces:
        assert len(members) == 2 and len(set(members)) == 2


# ---------------------------------------------------------- write fan-out ---


def test_write_lands_on_every_member_and_nowhere_else():
    store = ClusterStore(spec(), n_nodes=3, replication=2)
    try:
        ingest(store, 0, volume(seed=3))
        store.flush()
        keys = store.stored_keys()
        assert keys  # the walk below must check something
        for r, c, m in keys:
            members = store.router.replica_set(r, m)
            assert len(members) == 2
            for i, node in enumerate(store.nodes):
                assert node.has_cuboid(r, m, c) == (i in members), (
                    f"key {(r, c, m)} misplaced on node {i}")
    finally:
        store.close()


def test_replicated_cluster_matches_reference_serial():
    """Full-volume ingest + random boxes, bit-identical to the reference."""
    store = ClusterStore(spec(), n_nodes=3, replication=3)
    try:
        base = volume(seed=5)
        ingest(store, 0, base)
        rng = np.random.default_rng(17)
        for _ in range(20):
            lo, hi = rand_box(rng)
            sl = tuple(slice(a, b) for a, b in zip(lo, hi))
            np.testing.assert_array_equal(cutout(store, 0, lo, hi), base[sl])
    finally:
        store.close()


# ----------------------------------------------------------- read balancing --


def test_reads_spread_across_replicas():
    """With R == n_nodes == 2 every key lives on both nodes; the
    least-loaded pick must not pin all traffic to one replica."""
    store = ClusterStore(spec(), n_nodes=2, replication=2)
    try:
        ingest(store, 0, volume(seed=9))
        store.flush()
        rng = np.random.default_rng(4)
        for _ in range(30):
            lo, hi = rand_box(rng)
            cutout(store, 0, lo, hi)
        reads = [node.read_stats.reads for node in store.nodes]
        assert all(r > 0 for r in reads), f"traffic pinned: {reads}"
    finally:
        store.close()


def test_inflight_gauge_returns_to_zero():
    store = ClusterStore(spec(), n_nodes=2, replication=2)
    try:
        ingest(store, 0, volume(seed=2))
        cutout(store, 0, (0, 0, 0), SHAPE)
        assert all(node.read_stats.inflight == 0 for node in store.nodes)
        assert store.read_stats.inflight == 0  # gauge aggregates by max
    finally:
        store.close()


# -------------------------------------------------- coherence interleavings --


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_replicated_walk_matches_reference(seed):
    """The rebalance suite's coherence walk, replicated: topology changes
    interleaved with reads/writes/flushes stay bit-identical at R=2."""
    rng = np.random.default_rng(seed * 11 + 5)
    ops = [("write_cutout", [0, 0, 0], volume(seed=seed + 20))]
    ops += random_ops(rng, 40)
    ops += [("rebalance", 2), ("rebalance", 4), ("rebalance", 3)]
    run_interleaving(2, ops, replication=2)


def test_replicated_walk_matches_reference_tiered():
    """Same walk with the cache + write-behind tiers on top of R=2."""
    rng = np.random.default_rng(31)
    ops = [("write_cutout", [0, 0, 0], volume(seed=41))]
    ops += random_ops(rng, 30)
    ops += [("rebalance", 3), ("rebalance", 2), ("rebalance", 4)]
    run_interleaving(2, ops, replication=2, cache_bytes=6 << 10,
                     write_behind=True, write_behind_items=16)


if HAVE_HYPOTHESIS:

    @given(st.integers(min_value=0, max_value=2 ** 31 - 1),
           st.sampled_from([1, 2, 3]),
           st.sampled_from([2, 3]),
           st.integers(min_value=5, max_value=20))
    @settings(max_examples=10, deadline=None)
    def test_replicated_coherence_property(seed, n_nodes, replication, n_ops):
        rng = np.random.default_rng(seed)
        run_interleaving(n_nodes, random_ops(rng, n_ops),
                         replication=replication)


# -------------------------------------------------------- zero-loss removal --


def test_remove_node_promotes_survivors():
    """Removing a live owner of an R=2 cluster loses nothing: the whole
    volume reads back bit-identical and every key sits on exactly the
    surviving replica set."""
    store = ClusterStore(spec(), n_nodes=3, replication=2)
    try:
        base = volume(seed=7)
        ingest(store, 0, base)
        store.flush()
        before = store.stored_keys()
        stats = store.remove_node(1)
        assert stats["n_nodes"] == 2 and stats["removed"] == 1
        assert store.n_nodes == 2
        np.testing.assert_array_equal(cutout(store, 0, (0, 0, 0), SHAPE), base)
        store.flush()
        assert store.stored_keys() == before
        for r, c, m in store.stored_keys():
            members = store.router.replica_set(r, m)
            for i, node in enumerate(store.nodes):
                assert node.has_cuboid(r, m, c) == (i in members)
    finally:
        store.close()


def test_remove_node_unreplicated_still_streams_off():
    """R=1 removal keeps the old migrate-everything-off behaviour."""
    store = ClusterStore(spec(), n_nodes=3)
    try:
        base = volume(seed=8)
        ingest(store, 0, base)
        store.flush()
        stats = store.remove_node(0)
        assert stats["n_nodes"] == 2 and stats["moved_keys"] > 0
        np.testing.assert_array_equal(cutout(store, 0, (0, 0, 0), SHAPE), base)
    finally:
        store.close()


def test_remove_node_under_concurrent_reads():
    """The acceptance walk, transport-free: reader threads observe
    bit-identical cutouts before, during, and after removal of a live
    owner from an R=2 cluster."""
    base = volume(seed=13)
    store = ClusterStore(spec(), n_nodes=3, replication=2)
    failures = []
    stop = threading.Event()

    def reader(tid):
        rng = np.random.default_rng(700 + tid)
        try:
            while not stop.is_set():
                lo, hi = rand_box(rng)
                got = cutout(store, 0, lo, hi)
                sl = tuple(slice(a, b) for a, b in zip(lo, hi))
                np.testing.assert_array_equal(got, base[sl])
        except Exception as e:  # pragma: no cover - surfaced via failures
            failures.append((tid, e))

    try:
        ingest(store, 0, base)
        store.flush()
        threads = [threading.Thread(target=reader, args=(t,)) for t in range(3)]
        for t in threads:
            t.start()
        try:
            stats = store.remove_node(2)
            assert stats["n_nodes"] == 2
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=60)
        assert not failures, failures
        np.testing.assert_array_equal(cutout(store, 0, (0, 0, 0), SHAPE), base)
    finally:
        store.close()


def test_grow_then_shrink_replicated():
    """add_node / remove_node round trip at R=2 stays coherent."""
    store = ClusterStore(spec(), n_nodes=2, replication=2)
    try:
        base = volume(seed=21)
        ingest(store, 0, base)
        idx = store.add_node()
        assert idx == 2 and store.n_nodes == 3
        np.testing.assert_array_equal(cutout(store, 0, (0, 0, 0), SHAPE), base)
        store.remove_node(0)
        assert store.n_nodes == 2
        np.testing.assert_array_equal(cutout(store, 0, (0, 0, 0), SHAPE), base)
        store.flush()
        for r, c, m in store.stored_keys():
            members = store.router.replica_set(r, m)
            for i, node in enumerate(store.nodes):
                assert node.has_cuboid(r, m, c) == (i in members)
    finally:
        store.close()


# -------------------------------------------------------- admission / 409 ---


def test_concurrent_topology_change_raises_409():
    """wait=False surfaces an in-flight migration as `RebalanceInFlight`;
    the rebalance verb maps it to a 409 envelope."""
    store = ClusterStore(spec(), n_nodes=2, replication=2)
    try:
        ingest(store, 0, volume(seed=1))
        held = threading.Event()
        release = threading.Event()

        def holder():
            with store._admin_lock:
                held.set()
                release.wait(timeout=30)

        t = threading.Thread(target=holder)
        t.start()
        try:
            assert held.wait(timeout=10)
            with pytest.raises(RebalanceInFlight):
                store.rebalance(wait=False)
            with pytest.raises(RebalanceInFlight):
                store.add_node(wait=False)
            with pytest.raises(RebalanceInFlight):
                store.remove_node(0, wait=False)
            service = make_service(store)
            resp = dispatch(service, {"verb": "POST /rebalance", "dataset": "rb"})
            assert resp["status"] == 409 and "error" in resp
        finally:
            release.set()
            t.join(timeout=10)
        # lock released: the same verb now succeeds
        resp = dispatch(make_service(store),
                        {"verb": "POST /rebalance", "dataset": "rb"})
        assert resp["status"] == 200
    finally:
        store.close()


def test_topology_reports_replication():
    store = ClusterStore(spec(), n_nodes=3, replication=2)
    try:
        topo = store.topology()
        assert topo["replication"] == 2 and topo["n_nodes"] == 3
    finally:
        store.close()


def test_env_default_replication(monkeypatch):
    monkeypatch.setenv("REPRO_REPLICATION", "2")
    store = ClusterStore(spec(), n_nodes=3)
    try:
        assert store.topology()["replication"] == 2
    finally:
        store.close()
