"""Data pipeline, checkpointing, fault tolerance, optimizer tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.data import DataPipeline, PipelineConfig, TokenStore
from repro.ft import FailureInjector, StragglerMonitor, TrainingSupervisor
from repro.optim import (AdamWConfig, adamw_update, compress_grads,
                         cosine_schedule, decompress_grads)


# ------------------------------------------------------------- pipeline ----

@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    store = TokenStore(64, 256, cuboid=(16, 256))
    toks = rng.integers(0, 1000, size=(64, 256))
    store.ingest_corpus(toks)
    return store, toks


def test_pipeline_batch_correctness(corpus):
    store, toks = corpus
    pipe = DataPipeline(store, PipelineConfig(seq_len=32, global_batch=8))
    batch = pipe.get_batch(0)
    assert batch["tokens"].shape == (8, 32)
    rows = pipe.batch_rows(0)
    for i, r in enumerate(rows):
        np.testing.assert_array_equal(batch["tokens"][i], toks[r, :32])
        np.testing.assert_array_equal(batch["labels"][i], toks[r, 1:33])


def test_pipeline_stateless_addressing(corpus):
    """Same (seed, step) -> same batch; different steps differ."""
    store, _ = corpus
    p1 = DataPipeline(store, PipelineConfig(seq_len=16, global_batch=4,
                                            seed=7))
    p2 = DataPipeline(store, PipelineConfig(seq_len=16, global_batch=4,
                                            seed=7))
    np.testing.assert_array_equal(p1.get_batch(3)["tokens"],
                                  p2.get_batch(3)["tokens"])
    assert not np.array_equal(p1.get_batch(3)["tokens"],
                              p1.get_batch(4)["tokens"])


def test_pipeline_host_sharding_covers_batch(corpus):
    store, _ = corpus
    shards = []
    for host in range(4):
        p = DataPipeline(store, PipelineConfig(
            seq_len=16, global_batch=8, n_hosts=4, host_id=host))
        shards.append(p.host_slice(0))
    all_rows = np.concatenate(shards)
    np.testing.assert_array_equal(
        all_rows, DataPipeline(store, PipelineConfig(
            seq_len=16, global_batch=8)).batch_rows(0))


def test_pipeline_prefetch(corpus):
    store, _ = corpus
    pipe = DataPipeline(store, PipelineConfig(seq_len=16, global_batch=4))
    pipe.start(first_step=5)
    step, batch = pipe.next()
    assert step == 5
    step2, _ = pipe.next()
    assert step2 == 6
    pipe.stop()


# ----------------------------------------------------------- checkpoint ----

def tree_example(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": rng.normal(size=(33, 17)).astype(np.float32),
                       "b": rng.normal(size=(9,)).astype(np.float32)},
            "opt": {"step": np.array(7, np.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = tree_example()
    save_checkpoint(str(tmp_path), 3, tree)
    step, back = restore_checkpoint(str(tmp_path))
    assert step == 3
    np.testing.assert_array_equal(back["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(back["opt"]["step"], tree["opt"]["step"])


def test_checkpoint_atomic_commit(tmp_path):
    tree = tree_example()
    save_checkpoint(str(tmp_path), 1, tree)
    # a crashed (uncommitted) attempt leaves only a .tmp dir -> invisible
    os.makedirs(tmp_path / ".tmp_step_00000002" )
    step, _ = restore_checkpoint(str(tmp_path))
    assert step == 1


def test_checkpoint_large_leaf_multichunk(tmp_path):
    big = {"w": np.arange(3 << 20, dtype=np.float32)}  # 12MB -> 3 chunks
    save_checkpoint(str(tmp_path), 1, big)
    manifest_chunks = [f for f in os.listdir(tmp_path / "step_00000001")
                      if f.endswith(".chunk")]
    assert len(manifest_chunks) >= 3
    _, back = restore_checkpoint(str(tmp_path))
    np.testing.assert_array_equal(back["w"], big["w"])


def test_checkpoint_elastic_shard_union(tmp_path):
    """Sharded restore across a *different* host count reassembles the
    whole leaf (elastic rescale via curve re-partition)."""
    big = {"w": np.arange(2 << 20, dtype=np.float32) * 0.5}
    save_checkpoint(str(tmp_path), 1, big)
    n_hosts = 3
    acc = np.zeros_like(big["w"])
    for h in range(n_hosts):
        _, part = restore_checkpoint(str(tmp_path), shard_info=(h, n_hosts))
        acc += part["w"]
    np.testing.assert_array_equal(acc, big["w"])  # disjoint cover


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree_example(s))
    mgr.wait()
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


# ------------------------------------------------------ fault tolerance ----

def test_supervisor_recovers_from_failure(tmp_path):
    injector = FailureInjector({7: 2})
    sup = TrainingSupervisor(str(tmp_path), ckpt_every=3,
                             injector=injector)

    def step_fn(state, step):
        return {"x": state["x"] + 1.0}

    out = sup.run({"x": np.float32(0)}, step_fn, 10,
                  state_to_tree=lambda s: s,
                  tree_to_state=lambda t, s: {"x": np.float32(t["x"])})
    # deterministic step function -> recovery is exact
    assert float(out["x"]) == 10.0
    assert sup.restarts == 1
    assert sup.recovery_log[0]["failed_step"] == 7
    assert sup.recovery_log[0]["restored_to"] == 6


def test_supervisor_cold_restart(tmp_path):
    injector = FailureInjector({1: 0})
    sup = TrainingSupervisor(str(tmp_path), ckpt_every=100,
                             injector=injector)
    out = sup.run({"x": np.float32(0)},
                  lambda s, i: {"x": s["x"] + 1}, 5)
    assert float(out["x"]) == 5.0  # replayed from 0


def test_straggler_monitor():
    mon = StragglerMonitor(4, threshold=1.5)
    for _ in range(5):
        for w, dt in [(0, 1.0), (1, 1.0), (2, 1.1), (3, 3.0)]:
            mon.record(w, dt)
    assert mon.stragglers() == [3]


# ------------------------------------------------------------ optimizer ----

def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, clip_norm=10.0)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8,)),
                         jnp.float32)
    params = {"w": jnp.zeros(8, jnp.bfloat16)}
    opt = {"mu": {"w": jnp.zeros(8)}, "nu": {"w": jnp.zeros(8)},
           "master": {"w": jnp.zeros(8)}, "step": jnp.int32(0)}

    def loss(p):
        return jnp.sum((p["w"].astype(jnp.float32) - target) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < 0.05 * l0


def test_grad_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(
        size=(64,)).astype(np.float32) * 1e-3)}
    comp, resid = compress_grads(g, "int8")
    back = decompress_grads(comp, "int8")
    # error feedback: residual + decompressed == original
    np.testing.assert_allclose(
        np.asarray(back["w"]) + np.asarray(resid["w"]),
        np.asarray(g["w"]), rtol=1e-5, atol=1e-8)
    # second step folds the residual back in
    comp2, resid2 = compress_grads(g, "int8", resid)
    back2 = decompress_grads(comp2, "int8")
    np.testing.assert_allclose(
        np.asarray(back2["w"]) + np.asarray(resid2["w"]),
        np.asarray(g["w"]) + np.asarray(resid["w"]), rtol=1e-5, atol=1e-8)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, 0)) == 0.0
    assert float(cosine_schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(cosine_schedule(cfg, 100)) == pytest.approx(0.0, abs=1e-6)
    assert float(cosine_schedule(cfg, 55)) < 1.0

def test_adamw_bf16_states_reduces_quadratic_loss():
    """bf16 moments still optimize (memory-efficient production mode)."""
    import jax
    import jax.numpy as jnp
    from repro.models.params import ParamSpec, init_params
    from repro.optim import AdamWConfig, adamw_init_specs, adamw_update

    specs = {"w": ParamSpec((8,), (None,), dtype="float32")}
    cfg = AdamWConfig(lr_peak=0.2, warmup_steps=1, total_steps=300,
                      weight_decay=0.0, state_dtype="bfloat16")
    params = init_params(specs, jax.random.key(0))
    opt = init_params(adamw_init_specs(specs, cfg.state_dtype),
                      jax.random.key(1))
    opt["master"] = jax.tree.map(
        lambda p: jnp.array(p, jnp.float32, copy=True), params)
    target = jnp.arange(8.0)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert opt["mu"]["w"].dtype == jnp.bfloat16
    assert float(loss(params)) < l0 * 0.1
