"""Suite-wide correctness instrumentation.

* The lock-order witness is ON for the whole tier-1 suite (unless the
  environment explicitly disables it), so every coherence / rebalance /
  compaction stress test doubles as a deadlock-order test: any rank
  inversion, acquisition-graph cycle, or submit-under-lock observed
  anywhere in the run fails the test that triggered it, with stacks.
* A session-teardown deflake guard asserts that no non-daemon thread and
  no ranked lock outlives the suite (a leaked Compactor / Supervisor /
  flusher thread turns into cross-test flakes otherwise).

The env knob must be set before any ``repro`` module is imported —
conftest import time is the one hook that reliably precedes them.
"""

import os

os.environ.setdefault("REPRO_LOCK_WITNESS", "1")

import threading  # noqa: E402

import pytest  # noqa: E402

from repro.analysis import witness  # noqa: E402


@pytest.fixture(autouse=True)
def lock_witness_guard():
    """Fail the current test on any lock-discipline violation it caused."""
    witness.GLOBAL.take_violations()  # drop anything left by collection
    yield
    violations = witness.GLOBAL.take_violations()
    if violations:
        pytest.fail(
            "lock-order witness recorded %d violation(s):\n\n%s"
            % (len(violations), "\n\n".join(v.format() for v in violations)),
            pytrace=False,
        )


# ThreadPoolExecutor workers are non-daemon but park idle on their work
# queue and are joined by the interpreter at exit; pools from stores the
# tests never close are not the flake source this guard hunts (leaked
# component threads — compactor / supervisor / flusher — are).
_POOL_PREFIXES = ("ocp-node", "ocp-batch", "ocp-decode", "ThreadPoolExecutor")


@pytest.fixture(scope="session", autouse=True)
def deflake_guard():
    """No non-daemon threads and no ranked locks held at session end."""
    main = threading.main_thread()
    yield
    leaked = [t for t in threading.enumerate()
              if t is not main and t.is_alive() and not t.daemon
              and not t.name.startswith(_POOL_PREFIXES)]
    for t in leaked:
        t.join(timeout=2.0)
    leaked = [t for t in leaked if t.is_alive()]
    assert not leaked, (
        "non-daemon thread(s) leaked past session teardown: "
        + ", ".join(repr(t) for t in leaked))

    held = witness.GLOBAL.held_snapshot()
    assert not held, f"ranked locks still held at session teardown: {held}"
