"""Sharding-rule resolution tests: every arch must resolve to legal specs."""
import numpy as np
import pytest
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.params import ParamSpec, is_spec
from repro.train.sharding import (default_rules, make_plan, resolve_leaf, batch_pspec)


class FakeMesh:
    """Shape-only stand-in so rule tests don't need 256 devices."""

    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)

    @property
    def size(self):
        v = 1
        for s in self.shape.values():
            v *= s
        return v


def plan16x16():
    return make_plan(FakeMesh({"data": 16, "model": 16}), multi_pod=False)


def plan2x16x16():
    return make_plan(FakeMesh({"pod": 2, "data": 16, "model": 16}),
                     multi_pod=True)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("plan_fn", [plan16x16, plan2x16x16])
def test_all_arch_params_resolve(arch, plan_fn):
    """Every param dim's assignment divides; no mesh axis used twice."""
    plan = plan_fn()
    cfg = get_config(arch)
    model = build_model(cfg)
    specs = model.specs()
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    assert leaves
    for s in leaves:
        p = resolve_leaf(s, plan)
        used = []
        for dim, cand in enumerate(p):
            if cand is None:
                continue
            names = (cand,) if isinstance(cand, str) else cand
            size = int(np.prod([plan.mesh.shape[n] for n in names]))
            assert s.shape[dim] % size == 0, (arch, s.shape, s.axes, p)
            used += list(names)
        assert len(used) == len(set(used)), (arch, s.axes, p)


def test_fsdp_and_tp_assignment():
    plan = plan16x16()
    s = ParamSpec((1024, 4096), ("embed", "ff"))
    assert resolve_leaf(s, plan) == P(("data",), ("model",))


def test_divisibility_fallback_drops_axis():
    plan = plan16x16()
    # 9 kv heads: not divisible by 16 -> replicated, kv_len picks up model
    s = ParamSpec((32, 32768, 9, 64),
                  ("batch", "kv_len", "kv_heads_cache", None))
    p = resolve_leaf(s, plan)
    assert p[2] is None
    assert p[1] in ("model", ("model",))  # sequence-sharded cache
    assert p[0] in ("data", ("data",))


def test_kv_heads_shardable_keeps_seq_replicated():
    plan = plan16x16()
    s = ParamSpec((8, 32768, 16, 64),
                  ("batch", "kv_len", "kv_heads_cache", None))
    p = resolve_leaf(s, plan)
    assert p[2] in ("model", ("model",))
    assert p[1] is None


def test_batch_pspec_fallbacks():
    plan = plan2x16x16()
    assert batch_pspec(plan, 2, 256) == P(("pod", "data"), None)
    assert batch_pspec(plan, 2, 16) == P(("data",), None)   # pod drop
    assert batch_pspec(plan, 2, 1) == P(None, None)         # replicate


def test_multi_pod_fsdp_over_both_axes():
    plan = plan2x16x16()
    s = ParamSpec((16384, 53248), ("embed", "ff"))
    assert resolve_leaf(s, plan) == P(("pod", "data"), ("model",))


@pytest.mark.parametrize("arch", ["arctic_480b", "granite_moe_1b_a400m"])
def test_experts_map_to_model_axis(arch):
    plan = plan16x16()
    cfg = get_config(arch)
    model = build_model(cfg)
    specs = model.specs()
    moe_leaf = jax.tree.leaves(
        {"w": specs["blocks"]["moe"]["w_gate"]}, is_leaf=is_spec)[0]
    p = resolve_leaf(moe_leaf, plan)
    # (layers, experts, d, ff): experts on model axis (EP)
    assert p[1] in ("model", ("model",))


def test_weight_stationary_decode_rules():
    """§Perf decode variant: batch replicated, weights stay sharded."""
    from repro.launch.hillclimb import _rules_weight_stationary
    rules = _rules_weight_stationary(default_rules(False))
    plan = make_plan(FakeMesh({"data": 16, "model": 16}), multi_pod=False,
                     rules=rules)
    # weight (embed, ff): embed over data, ff over model — unchanged shards
    assert resolve_leaf(ParamSpec((16384, 53248), ("embed", "ff")),
                        plan) == P(("data",), ("model",))
    # kv cache: batch replicated, seq over data
    p = resolve_leaf(ParamSpec((128, 32768, 8, 128),
                               ("batch", "kv_len", "kv_heads_cache", None)),
                     plan)
    assert p[0] is None and p[1] in ("data", ("data",))


from hypothesis_compat import given, settings, st


@given(data=st.data())
@settings(max_examples=50, deadline=None)
def test_resolve_leaf_properties(data):
    """Any ParamSpec resolves to a legal assignment: all sharded dims
    divide, no mesh axis is used twice."""
    plan = data.draw(st.sampled_from([plan16x16(), plan2x16x16()]))
    names = list(default_rules(plan.multi_pod))
    rank = data.draw(st.integers(1, 4))
    axes = tuple(data.draw(st.sampled_from(names + [None]))
                 for _ in range(rank))
    shape = tuple(data.draw(st.sampled_from([1, 2, 9, 16, 56, 128, 256,
                                             4096]))
                  for _ in range(rank))
    s = ParamSpec(shape, axes)
    p = resolve_leaf(s, plan)
    used = []
    for dim, cand in enumerate(p):
        if cand is None:
            continue
        ns = (cand,) if isinstance(cand, str) else cand
        size = int(np.prod([plan.mesh.shape[n] for n in ns]))
        assert shape[dim] % size == 0
        used += list(ns)
    assert len(used) == len(set(used))
