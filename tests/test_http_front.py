"""The HTTP front door over real sockets: URL routing, wire encoding,
admission shedding, cutout coalescing, and the replicated-failover
acceptance walk (reads stay bit-identical over HTTP while a live owner
is decommissioned).

Everything runs against an ephemeral-port `FrontDoor` (stdlib
`ThreadingHTTPServer`) talking to in-process cluster stores — no fixtures
beyond the standard library's `urllib`.
"""
import base64
import json
import threading
import time
import urllib.error
import urllib.request
import zlib

import numpy as np
import pytest

from repro.cluster import ClusterStore, VolumeService
from repro.core.annotations import AnnotationProject
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest
from repro.core.store import CuboidStore
from repro.serve.http_front import FrontDoor

SHAPE = (32, 32, 16)
CUBOID = (8, 8, 4)


def spec(name="web", **kw):
    return DatasetSpec(name=name, volume_shape=SHAPE, dtype="uint8",
                       base_cuboid=CUBOID, **kw)


def volume(seed=0):
    return np.random.default_rng(seed).integers(1, 255, size=SHAPE,
                                                dtype=np.uint8)


def http(method, url, body=None, headers=None):
    """One urllib round trip -> (status, headers, payload bytes)."""
    req = urllib.request.Request(url, data=body, method=method,
                                 headers=headers or {})
    try:
        with urllib.request.urlopen(req, timeout=30) as resp:
            return resp.status, dict(resp.headers), resp.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


def get_volume(url):
    """GET an octet-stream volume -> ndarray (decoding zlib if flagged)."""
    status, headers, payload = http("GET", url)
    assert status == 200, payload
    if headers.get("X-Encode") == "zlib":
        payload = zlib.decompress(payload)
    shape = tuple(int(s) for s in headers["X-Shape"].split(","))
    return np.frombuffer(payload, dtype=headers["X-Dtype"]).reshape(shape)


def json_body(payload):
    return json.loads(payload)


@pytest.fixture()
def front():
    """Ephemeral-port front door over one replicated cluster dataset."""
    base = volume(seed=1)
    store = ClusterStore(spec(), n_nodes=3, replication=2)
    ingest(store, 0, base)
    service = VolumeService()
    service.add_dataset("web", store)
    door = FrontDoor(service)
    door.start()
    yield door, base, store
    door.close()
    store.close()


# -------------------------------------------------------------- round trips --


def test_get_cutout_raw_and_zlib(front):
    door, base, _store = front
    url = f"{door.url}/v1/web/cutout/0/0,16/8,24/0,8"
    want = base[0:16, 8:24, 0:8]
    np.testing.assert_array_equal(get_volume(url), want)
    np.testing.assert_array_equal(get_volume(url + "?encode=zlib"), want)
    status, headers, _ = http("GET", url + "?encode=zlib&level=7")
    assert status == 200
    assert headers["X-Encode"] == "zlib" and headers["X-Level"] == "7"
    assert headers["Content-Type"] == "application/octet-stream"


def test_v1_prefix_optional(front):
    door, base, _store = front
    a = get_volume(f"{door.url}/v1/web/cutout/0/0,8/0,8/0,4")
    b = get_volume(f"{door.url}/web/cutout/0/0,8/0,8/0,4")
    np.testing.assert_array_equal(a, b)


def test_put_cutout_raw_round_trip(front):
    door, _base, _store = front
    data = np.random.default_rng(5).integers(1, 255, size=(16, 8, 8),
                                             dtype=np.uint8)
    url = f"{door.url}/web/cutout/0/8,24/16,24/4,12"
    status, _h, payload = http("PUT", url, body=data.tobytes())
    env = json_body(payload)
    assert status == 200 and env["written_shape"] == [16, 8, 8]
    np.testing.assert_array_equal(get_volume(url), data)


def test_put_cutout_zlib_round_trip(front):
    door, _base, _store = front
    data = np.random.default_rng(6).integers(1, 255, size=(8, 8, 4),
                                             dtype=np.uint8)
    url = f"{door.url}/web/cutout/0/0,8/0,8/0,4"
    status, _h, payload = http("PUT", url + "?encode=zlib&sync=1",
                               body=zlib.compress(data.tobytes()))
    assert status == 200, payload
    np.testing.assert_array_equal(get_volume(url), data)
    # the durability barrier verb still works over the wire
    status, _h, payload = http("POST", f"{door.url}/web/flush")
    env = json_body(payload)
    assert status == 200 and "flushed" in env


def test_put_payload_size_mismatch_is_400(front):
    door, _base, _store = front
    status, _h, payload = http(
        "PUT", f"{door.url}/web/cutout/0/0,8/0,8/0,4", body=b"\x01" * 10)
    env = json_body(payload)
    assert status == 400 and "payload" in env["error"]


def test_projection_routes(front):
    door, base, _store = front
    tile = get_volume(f"{door.url}/web/xy/0/0,16/0,16/3,4")
    np.testing.assert_array_equal(tile, base[0:16, 0:16, 3])
    tile = get_volume(f"{door.url}/web/yz/0/2,3/0,16/0,8")
    np.testing.assert_array_equal(tile, base[2, 0:16, 0:8])


def test_batch_cutout_json(front):
    door, base, _store = front
    boxes = [[[0, 0, 0], [8, 8, 4]], [[8, 8, 4], [16, 16, 8]]]
    body = json.dumps({"boxes": boxes, "encode": "zlib"}).encode()
    status, _h, payload = http(
        "POST", f"{door.url}/web/batch/cutout", body=body,
        headers={"Content-Type": "application/json"})
    env = json_body(payload)
    assert status == 200 and env["n"] == 2
    for box, result in zip(boxes, env["results"]):
        assert result["status"] == 200 and result["encode"] == "zlib"
        raw = zlib.decompress(base64.b64decode(result["data"]))
        got = np.frombuffer(raw, dtype=result["dtype"]).reshape(result["shape"])
        (x0, y0, z0), (x1, y1, z1) = box
        np.testing.assert_array_equal(got, base[x0:x1, y0:y1, z0:z1])


def test_stats_topology_rebalance_routes(front):
    door, _base, store = front
    status, _h, payload = http("GET", f"{door.url}/web/stats")
    env = json_body(payload)
    assert status == 200 and "read" in env and "write" in env
    status, _h, payload = http("GET", f"{door.url}/web/topology")
    env = json_body(payload)
    assert status == 200 and env["n_nodes"] == 3 and env["replication"] == 2
    status, _h, payload = http("POST", f"{door.url}/web/rebalance",
                               body=json.dumps({"target": 4}).encode())
    env = json_body(payload)
    assert status == 200 and env["topology"]["n_nodes"] == 4
    assert store.n_nodes == 4


def test_objects_routes():
    proj = AnnotationProject("ann", spec(name="img"))
    a = proj.meta.create(ann_type="synapse")
    blob = np.full((5, 4, 3), a.ann_id, dtype=np.uint32)
    proj.write(0, (4, 6, 2), blob)
    service = VolumeService()
    service.add_project("ann", proj)
    with FrontDoor(service) as door:
        status, _h, payload = http(
            "GET", f"{door.url}/ann/objects/{a.ann_id}/boundingbox")
        env = json_body(payload)
        assert status == 200 and env["id"] == a.ann_id
        # the index is cuboid-granular: the bbox contains the written blob
        assert all(l <= w for l, w in zip(env["lo"], (4, 6, 2)))
        assert all(h >= w for h, w in zip(env["hi"], (9, 10, 5)))
        status, headers, payload = http(
            "GET", f"{door.url}/ann/objects/{a.ann_id}/cutout")
        assert status == 200
        got = np.frombuffer(payload, dtype=headers["X-Dtype"]).reshape(
            tuple(int(s) for s in headers["X-Shape"].split(",")))
        # bbox-shaped dense array: the object's voxels, all else masked to 0
        assert set(np.unique(got)) == {0, a.ann_id}
        assert int((got == a.ann_id).sum()) == 5 * 4 * 3
        status, _h, payload = http("GET", f"{door.url}/ann/objects/99/boundingbox")
        assert status == 404


# ------------------------------------------------------------ the envelope ---


def test_error_envelope(front):
    door, _base, _store = front
    cases = [
        ("GET", "/nosuch/cutout/0/0,8/0,8/0,4", None, 404),
        ("GET", "/web/cutout/0/8,0/0,8/0,4", None, 400),  # lo > hi
        ("GET", "/web/cutout/0/0,x/0,8/0,4", None, 400),  # non-integer
        ("GET", "/web/cutout/zz/0,8/0,8/0,4", None, 400),  # bad resolution
        ("POST", "/web/topology", b"{}", 405),
        ("DELETE", "/web/cutout/0/0,8/0,8/0,4", None, 405),
        ("GET", "/totally/made/up/route", None, 404),
        ("POST", "/web/batch/cutout", b"not json", 400),
    ]
    for method, path, body, want in cases:
        status, _h, payload = http(method, door.url + path, body=body)
        env = json_body(payload)
        assert status == want, (method, path, env)
        assert env["status"] == want and env["error"]


# ------------------------------------------------------- admission control ---


class _SlowStore(CuboidStore):
    """A store whose reads block until released (to pile up admissions)."""

    def __init__(self, spec, gate):
        super().__init__(spec)
        self._gate = gate

    def fetch_blocks(self, r, runs, channel=0, sink=None):
        self._gate.wait(timeout=30)
        return super().fetch_blocks(r, runs, channel, sink)


def test_admission_limit_sheds_with_503():
    gate = threading.Event()
    store = _SlowStore(spec(), gate)
    ingest(store, 0, volume(seed=2))
    gate.set()  # ingest path unaffected
    if hasattr(store, "flush"):
        store.flush()
    gate.clear()
    service = VolumeService()
    service.add_dataset("web", store)
    with FrontDoor(service, admit_limit=2, admit_timeout=0.1,
                   coalesce=False) as door:
        url = f"{door.url}/web/cutout/0/0,8/0,8/0,4"
        results = []

        def fetch():
            results.append(http("GET", url)[0])

        threads = [threading.Thread(target=fetch) for _ in range(6)]
        for t in threads:
            t.start()
        time.sleep(0.5)  # let 2 enter and the rest time out of admission
        gate.set()
        for t in threads:
            t.join(timeout=30)
        assert sorted(results)[:1] == [200]  # the admitted ones finish
        assert results.count(503) >= 1, results
        assert door.shed >= 1 and door.counters()["shed"] == results.count(503)
    store.close()


def test_control_plane_never_shed():
    """Topology/stats must answer even when the data plane is saturated."""
    gate = threading.Event()
    store = _SlowStore(spec(), gate)
    ingest(store, 0, volume(seed=3))
    gate.clear()
    service = VolumeService()
    service.add_dataset("web", store)
    with FrontDoor(service, admit_limit=1, admit_timeout=0.1,
                   coalesce=False) as door:
        blocker = threading.Thread(
            target=http, args=("GET", f"{door.url}/web/cutout/0/0,8/0,8/0,4"))
        blocker.start()
        time.sleep(0.2)
        status, _h, _p = http("GET", f"{door.url}/web/stats")
        assert status == 200
        gate.set()
        blocker.join(timeout=30)
    store.close()


# ---------------------------------------------------------------- coalescer --


class _SlowCluster(ClusterStore):
    """A cluster whose cutout fetches take a beat, so concurrent requests
    pile up behind the coalescer's leader deterministically."""

    def fetch_blocks(self, r, runs, channel=0, sink=None):
        time.sleep(0.03)
        return super().fetch_blocks(r, runs, channel, sink)


def test_identical_concurrent_cutouts_coalesce():
    base = volume(seed=4)
    store = _SlowCluster(spec(), n_nodes=2, replication=2)
    ingest(store, 0, base)
    service = VolumeService()
    service.add_dataset("web", store)
    with FrontDoor(service, admit_limit=16) as door:
        url = f"{door.url}/web/cutout/0/0,16/0,16/0,8"
        want = base[0:16, 0:16, 0:8]
        barrier = threading.Barrier(8)
        failures = []

        def fetch():
            try:
                barrier.wait(timeout=10)
                for _ in range(4):
                    np.testing.assert_array_equal(get_volume(url), want)
            except Exception as e:  # pragma: no cover
                failures.append(e)

        threads = [threading.Thread(target=fetch) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not failures, failures
        counters = door.counters()
        assert counters["batches"] >= 1
        # 8 threads x 4 identical ~30ms requests: while the leader executes
        # one batch the rest queue, so later rounds must have shared a batch
        assert counters["coalesced"] + counters["deduped"] > 0, counters
    store.close()


def test_mixed_concurrent_cutouts_all_correct(front):
    door, base, _store = front
    failures = []

    def fetch(tid):
        rng = np.random.default_rng(100 + tid)
        try:
            for _ in range(5):
                lo = [int(rng.integers(0, s - 4)) for s in SHAPE]
                hi = [l + 4 for l in lo]
                url = (f"{door.url}/web/cutout/0/"
                       + "/".join(f"{a},{b}" for a, b in zip(lo, hi)))
                sl = tuple(slice(a, b) for a, b in zip(lo, hi))
                np.testing.assert_array_equal(get_volume(url), base[sl])
        except Exception as e:  # pragma: no cover
            failures.append((tid, e))

    threads = [threading.Thread(target=fetch, args=(t,)) for t in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not failures, failures


# ------------------------------------------------- replicated failover walk --


def test_replicated_failover_over_http():
    """The acceptance scenario end to end: an R=2 cluster serves
    bit-identical cutouts over HTTP before, during, and after
    ``DELETE /v1/web/nodes/1`` removes a live owner — zero lost or stale
    reads, verified by a full coherence walk afterwards."""
    base = volume(seed=11)
    store = ClusterStore(spec(), n_nodes=3, replication=2)
    ingest(store, 0, base)
    store.flush()
    service = VolumeService()
    service.add_dataset("web", store)
    failures = []
    lost_reads = []
    stop = threading.Event()
    with FrontDoor(service) as door:

        def reader(tid):
            rng = np.random.default_rng(300 + tid)
            try:
                while not stop.is_set():
                    lo = [int(rng.integers(0, s - 4)) for s in SHAPE]
                    hi = [l + 4 for l in lo]
                    url = (f"{door.url}/web/cutout/0/"
                           + "/".join(f"{a},{b}" for a, b in zip(lo, hi)))
                    status, headers, payload = http("GET", url)
                    if status != 200:
                        lost_reads.append((tid, status))
                        continue
                    got = np.frombuffer(
                        payload, dtype=headers["X-Dtype"]).reshape(
                        tuple(int(s) for s in headers["X-Shape"].split(",")))
                    sl = tuple(slice(a, b) for a, b in zip(lo, hi))
                    np.testing.assert_array_equal(got, base[sl])
            except Exception as e:  # pragma: no cover
                failures.append((tid, e))

        threads = [threading.Thread(target=reader, args=(t,))
                   for t in range(3)]
        for t in threads:
            t.start()
        try:
            time.sleep(0.2)  # reads in flight before the failover
            status, _h, payload = http("DELETE", f"{door.url}/v1/web/nodes/1")
            env = json_body(payload)
            assert status == 200, env
            assert env["n_nodes"] == 2 and env["topology"]["n_nodes"] == 2
            time.sleep(0.2)  # reads in flight after the failover
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=60)
    assert not failures, failures
    assert not lost_reads, lost_reads  # zero lost reads: nothing shed or 4xx
    # post-failover coherence walk: bit-identical, keys on survivors only
    np.testing.assert_array_equal(cutout(store, 0, (0, 0, 0), SHAPE), base)
    store.flush()
    assert store.n_nodes == 2
    for r, c, m in store.stored_keys():
        members = store.router.replica_set(r, m)
        for i, node in enumerate(store.nodes):
            assert node.has_cuboid(r, m, c) == (i in members)
    store.close()


def test_add_node_over_http(front):
    door, base, store = front
    status, _h, payload = http("POST", f"{door.url}/web/nodes")
    env = json_body(payload)
    assert status == 200 and env["node"] == 3
    assert store.n_nodes == 4
    np.testing.assert_array_equal(
        get_volume(f"{door.url}/web/cutout/0/0,32/0,32/0,16"), base)
