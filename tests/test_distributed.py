"""Distributed (shard_map) cutout vs numpy oracle, on a small host mesh."""
import os

import numpy as np
import pytest

# NOTE: tests use the single real CPU device by default; this file builds a
# tiny 4-device mesh via a subprocess-free trick: jax allows a 1-device mesh
# too, so we exercise both code paths with n_dev in {1}. The 512-device path
# is exercised by launch/dryrun.py (see EXPERIMENTS.md §Dry-run).
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.cuboid import CuboidGrid
from repro.core.distributed import (distributed_cutout,
                                    distributed_write_cutout,
                                    pack_to_cuboids, shard_cuboids,
                                    unpack_from_cuboids)


@pytest.fixture(scope="module")
def mesh1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))


def test_pack_unpack_roundtrip():
    grid = CuboidGrid((20, 12, 6), (8, 8, 4))
    rng = np.random.default_rng(0)
    vol = rng.integers(0, 255, size=grid.volume_shape, dtype=np.uint8)
    packed = pack_to_cuboids(vol, grid)
    assert packed.shape == (grid.n_cells, 8, 8, 4)
    back = unpack_from_cuboids(packed, grid)
    np.testing.assert_array_equal(back, vol)


def test_distributed_cutout_matches_numpy(mesh1):
    grid = CuboidGrid((32, 32, 8), (8, 8, 4))
    rng = np.random.default_rng(1)
    vol = rng.integers(0, 255, size=grid.volume_shape, dtype=np.uint8)
    packed = shard_cuboids(jnp.asarray(pack_to_cuboids(vol, grid)), mesh1)
    for lo, hi in [((0, 0, 0), (8, 8, 4)), ((3, 5, 1), (27, 30, 7)),
                   ((8, 8, 0), (24, 24, 8))]:
        got = distributed_cutout(packed, grid, lo, hi, mesh1)
        want = vol[tuple(slice(l, h) for l, h in zip(lo, hi))]
        np.testing.assert_array_equal(np.asarray(got), want)


def test_distributed_write_then_read(mesh1):
    grid = CuboidGrid((16, 16, 8), (8, 8, 4))
    vol = np.zeros(grid.volume_shape, dtype=np.float32)
    packed = shard_cuboids(jnp.asarray(pack_to_cuboids(vol, grid)), mesh1)
    patch = jnp.full((6, 5, 3), 3.25, dtype=jnp.float32)
    updated = distributed_write_cutout(packed, grid, (5, 6, 2), patch, mesh1)
    got = distributed_cutout(updated, grid, (0, 0, 0), (16, 16, 8), mesh1)
    want = vol.copy()
    want[5:11, 6:11, 2:5] = 3.25
    np.testing.assert_allclose(np.asarray(got), want)
